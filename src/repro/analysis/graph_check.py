"""Pre-flight job-graph / QoS validation (run by both execution backends).

``run_preflight`` is called at the top of ``StreamSimulator.__init__`` and
``StreamEngine.__init__`` (opt out with ``preflight=False``): it walks the
*job-level* description — job graph, constraints, pool parameters, buffer
bounds — and returns structured ``Diagnostic`` records against the shared
rule catalog in analysis/diagnostics.py.  Any ERROR raises
``GraphValidationError`` (a ValueError) before the runtime graph is
expanded; WARNs are stored on the executor as ``preflight_diagnostics``.

Everything here is O(job graph): the pass never expands or iterates
runtime channels (a paper-scale m=800 media job has ~640k of them), so
pre-flight cost is negligible even for the largest grids.  It consumes no
randomness and mutates nothing — the simulator's bit-exact determinism
goldens are unaffected.

The checks that must also hold while *building* a graph (duplicate vertex,
dangling edge, POINTWISE mismatch, cycle, key-range addressability) are
raised by ``core/graphs.py`` through the same registry, so build-time and
pre-flight failures carry identical rule ids and wording.
"""
from __future__ import annotations

from typing import Any, Iterable, Mapping, Sequence

from repro.core.graphs import ALL_TO_ALL, POINTWISE, JobGraph
from repro.core.placement import MODULO, WorkerPool
from repro.core.routing import NUM_KEY_RANGES

from .diagnostics import (
    Diagnostic,
    ERROR,
    GraphValidationError,
    diag,
    raise_on_error,
)

__all__ = ["check_job", "run_preflight", "GraphValidationError"]


def check_job(
    jg: JobGraph,
    constraints: Sequence[Any] = (),
    *,
    pool: WorkerPool | None = None,
    num_workers: int | None = None,
    num_key_ranges: int | None = None,
    initial_buffer_bytes: int | None = None,
    max_buffer_lifetime_ms: float | None = None,
    policy: Any = None,
    sources: Mapping[str, Any] | None = None,
    net: Any = None,
    proactive: Any = None,
    measurement_interval_ms: float | None = None,
) -> list[Diagnostic]:
    """Validate one job description; returns every finding (never raises)."""
    out: list[Diagnostic] = []
    out.extend(_check_structure(jg))
    out.extend(_check_constraints(jg, constraints))
    out.extend(_check_routing(jg, constraints, num_key_ranges))
    if pool is not None:
        out.extend(_check_placement(jg, pool))
    out.extend(_check_chaining(jg, constraints))
    out.extend(_check_buffers(initial_buffer_bytes, max_buffer_lifetime_ms,
                              policy))
    if proactive is not None:
        out.extend(_check_estimation(proactive, measurement_interval_ms))
    # semantic layer: static QoS feasibility (lazy import — feasibility
    # reuses helpers from this module, so the import must not be cyclic at
    # module load time)
    from . import feasibility as _feasibility
    out.extend(_feasibility.check_feasibility(
        jg, constraints, sources=sources, net=net, num_workers=num_workers,
        num_key_ranges=num_key_ranges, policy=policy,
        max_buffer_lifetime_ms=max_buffer_lifetime_ms))
    return out


#: process-wide count of WARN diagnostics returned by ``run_preflight`` —
#: benchmark harnesses read the delta around a scenario to surface the
#: pre-flight WARN count per recorded row without touching the executors.
preflight_warn_count = 0


def run_preflight(
    jg: JobGraph,
    constraints: Sequence[Any] = (),
    **kwargs: Any,
) -> list[Diagnostic]:
    """``check_job`` with ERROR-fails-fast semantics: raises
    ``GraphValidationError`` on any ERROR, returns the WARNs otherwise."""
    global preflight_warn_count
    diags = check_job(jg, constraints, **kwargs)
    raise_on_error(diags)
    preflight_warn_count += sum(1 for d in diags if d.severity != ERROR)
    return diags


# ---------------------------------------------------------------------------
# Structural rules (NS-G***)
# ---------------------------------------------------------------------------


def _check_structure(jg: JobGraph) -> list[Diagnostic]:
    out: list[Diagnostic] = []
    loc = f"job graph {jg.name!r}"
    # NS-G002: dangling edges (endpoints must exist).  JobGraph.add_edge
    # enforces this, but hand-mutated graphs reach the executors too.
    known = set(jg.vertices)
    seen_pairs: set[tuple[str, str]] = set()
    for e in jg.edges:
        for name in (e.src, e.dst):
            if name not in known:
                out.append(diag("NS-G002", f"job edge {e.src}->{e.dst}",
                                f"unknown job vertex {name!r}"))
        # NS-G005: duplicate channel group
        if (e.src, e.dst) in seen_pairs:
            out.append(diag("NS-G005", f"job edge {e.src}->{e.dst}",
                            f"duplicate job edge {e.src}->{e.dst}"))
        seen_pairs.add((e.src, e.dst))
        # NS-G003: POINTWISE parallelism (add_edge enforces; re-check for
        # graphs whose vertices were swapped after wiring)
        if (e.pattern == POINTWISE and e.src in known and e.dst in known
                and jg.vertices[e.src].parallelism
                != jg.vertices[e.dst].parallelism):
            out.append(diag(
                "NS-G003", f"job edge {e.src}->{e.dst}",
                f"POINTWISE edge requires equal parallelism "
                f"({e.src} x{jg.vertices[e.src].parallelism} vs "
                f"{e.dst} x{jg.vertices[e.dst].parallelism})"))
    # NS-G004: cycle (Kahn without raising)
    indeg = {n: 0 for n in jg.vertices}
    for e in jg.edges:
        if e.dst in indeg:
            indeg[e.dst] += 1
    stack = [n for n, d in indeg.items() if d == 0]
    seen = 0
    while stack:
        n = stack.pop()
        seen += 1
        for e in jg.out_edges(n):
            if e.dst not in indeg:
                continue
            indeg[e.dst] -= 1
            if indeg[e.dst] == 0:
                stack.append(e.dst)
    if seen != len(jg.vertices):
        out.append(diag("NS-G004", loc, "job graph contains a cycle"))
    # NS-G006/NS-G007: reachability from the in-degree-0 frontier
    reachable = set(jg.sources())
    frontier = list(reachable)
    while frontier:
        n = frontier.pop()
        for e in jg.out_edges(n):
            if e.dst in known and e.dst not in reachable:
                reachable.add(e.dst)
                frontier.append(e.dst)
    for name, jv in jg.vertices.items():
        if name in reachable:
            continue
        if jv.is_sink or not jg.out_edges(name):
            out.append(diag("NS-G006", f"job vertex {name!r}",
                            f"sink {name!r} is unreachable from every "
                            f"source"))
        else:
            out.append(diag("NS-G007", f"job vertex {name!r}",
                            f"{name!r} is unreachable from every source"))
    return out


# ---------------------------------------------------------------------------
# Constraint rules (NS-C***).  Latency and throughput constraints are
# duck-typed (sequence vs. job_vertex attribute) so this module needs no
# import from core/constraints or core/elastic.
# ---------------------------------------------------------------------------


def _split(constraints: Sequence[Any]) -> tuple[list[Any], list[Any]]:
    latency = [c for c in constraints if hasattr(c, "sequence")]
    throughput = [c for c in constraints
                  if hasattr(c, "job_vertex") and not hasattr(c, "sequence")]
    return latency, throughput


def _check_constraints(jg: JobGraph,
                       constraints: Sequence[Any]) -> list[Diagnostic]:
    out: list[Diagnostic] = []
    edges = {(e.src, e.dst) for e in jg.edges}
    latency, throughput = _split(constraints)
    for c in latency:
        loc = f"constraint {getattr(c, 'name', '?')!r}"
        for v in c.sequence.vertices():
            if v not in jg.vertices:
                out.append(diag("NS-C001", loc,
                                f"sequence references unknown job vertex "
                                f"{v!r}"))
        for (s, d) in c.sequence.edges():
            if s not in jg.vertices or d not in jg.vertices:
                out.append(diag("NS-C001", loc,
                                f"sequence references unknown job vertex "
                                f"in edge {s}->{d}"))
            elif (s, d) not in edges:
                out.append(diag("NS-C002", loc,
                                f"sequence edge {s}->{d} does not exist in "
                                f"the job graph"))
        if not c.latency_limit_ms > 0:
            out.append(diag("NS-C003", loc,
                            f"latency_limit_ms={c.latency_limit_ms!r} "
                            f"must be > 0"))
        if not c.window_ms > 0:
            out.append(diag("NS-C003", loc,
                            f"window_ms={c.window_ms!r} must be > 0"))
    for c in throughput:
        loc = f"throughput constraint {getattr(c, 'name', '?')!r}"
        v = c.job_vertex
        if v not in jg.vertices:
            out.append(diag("NS-C004", loc,
                            f"unknown job vertex {v!r}"))
            continue
        if not c.window_ms > 0:
            out.append(diag("NS-C003", loc,
                            f"window_ms={c.window_ms!r} must be > 0"))
        jv = jg.vertices[v]
        if jv.is_source or not jg.in_edges(v):
            out.append(diag("NS-C005", loc,
                            f"{v!r} is a source; the scale-out "
                            f"countermeasure refuses source vertices"))
        elif any(e.pattern != ALL_TO_ALL
                 for e in jg.in_edges(v) + jg.out_edges(v)):
            out.append(diag("NS-C005", loc,
                            f"{v!r} has a non-ALL_TO_ALL edge; "
                            f"grow/shrink requires ALL_TO_ALL wiring"))
    return out


# ---------------------------------------------------------------------------
# Routing rules (NS-R***): generalizes the PR-5 m-vs-num_key_ranges
# fail-fast to a uniform, pre-expansion diagnostic.
# ---------------------------------------------------------------------------


def _check_routing(jg: JobGraph, constraints: Sequence[Any],
                   num_key_ranges: int | None) -> list[Diagnostic]:
    out: list[Diagnostic] = []
    effective = NUM_KEY_RANGES if num_key_ranges is None else num_key_ranges
    if num_key_ranges is not None and (
            num_key_ranges < 1 or num_key_ranges & (num_key_ranges - 1)):
        out.append(diag("NS-R003", "num_key_ranges",
                        f"num_key_ranges={num_key_ranges} is not a power "
                        f"of two; the masked table[key & mask] fast path "
                        f"is disabled"))
    for name, jv in jg.vertices.items():
        if jv.parallelism > effective:
            out.append(diag(
                "NS-R001", f"job vertex {name!r}",
                f"parallelism {jv.parallelism} exceeds the {effective} "
                f"addressable key ranges: owners >= {effective} would "
                f"never be addressed; pass num_key_ranges >= "
                f"{jv.parallelism} (a power of two) to RuntimeGraph / "
                f"StreamSimulator / StreamEngine"))
    _, throughput = _split(constraints)
    for c in throughput:
        mp = getattr(c, "max_parallelism", None)
        if (mp is not None and c.job_vertex in jg.vertices
                and mp > effective
                and jg.vertices[c.job_vertex].parallelism <= effective):
            out.append(diag(
                "NS-R002", f"throughput constraint "
                f"{getattr(c, 'name', '?')!r}",
                f"max_parallelism {mp} for {c.job_vertex!r} exceeds the "
                f"{effective} addressable key ranges"))
    return out


# ---------------------------------------------------------------------------
# Placement rules (NS-P***)
# ---------------------------------------------------------------------------


def _check_placement(jg: JobGraph, pool: WorkerPool) -> list[Diagnostic]:
    out: list[Diagnostic] = []
    affinity: Mapping[str, frozenset[str]] = pool.affinity
    for jv_name in sorted(affinity):
        if jv_name not in jg.vertices:
            continue  # affinity for a vertex of another job: inert
        need = affinity[jv_name]
        if not need:
            continue
        if pool.policy == MODULO:
            out.append(diag("NS-P002", f"job vertex {jv_name!r}",
                            f"affinity {sorted(need)} is ignored by the "
                            f"modulo placement policy"))
            continue
        with pool._lock:
            match = any(need <= w.tags for w in pool.workers.values())
            capped = (pool.max_workers is not None
                      and len(pool.workers) >= pool.max_workers)
        if not match and capped:
            out.append(diag(
                "NS-P001", f"job vertex {jv_name!r}",
                f"no worker carries affinity tags {sorted(need)} and the "
                f"pool is capped at max_workers={pool.max_workers}"))
    if pool.policy != MODULO and pool.max_workers is not None:
        capacity = (pool.slots_per_worker or 0) * pool.max_workers
        tasks = sum(v.parallelism for v in jg.vertices.values())
        if capacity and tasks > capacity:
            out.append(diag(
                "NS-P003", f"job graph {jg.name!r}",
                f"{tasks} initial tasks exceed the capped pool capacity "
                f"of {capacity} slots ({pool.max_workers} x "
                f"{pool.slots_per_worker})"))
    return out


# ---------------------------------------------------------------------------
# Chain-eligibility pre-computation (NS-H001, §3.5.2) — job-level
# approximation of the five chaining conditions evaluated by
# core/chaining.py at decision time.
# ---------------------------------------------------------------------------


def _runtime_out_channels(jg: JobGraph, name: str) -> int:
    """Out-channels of one task of ``name`` (per-pattern fan-out)."""
    return sum(1 if e.pattern == POINTWISE else jg.vertices[e.dst].parallelism
               for e in jg.out_edges(name) if e.dst in jg.vertices)


def _runtime_in_channels(jg: JobGraph, name: str) -> int:
    return sum(1 if e.pattern == POINTWISE else jg.vertices[e.src].parallelism
               for e in jg.in_edges(name) if e.src in jg.vertices)


def _pair_chainable(jg: JobGraph, a: str, b: str) -> bool:
    """Could tasks of adjacent stages ``a -> b`` *ever* fuse?  Conditions
    §3.5.2 (4) and (5) are static: the head may keep extra in-channels and
    the tail extra out-channels, but the a->b hand-over itself must be the
    head's only out-channel and the tail's only in-channel, and neither
    stage may carry the chainable=False / stateful veto.  Worker
    co-location and CPU budget (conditions 1-3) are runtime facts — the
    pre-flight pass stays optimistic about them."""
    va, vb = jg.vertices[a], jg.vertices[b]
    if not va.chainable or not vb.chainable or va.stateful or vb.stateful:
        return False
    return (_runtime_out_channels(jg, a) == 1
            and _runtime_in_channels(jg, b) == 1)


def _adjacent_task_pairs(seq: Any) -> list[tuple[str, str]]:
    """Candidate §3.5.2 chain pairs of a (duck-typed) sequence — prefers
    the JobSequence helper, falls back to zipping the task elements."""
    fn = getattr(seq, "adjacent_task_pairs", None)
    if fn is not None:
        return list(fn())
    ts = list(seq.vertices())
    return list(zip(ts, ts[1:]))


def _check_chaining(jg: JobGraph,
                    constraints: Sequence[Any]) -> list[Diagnostic]:
    out: list[Diagnostic] = []
    edges = {(e.src, e.dst) for e in jg.edges}
    latency, _ = _split(constraints)
    for c in latency:
        tasks = [v for v in c.sequence.vertices() if v in jg.vertices]
        if len(tasks) < 2:
            continue  # chaining needs >= 2 task elements: inapplicable
        pairs = [(a, b) for a, b in _adjacent_task_pairs(c.sequence)
                 if a in jg.vertices and b in jg.vertices
                 and (a, b) in edges]
        if pairs and not any(_pair_chainable(jg, a, b) for a, b in pairs):
            out.append(diag(
                "NS-H001", f"constraint {getattr(c, 'name', '?')!r}",
                f"no adjacent task pair of {tasks} can ever satisfy the "
                f"§3.5.2 chaining conditions — the chaining "
                f"countermeasure will never fire for this constraint"))
    return out


# ---------------------------------------------------------------------------
# Predictive-QoS estimator config (NS-E***): rejects nonsensical
# ProactiveConfig values before either backend builds its runtime graph.
# Duck-typed like the constraint checks so a hand-rolled config object
# with the same fields validates identically.
# ---------------------------------------------------------------------------


def _check_estimation(proactive: Any,
                      measurement_interval_ms: float | None
                      ) -> list[Diagnostic]:
    out: list[Diagnostic] = []
    loc = "proactive config"
    horizon = getattr(proactive, "horizon_ms", None)
    if horizon is not None and not horizon > 0:
        out.append(diag("NS-E001", loc,
                        f"horizon_ms={horizon!r} must be > 0"))
    period = getattr(proactive, "update_period_ms", None)
    if period is not None and not period > 0:
        out.append(diag("NS-E002", loc,
                        f"update_period_ms={period!r} must be > 0 "
                        f"(None updates on every control tick)"))
    if (horizon is not None and horizon > 0
            and measurement_interval_ms is not None
            and measurement_interval_ms > 0
            and horizon < measurement_interval_ms / 4.0):
        out.append(diag(
            "NS-E003", loc,
            f"horizon_ms={horizon!r} is shorter than the control tick "
            f"(measurement_interval_ms / 4 = "
            f"{measurement_interval_ms / 4.0:g}ms); the forecast cannot "
            f"see past the next reactive check"))
    kind = getattr(proactive, "estimator", None)
    if kind is not None:
        from repro.core.estimation import ESTIMATOR_KINDS
        if kind not in ESTIMATOR_KINDS:
            out.append(diag(
                "NS-E004", loc,
                f"unknown estimator kind {kind!r}; registered kinds: "
                f"{sorted(ESTIMATOR_KINDS)}"))
    return out


# ---------------------------------------------------------------------------
# Buffer-bound sanity (NS-B***, §3.5.1)
# ---------------------------------------------------------------------------


def _check_buffers(initial_buffer_bytes: int | None,
                   max_buffer_lifetime_ms: float | None,
                   policy: Any) -> list[Diagnostic]:
    out: list[Diagnostic] = []
    if initial_buffer_bytes is not None and initial_buffer_bytes < 1:
        out.append(diag("NS-B001", "initial_buffer_bytes",
                        f"initial_buffer_bytes={initial_buffer_bytes} "
                        f"must be >= 1"))
    if max_buffer_lifetime_ms is not None and not max_buffer_lifetime_ms > 0:
        out.append(diag("NS-B002", "max_buffer_lifetime_ms",
                        f"max_buffer_lifetime_ms={max_buffer_lifetime_ms!r} "
                        f"must be > 0 (use None to disable flush sweeps)"))
    if policy is not None:
        loc = "buffer sizing policy"
        if policy.eps_bytes < 1 or policy.omega_bytes < policy.eps_bytes:
            out.append(diag("NS-B001", loc,
                            f"need 1 <= eps_bytes <= omega_bytes, got "
                            f"eps={policy.eps_bytes} "
                            f"omega={policy.omega_bytes}"))
        if not 0.0 < policy.r < 1.0:
            out.append(diag("NS-B001", loc,
                            f"shrink factor r={policy.r!r} must be in "
                            f"(0, 1) (Eq. 2 decays per ms)"))
        if not policy.s > 1.0:
            out.append(diag("NS-B001", loc,
                            f"growth factor s={policy.s!r} must be > 1 "
                            f"(Eq. 3 must grow)"))
        if (initial_buffer_bytes is not None
                and initial_buffer_bytes > policy.omega_bytes):
            out.append(diag("NS-B003", "initial_buffer_bytes",
                            f"initial_buffer_bytes={initial_buffer_bytes} "
                            f"exceeds the policy ceiling "
                            f"omega_bytes={policy.omega_bytes}"))
    return out
