"""Static/dynamic analysis layer: validation, lint, race/deadlock, sanitize.

Five layers (see docs/analysis.md for the rule catalog):

* ``analysis.graph_check`` — pre-flight job-graph/QoS validator, run by
  both execution backends at construction (``preflight=False`` opts out).
* ``analysis.feasibility`` — static QoS-feasibility pass (NS-F00x): the
  §3 latency/throughput model evaluated over the admissible configuration
  lattice, dispatched from ``graph_check.check_job``.
* ``analysis.lint`` — repo-specific AST rules (``scripts/lint.py``).
* ``analysis.race`` — ``REPRO_RACE_CHECK=1`` lockset race detector plus
  lock-order deadlock detection for the threaded engine.
* ``analysis.sanitize`` — ``REPRO_SANITIZE=1`` runtime invariant
  sanitizer (channel conservation, event-time monotonicity, key-ownership
  exclusivity, buffer fill accounting).

This package init stays import-light on purpose: ``core/routing.py`` and
``core/buffers.py`` import ``analysis.race`` / ``analysis.sanitize`` at
*their* import time, so nothing here may import ``repro.core``
(``graph_check`` and ``feasibility`` do, and are therefore loaded lazily).
"""
from __future__ import annotations

from typing import Any

from .diagnostics import (  # noqa: F401
    Diagnostic,
    ERROR,
    GraphValidationError,
    REGISTRY,
    Rule,
    WARN,
    diag,
    register,
)
from .race import RACE_CHECK, DeadlockReport, RaceReport  # noqa: F401
from .sanitize import SANITIZE  # noqa: F401

__all__ = [
    "Diagnostic", "ERROR", "WARN", "Rule", "REGISTRY", "diag", "register",
    "GraphValidationError", "RACE_CHECK", "RaceReport", "DeadlockReport",
    "SANITIZE", "check_job", "run_preflight", "check_feasibility",
]


def __getattr__(name: str) -> Any:
    # lazy: these import repro.core (cycle with core's import of us)
    if name in ("check_job", "run_preflight"):
        from . import graph_check
        return getattr(graph_check, name)
    if name == "check_feasibility":
        from . import feasibility
        return feasibility.check_feasibility
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
