"""Static/dynamic analysis layer: pre-flight validation, lint, race check.

Three layers (see docs/analysis.md for the rule catalog):

* ``analysis.graph_check`` — pre-flight job-graph/QoS validator, run by
  both execution backends at construction (``preflight=False`` opts out).
* ``analysis.lint`` — repo-specific AST rules (``scripts/lint.py``).
* ``analysis.race`` — ``REPRO_RACE_CHECK=1`` lockset race detector for
  the threaded engine.

This package init stays import-light on purpose: ``core/routing.py`` and
``core/buffers.py`` import ``analysis.race`` at *their* import time, so
nothing here may import ``repro.core`` (``graph_check`` does, and is
therefore loaded lazily).
"""
from __future__ import annotations

from typing import Any

from .diagnostics import (  # noqa: F401
    Diagnostic,
    ERROR,
    GraphValidationError,
    REGISTRY,
    Rule,
    WARN,
    diag,
    register,
)
from .race import RACE_CHECK, RaceReport  # noqa: F401

__all__ = [
    "Diagnostic", "ERROR", "WARN", "Rule", "REGISTRY", "diag", "register",
    "GraphValidationError", "RACE_CHECK", "RaceReport",
    "check_job", "run_preflight",
]


def __getattr__(name: str) -> Any:
    # lazy: graph_check imports repro.core (cycle with core's import of us)
    if name in ("check_job", "run_preflight"):
        from . import graph_check
        return getattr(graph_check, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
