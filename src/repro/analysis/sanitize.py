"""Runtime invariant sanitizer (``REPRO_SANITIZE=1``).

Where the race detector (analysis/race.py) checks lock *discipline*, this
layer checks data-plane *invariants* — the conservation and ordering facts
both backends promise but only assert indirectly through end-to-end
benchmarks:

* **NS-S001 — channel conservation**: per output buffer, a ledger of
  appended/taken items and bytes; at every simulator control tick (and at
  engine ``stop()``) emitted must equal shipped + still-buffered, and a
  channel may never deliver more items than were shipped (in-flight count
  stays >= 0).  Nothing is ever dropped by either backend, so the paper's
  "emitted = delivered + in-flight + dropped" closes with dropped = 0.
* **NS-S002 — event-time monotonicity**: the simulator core dispatches
  heap events in non-decreasing time order in *both* event modes (batched
  runs retire early but their heap boundaries still advance) — the sim
  clock's ``_now`` is re-classed into a checked property, so every
  ``clock._now = t`` store in the run loop and every ``advance_to`` is
  verified.
* **NS-S003 — key-ownership exclusivity**: after every keyed-state
  migration (pause-drain-install-swap, core/elastic.py), each key of a
  stateful stage must reside in exactly the store of its routed owner —
  no duplicates across stores, no strays on non-owners.
* **NS-S004 — OutputBuffer fill accounting**: ``used_bytes`` must equal
  the ledger's appended-minus-taken bytes after every operation, ``take``
  must reset cleanly, and ``append_run`` callers must honor the
  ``room_for`` contract (at most the final item of a run crosses
  capacity).
* **NS-S005 — post-recovery key ownership**: after every crash-recovery
  cycle (``recover_worker``, core/elastic.py) the same exclusivity scan as
  NS-S003 runs over every stateful stage the crash touched — the
  checkpoint restore + replay must never leave a key served by two owners.
  Buffers destroyed by an *injected* crash are exempted from the NS-S001
  zero-drop ledger via ``note_crashed`` (their losses are accounted per
  key by the fault machinery instead).

Violations become structured ``Diagnostic`` records (shared registry,
analysis/diagnostics.py) with the capture-site stack in ``detail``,
reported once per call site; they are collected, never raised mid-run —
inspect ``CHECKER.reports`` or call ``CHECKER.assert_clean()`` after the
scenario (the sanitizer arm of scripts/ci.sh does exactly that over the
golden scenarios).

Zero-cost when disabled, exactly like race.py: the flag is read once at
import, and with it unset the ``instrument_*`` hooks at the bottom of the
core modules never run — the classes keep their original bytecode (pinned
by tests/test_analysis_sanitize.py).  Stdlib-only and free of
``repro.core`` imports: core modules import *us* and pass their classes in.
"""
from __future__ import annotations

import os
import sys
import threading
import traceback
from typing import Any

from .diagnostics import Diagnostic, diag, register

#: read once at import: instrumentation is selected here and never again.
SANITIZE: bool = os.environ.get("REPRO_SANITIZE", "") == "1"

register("NS-S001", "ERROR", "channel conservation violated",
         "every item appended to a channel's output buffer must be shipped "
         "or still buffered, and no channel may deliver more than was "
         "shipped — a mismatch means the backend lost or duplicated items")
register("NS-S002", "ERROR", "simulated event time went backwards",
         "the event core must dispatch heap events in non-decreasing time "
         "order (both exact and batched modes); a backwards store into the "
         "sim clock corrupts every latency measurement after it")
register("NS-S003", "ERROR", "key ownership not exclusive after migration",
         "the pause-drain-install-swap protocol must leave every key of a "
         "stateful stage in exactly its routed owner's store (§ keyed-state "
         "migration); a duplicate or stray key double-counts aggregates")
register("NS-S004", "ERROR", "output-buffer fill accounting violated",
         "used_bytes must track appended-minus-taken bytes exactly and "
         "append_run callers must pre-split runs with room_for (at most "
         "the final item may cross capacity)")
register("NS-S005", "ERROR", "key ownership not exclusive after recovery",
         "crash recovery (recover_worker) must leave every key of every "
         "affected stateful stage in exactly its routed owner's store — a "
         "key served by two owners double-counts aggregates after the "
         "checkpoint restore + replay (docs/robustness.md)")


def _capture_stack(skip: int = 2) -> str:
    frame = sys._getframe(skip)
    summary = traceback.StackSummary.extract(
        traceback.walk_stack(frame), limit=10, lookup_lines=False)
    summary.reverse()
    return "".join(summary.format())


def _site(skip: int = 2) -> str:
    """file:line of the instrumented call site (dedup key)."""
    f = sys._getframe(skip)
    return f"{f.f_code.co_filename}:{f.f_lineno}"


class InvariantChecker:
    """Central sink for sanitizer findings + the per-object ledgers.

    Ledger mutation is meta-locked only on first touch of an object; the
    per-object dict is then updated by whatever thread legitimately owns
    the object at that moment (the race detector, not this layer, is the
    authority on *that* discipline)."""

    def __init__(self) -> None:
        self._meta = threading.Lock()
        #: id(obj) -> (obj, ledger) — the instance reference pins ``id``.
        self._ledgers: dict[int, tuple[Any, dict[str, int]]] = {}
        #: buffers of channels that were ever chained: chained hand-over
        #: delivers without shipping, so their delivered<=shipped check is
        #: inapplicable
        self._ever_chained: set[int] = set()
        #: buffers hit by an injected crash (core/faults.py): their contents
        #: were dropped BY DESIGN with explicit per-key drop accounting in
        #: the executor, so the zero-drop conservation ledger is
        #: inapplicable to them (and only to them)
        self._crashed_buffers: set[int] = set()
        self._sites: set[tuple[str, str]] = set()
        #: _SimTask.enqueue nesting depth (the sim core is single-threaded):
        #: re-homed items (key-ownership forwarding, scale-in stragglers)
        #: arrive via nested enqueue calls on the same channel id and must
        #: not count as a second delivery
        self._enqueue_depth = 0
        self.reports: list[Diagnostic] = []

    def ledger(self, obj: Any) -> dict[str, int]:
        entry = self._ledgers.get(id(obj))
        if entry is None or entry[0] is not obj:
            with self._meta:
                entry = self._ledgers.get(id(obj))
                if entry is None or entry[0] is not obj:
                    entry = (obj, {"items_in": 0, "items_out": 0,
                                   "bytes_in": 0, "bytes_out": 0,
                                   "delivered": 0})
                    self._ledgers[id(obj)] = entry
        return entry[1]

    def report(self, rule_id: str, location: str, message: str,
               skip: int = 3) -> None:
        site = (rule_id, _site(skip))
        with self._meta:
            if site in self._sites:
                return  # once per capture site
            self._sites.add(site)
            d = diag(rule_id, location, message)
            self.reports.append(Diagnostic(
                d.rule, d.severity, d.location, d.message, d.hint,
                detail="capture site:\n" + _capture_stack(skip)))

    def note_crashed(self, buf: Any) -> None:
        """Exempt a buffer whose contents an injected crash destroyed from
        the zero-drop conservation sweeps (the executor accounts the drops
        per key instead)."""
        with self._meta:
            self._crashed_buffers.add(id(buf))

    def clear(self) -> None:
        with self._meta:
            self._ledgers.clear()
            self._ever_chained.clear()
            self._crashed_buffers.clear()
            self._sites.clear()
            self.reports = []

    def assert_clean(self) -> None:
        if self.reports:
            raise AssertionError(
                f"{len(self.reports)} sanitizer violation(s):\n\n"
                + "\n\n".join(d.format() for d in self.reports))


#: the process-wide checker; None when the sanitizer is disabled.
CHECKER: InvariantChecker | None = InvariantChecker() if SANITIZE else None


def _checker() -> InvariantChecker:
    assert CHECKER is not None
    return CHECKER


# ---------------------------------------------------------------------------
# NS-S004 / NS-S001 — OutputBuffer ledgers (shared by both backends)
# ---------------------------------------------------------------------------


def _check_buffer(buf: Any, led: dict[str, int], where: str,
                  skip: int = 4) -> None:
    ck = _checker()
    if len(buf.items) != led["items_in"] - led["items_out"]:
        ck.report(
            "NS-S004", f"OutputBuffer {buf.channel_id!r}",
            f"{where}: buffer holds {len(buf.items)} items but the ledger "
            f"says {led['items_in']} appended - {led['items_out']} taken",
            skip=skip)
    elif buf.used_bytes != led["bytes_in"] - led["bytes_out"]:
        ck.report(
            "NS-S004", f"OutputBuffer {buf.channel_id!r}",
            f"{where}: used_bytes={buf.used_bytes} but the ledger says "
            f"{led['bytes_in']} appended - {led['bytes_out']} taken bytes",
            skip=skip)


def instrument_output_buffer(cls: type) -> None:
    """Maintain the append/take ledger and verify fill accounting after
    every operation.  The ledger doubles as the channel-conservation
    baseline the control-tick sweep (``instrument_simulator``) and engine
    ``stop()`` sweep check against."""
    orig_append = cls.append
    orig_append_run = cls.append_run
    orig_take = cls.take

    def append(self: Any, item: Any, size_bytes: int, now_ms: float) -> bool:
        led = _checker().ledger(self)
        full = orig_append(self, item, size_bytes, now_ms)
        led["items_in"] += 1
        led["bytes_in"] += size_bytes
        _check_buffer(self, led, "append")
        return full

    def append_run(self: Any, items: list, size_bytes_each: int,
                   opened_at_ms: float) -> bool:
        led = _checker().ledger(self)
        if (len(items) > 1 and size_bytes_each > 0
                and self.used_bytes + size_bytes_each * (len(items) - 1)
                >= self.capacity_bytes):
            _checker().report(
                "NS-S004", f"OutputBuffer {self.channel_id!r}",
                f"append_run of {len(items)} x {size_bytes_each}B onto "
                f"{self.used_bytes}/{self.capacity_bytes}B crosses capacity "
                f"before the final item — the caller skipped the room_for "
                f"pre-split")
        full = orig_append_run(self, items, size_bytes_each, opened_at_ms)
        led["items_in"] += len(items)
        led["bytes_in"] += size_bytes_each * len(items)
        _check_buffer(self, led, "append_run")
        return full

    def take(self: Any, now_ms: float) -> tuple:
        led = _checker().ledger(self)
        out, nbytes, lifetime = orig_take(self, now_ms)
        led["items_out"] += len(out)
        led["bytes_out"] += nbytes
        _check_buffer(self, led, "take")
        return out, nbytes, lifetime

    for fn in (append, append_run, take):
        fn.__qualname__ = f"{cls.__name__}.{fn.__name__}"
    cls.append = append
    cls.append_run = append_run
    cls.take = take


# ---------------------------------------------------------------------------
# NS-S002 / NS-S001 — simulator core (checked clock + control-tick sweep)
# ---------------------------------------------------------------------------


def _make_checked_clock(clock_cls: type) -> type:
    """Subclass whose ``_now`` is a checked property: the run loop's direct
    ``clock._now = t`` stores (and ``advance_to``) are verified to never go
    backwards.  Instances are re-classed in place after construction, so
    every holder of the clock reference sees the checked behavior."""

    class _CheckedSimClock(clock_cls):  # type: ignore[misc, valid-type]
        @property
        def _now(self) -> float:
            return self.__dict__["_sanitize_now"]

        @_now.setter
        def _now(self, t: float) -> None:
            old = self.__dict__.get("_sanitize_now")
            if old is not None and t < old - 1e-9:
                _checker().report(
                    "NS-S002", "SimClock",
                    f"event time went backwards: {t:.6f} < {old:.6f}")
            self.__dict__["_sanitize_now"] = t

    _CheckedSimClock.__name__ = f"Checked{clock_cls.__name__}"
    return _CheckedSimClock


def _sweep_channels(sim: Any) -> None:
    """NS-S001 at a control tick: per channel, emitted items == shipped +
    still-buffered, and (never-chained channels) delivered <= shipped."""
    ck = _checker()
    for ch in sim.channels.values():
        if ch.chained:
            ck._ever_chained.add(id(ch.buffer))
    for cid, ch in sim.channels.items():
        if id(ch.buffer) in ck._crashed_buffers:
            continue  # crash-dropped by design; drops accounted per key
        led = ck.ledger(ch.buffer)
        buffered = len(ch.buffer.items)
        if led["items_in"] - led["items_out"] != buffered:
            ck.report(
                "NS-S001", f"channel {cid!r}",
                f"conservation broken at control tick: {led['items_in']} "
                f"emitted != {led['items_out']} shipped + {buffered} "
                f"buffered")
        elif (led["delivered"] > led["items_out"]
                and id(ch.buffer) not in ck._ever_chained):
            ck.report(
                "NS-S001", f"channel {cid!r}",
                f"delivered {led['delivered']} items but only "
                f"{led['items_out']} were ever shipped (in-flight count "
                f"went negative)")


def instrument_simulator(sim_cls: type, task_cls: type,
                         clock_cls: type) -> None:
    checked_clock = _make_checked_clock(clock_cls)
    orig_init = sim_cls.__init__
    orig_tick = sim_cls._control_tick
    orig_chain = sim_cls._apply_chain
    orig_enqueue = task_cls.enqueue

    def __init__(self: Any, *args: Any, **kwargs: Any) -> None:
        orig_init(self, *args, **kwargs)
        clk = self.clock
        now = clk.__dict__.pop("_now", 0.0)
        clk.__class__ = checked_clock
        clk.__dict__["_sanitize_now"] = now

    def _control_tick(self: Any) -> None:
        _sweep_channels(self)
        orig_tick(self)

    def _apply_chain(self: Any, req: Any) -> None:
        orig_chain(self, req)
        # chained hand-over enqueues without shipping: retire those
        # channels' delivered<=shipped check for good
        ck = _checker()
        for cid in self.chained_channels:
            ch = self.channels.get(cid)
            if ch is not None:
                ck._ever_chained.add(id(ch.buffer))

    def enqueue(self: Any, items: list, channel_id: str,
                now: float | None = None) -> None:
        ck = _checker()
        if ck._enqueue_depth == 0:
            ch = self.sim.channels.get(channel_id)
            if ch is not None:
                ck.ledger(ch.buffer)["delivered"] += len(items)
        ck._enqueue_depth += 1
        try:
            orig_enqueue(self, items, channel_id, now)
        finally:
            ck._enqueue_depth -= 1

    __init__.__qualname__ = f"{sim_cls.__name__}.__init__"
    _control_tick.__qualname__ = f"{sim_cls.__name__}._control_tick"
    _apply_chain.__qualname__ = f"{sim_cls.__name__}._apply_chain"
    enqueue.__qualname__ = f"{task_cls.__name__}.enqueue"
    sim_cls.__init__ = __init__
    sim_cls._control_tick = _control_tick
    sim_cls._apply_chain = _apply_chain
    task_cls.enqueue = enqueue


# ---------------------------------------------------------------------------
# NS-S001 — engine stop() sweep
# ---------------------------------------------------------------------------


def instrument_engine(engine_cls: type) -> None:
    """Verify every sender's buffer ledger once the engine has drained —
    the engine's per-operation accounting is already covered by the
    OutputBuffer wrappers; this closes the run with a whole-channel check."""
    orig_stop = engine_cls.stop

    def stop(self: Any) -> Any:
        res = orig_stop(self)
        ck = _checker()
        for cid, s in self.senders.items():
            if id(s.buffer) in ck._crashed_buffers:
                continue  # crash-dropped by design (see note_crashed)
            _check_buffer(s.buffer, ck.ledger(s.buffer),
                          f"engine stop() sweep of {cid!r}", skip=3)
        return res

    stop.__qualname__ = f"{engine_cls.__name__}.stop"
    engine_cls.stop = stop


# ---------------------------------------------------------------------------
# NS-S003 — key-ownership exclusivity after migration
# ---------------------------------------------------------------------------


def _scan_group_ownership(rewirer: Any, job_vertex: str, rule_id: str,
                          where: str) -> None:
    """Shared NS-S003/NS-S005 scan: every key of a stateful stage must live
    in exactly the store of its routed owner."""
    jv = rewirer.jg.vertices.get(job_vertex)
    if jv is None or not jv.stateful:
        return
    ck = _checker()
    router = rewirer.rg.routers[job_vertex]
    seen: dict[Any, Any] = {}
    for v in rewirer.rg.tasks_of(job_vertex):
        store = rewirer._task_state(v)
        if store is None:
            continue
        for key in store.keys():
            owner = router.owner(key)
            if key in seen:
                ck.report(
                    rule_id, where,
                    f"key {key!r} present in both {seen[key]} and "
                    f"{v.id}", skip=4)
            elif owner != v.index:
                ck.report(
                    rule_id, where,
                    f"key {key!r} resides in {v.id} but the routing "
                    f"table owns it to subtask {owner}", skip=4)
            seen[key] = v.id


def instrument_rewirer(rewirer_cls: type) -> None:
    orig_migrate = rewirer_cls._migrate_keyed_state
    orig_recover = rewirer_cls.recover_worker

    def _migrate_keyed_state(self: Any, job_vertex: str, plan: Any) -> None:
        orig_migrate(self, job_vertex, plan)
        _scan_group_ownership(self, job_vertex, "NS-S003",
                              f"migration of {job_vertex!r}")

    def recover_worker(self: Any, dead: int, now: float) -> Any:
        ev = orig_recover(self, dead, now)
        # NS-S005: ownership exclusivity over every stage the crash touched
        for jv in sorted({v.job_vertex for v in ev.lost_vertices}):
            _scan_group_ownership(self, jv, "NS-S005",
                                  f"recovery of worker {dead} ({jv!r})")
        return ev

    _migrate_keyed_state.__qualname__ = \
        f"{rewirer_cls.__name__}._migrate_keyed_state"
    recover_worker.__qualname__ = f"{rewirer_cls.__name__}.recover_worker"
    rewirer_cls._migrate_keyed_state = _migrate_keyed_state
    rewirer_cls.recover_worker = recover_worker
