"""Structured diagnostics + the shared rule registry (analysis layer core).

Every analysis layer — the pre-flight graph validator
(analysis/graph_check.py), the AST lint pass (analysis/lint.py) and the
build-time checks inside ``core/graphs.py`` — reports problems as
``Diagnostic`` records: a stable rule id, a severity, a human location, a
message and a fix hint.  The registry below is the single catalog of rule
ids, so an error raised while *building* a job graph carries the same id
and wording as the same condition caught by the *pre-flight* pass, and
``docs/analysis.md`` can enumerate the catalog mechanically.

This module deliberately imports nothing from ``repro.core`` (it is the
bottom of the dependency stack: ``core/graphs.py`` imports it to raise
uniform build-time errors).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Sequence

#: severities — ERROR fails fast (CI, pre-flight), WARN is advisory.
ERROR = "ERROR"
WARN = "WARN"


@dataclass(frozen=True)
class Rule:
    """One registered rule: identity + default severity + fix hint."""

    id: str
    severity: str
    title: str
    hint: str = ""


@dataclass(frozen=True)
class Diagnostic:
    """One finding: rule id, severity, where, what, and how to fix it.

    ``detail`` carries optional multi-line evidence (the runtime sanitizer
    and deadlock detector attach capture-site stack traces here); it is
    rendered indented below the one-line summary.
    """

    rule: str
    severity: str
    location: str
    message: str
    hint: str = ""
    detail: str = ""

    def format(self) -> str:
        s = f"[{self.rule}] {self.severity} {self.location}: {self.message}"
        if self.hint:
            s += f" | hint: {self.hint}"
        if self.detail:
            s += "\n" + "\n".join(
                "    " + line for line in self.detail.rstrip().splitlines())
        return s


#: rule id -> Rule.  Populated by ``register`` below; graph/constraint rules
#: live here (core/graphs.py raises through them), lint rules are registered
#: by analysis/lint.py on import.
REGISTRY: dict[str, Rule] = {}


def register(rule_id: str, severity: str, title: str, hint: str = "") -> Rule:
    if severity not in (ERROR, WARN):
        raise ValueError(f"bad severity {severity!r} for rule {rule_id}")
    if rule_id in REGISTRY:
        raise ValueError(f"duplicate rule id {rule_id!r}")
    rule = Rule(rule_id, severity, title, hint)
    REGISTRY[rule_id] = rule
    return rule


def diag(rule_id: str, location: str, message: str,
         hint: str | None = None, severity: str | None = None) -> Diagnostic:
    """Build a Diagnostic for a registered rule (severity/hint default to
    the registry's)."""
    rule = REGISTRY[rule_id]
    return Diagnostic(rule_id, severity or rule.severity, location, message,
                      rule.hint if hint is None else hint)


class GraphValidationError(ValueError):
    """Raised when validation finds at least one ERROR diagnostic.

    Subclasses ValueError so call sites that historically caught the ad-hoc
    ``raise ValueError`` graph checks keep working unchanged.
    """

    def __init__(self, diagnostics: Iterable[Diagnostic]) -> None:
        self.diagnostics: tuple[Diagnostic, ...] = tuple(diagnostics)
        errors = [d for d in self.diagnostics if d.severity == ERROR]
        if len(errors) == 1 and len(self.diagnostics) == 1:
            msg = errors[0].format()
        else:
            msg = (f"validation failed with {len(errors)} error(s):\n"
                   + "\n".join("  " + d.format() for d in self.diagnostics))
        super().__init__(msg)


def fail(rule_id: str, location: str, message: str,
         hint: str | None = None) -> None:
    """Raise a single-diagnostic GraphValidationError (build-time checks)."""
    raise GraphValidationError([diag(rule_id, location, message, hint)])


def raise_on_error(diagnostics: Sequence[Diagnostic]) -> None:
    """Raise iff ``diagnostics`` contains at least one ERROR (pre-flight
    fails-fast semantics; WARNs alone never raise)."""
    if any(d.severity == ERROR for d in diagnostics):
        raise GraphValidationError(diagnostics)


# ---------------------------------------------------------------------------
# Graph / constraint / routing / placement / buffer rule catalog.
# (Lint rules NS-L*** are registered by analysis/lint.py.)
# ---------------------------------------------------------------------------

register("NS-G001", ERROR, "duplicate job vertex",
         "job vertex names must be unique within a job graph")
register("NS-G002", ERROR, "dangling job edge (unknown endpoint)",
         "add_vertex() both endpoints before add_edge()")
register("NS-G003", ERROR, "POINTWISE edge with unequal parallelism",
         "POINTWISE wires subtask i to subtask i; make both degrees equal "
         "or use ALL_TO_ALL")
register("NS-G004", ERROR, "job graph contains a cycle",
         "the job graph must be a DAG (paper §3.1.1)")
register("NS-G005", ERROR, "duplicate job edge",
         "the same (src, dst) channel group was added twice; every pair "
         "may be wired at most once")
register("NS-G006", ERROR, "sink unreachable from any source",
         "every sink must be reachable from an in-degree-0 vertex or no "
         "item can ever arrive there")
register("NS-G007", WARN, "vertex unreachable from any source",
         "tasks of this vertex will never receive an item")
register("NS-G008", ERROR, "respawn targets a dead worker",
         "crash recovery must place lost subtasks on the replacement "
         "acquired via WorkerPool.acquire_replacement(); a worker marked "
         "dead is quarantined forever (core/faults.py, docs/robustness.md)")

register("NS-C001", ERROR, "constraint references unknown job vertex",
         "every vertex/edge element of a JobSequence must exist in the "
         "job graph")
register("NS-C002", ERROR, "constraint spans a non-contiguous sequence",
         "a JobSequence edge element has no matching job edge; constraints "
         "must follow existing edges (paper §3.2.4)")
register("NS-C003", ERROR, "non-positive constraint bound",
         "latency_limit_ms and window_ms must be > 0")
register("NS-C004", ERROR, "throughput constraint on unknown vertex",
         "ThroughputConstraint.job_vertex must name a job vertex")
register("NS-C005", WARN, "throughput constraint on an unscalable stage",
         "scale-out needs a non-source stage with ALL_TO_ALL in/out edges "
         "(POINTWISE pins parallelism to the peer's)")

register("NS-R001", ERROR, "stage parallelism exceeds addressable key ranges",
         "pass num_key_ranges >= parallelism (a power of two) to "
         "RuntimeGraph / StreamSimulator / StreamEngine")
register("NS-R002", WARN, "scale-out headroom exceeds addressable key ranges",
         "max_parallelism beyond the routing-table width would fail at "
         "rescale time; widen num_key_ranges or lower max_parallelism")
register("NS-R003", WARN, "num_key_ranges is not a power of two",
         "a power of two keeps the table[key & mask] masked fast path on "
         "the emit hot path")

register("NS-P001", ERROR, "affinity satisfiable by no worker",
         "no live worker carries the required tags and the pool is capped; "
         "raise max_workers or tag a worker")
register("NS-P002", WARN, "affinity ignored by the modulo policy",
         "the modulo policy places by index only; use packed/spread for "
         "tag-aware placement")
register("NS-P003", WARN, "initial tasks exceed capped pool capacity",
         "placement will overload workers beyond slots_per_worker; raise "
         "max_workers or slots_per_worker")

register("NS-H001", WARN, "latency constraint can never chain",
         "no adjacent task pair in the constrained sequence satisfies the "
         "§3.5.2 chaining conditions (chainable, stateless, single "
         "in/out channel); the chaining countermeasure is dead for it")

register("NS-E001", ERROR, "non-positive forecast horizon",
         "ProactiveConfig.horizon_ms must be > 0; the forecast path "
         "extrapolates forward in time")
register("NS-E002", ERROR, "non-positive estimator update period",
         "ProactiveConfig.update_period_ms must be > 0 (or None to update "
         "on every control tick)")
register("NS-E003", ERROR, "forecast horizon shorter than the control tick",
         "horizon_ms below measurement_interval_ms / 4 forecasts inside "
         "the window the reactive loop already covers; raise horizon_ms "
         "or shrink measurement_interval_ms")
register("NS-E004", ERROR, "unknown rate estimator kind",
         "ProactiveConfig.estimator must name a registered kind "
         "(core/estimation.py ESTIMATOR_KINDS)")

register("NS-B001", ERROR, "invalid buffer sizing bound",
         "initial buffer bytes and the sizing policy's eps/omega/r/s must "
         "satisfy 1 <= eps <= omega, 0 < r < 1, s > 1")
register("NS-B002", ERROR, "non-positive max buffer lifetime",
         "max_buffer_lifetime_ms must be > 0 (or None to disable flush "
         "sweeps)")
register("NS-B003", WARN, "initial buffer above the adaptive ceiling",
         "initial_buffer_bytes exceeds the policy's omega_bytes; Eq. 3 can "
         "never grow a buffer back to it after a shrink")
