"""Eraser-style lockset race detector for the threaded engine.

Enable with ``REPRO_RACE_CHECK=1`` **before the process imports repro**:
the flag is read exactly once, at import time.  When it is unset this
module costs nothing — ``make_lock`` *is* ``threading.Lock`` and the core
classes (``StateStore``, ``OutputBuffer``, ``KeyRouter``) are left
completely untouched, so the hot paths run the very same bytecode as
without the detector (the keyed_burst_sim events/sec canary in
scripts/ci.sh pins that).

When enabled, ``core/routing.py`` / ``core/buffers.py`` instrument their
shared-state classes at import (``instrument_*`` below) and the engine's
``ChannelSender`` takes a tracked lock from ``make_lock``:

* every tracked lock acquire/release maintains a per-thread *lockset*;
* every instrumented method call records an access event (read or write)
  against its instance;
* per instance, the classic Eraser state machine runs: *exclusive* while a
  single thread touches it, *shared* once a second thread reads, and
  *shared-modified* on any write after sharing.  The *candidate lockset*
  — the intersection of the locksets held at every shared access — going
  empty in shared-modified state means no single lock protected the
  conflicting accesses: a ``RaceReport`` with both stack traces is
  recorded (once per instance).

The init-then-publish idiom (one thread fills a structure, others only
read it afterwards) stays silent, as in the original Eraser paper.
Reports are collected, never raised mid-run — call ``CHECKER.reports`` /
``CHECKER.assert_clean()`` after the scenario (see tests/test_analysis_race.py
and the race step of scripts/ci.sh).

Stdlib-only and free of ``repro.core`` imports: the core modules import
*us* at their own import time.
"""
from __future__ import annotations

import os
import sys
import threading
import traceback
from dataclasses import dataclass
from typing import Any, Callable

#: read once at import: instrumentation is selected here and never again.
RACE_CHECK: bool = os.environ.get("REPRO_RACE_CHECK", "") == "1"


@dataclass(frozen=True)
class RaceReport:
    """One unsynchronized conflicting-access pair on one instance."""

    resource: str
    method: str
    first_thread: str
    first_stack: str
    second_thread: str
    second_stack: str

    def format(self) -> str:
        return (
            f"RACE on {self.resource}.{self.method}: no common lock "
            f"protects accesses from threads "
            f"{self.first_thread!r} and {self.second_thread!r}\n"
            f"--- earlier access ({self.first_thread}) ---\n"
            f"{self.first_stack}"
            f"--- conflicting access ({self.second_thread}) ---\n"
            f"{self.second_stack}"
        )


class _ResourceState:
    """Eraser per-instance state (virgin/exclusive handled by creation)."""

    __slots__ = ("label", "owner", "shared", "modified", "candidate",
                 "last_thread", "last_stack", "reported")

    def __init__(self, label: str, owner: int) -> None:
        self.label = label
        self.owner = owner
        self.shared = False
        self.modified = False
        self.candidate: frozenset[int] = frozenset()
        self.last_thread = ""
        self.last_stack = ""
        self.reported = False


def _capture_stack() -> str:
    # lookup_lines=False defers linecache reads; format() fills them in
    # only for the few stacks that end up inside a report.
    frame = sys._getframe(2)
    summary = traceback.StackSummary.extract(
        traceback.walk_stack(frame), limit=10, lookup_lines=False)
    summary.reverse()
    return "".join(summary.format())


class LocksetChecker:
    """Central event sink: per-thread locksets + per-instance lockset
    intersection.  Internally serialized by one meta lock (debug mode —
    throughput is not the point here)."""

    def __init__(self) -> None:
        self._meta = threading.Lock()
        self._held = threading.local()
        #: id(obj) -> (obj, state).  The instance reference is kept on
        #: purpose: it pins ``id`` stability for the process lifetime.
        self._resources: dict[int, tuple[Any, _ResourceState]] = {}
        self.reports: list[RaceReport] = []

    # -- lockset maintenance (called by TrackedLock) -------------------------
    def _held_map(self) -> dict[int, int]:
        held = getattr(self._held, "locks", None)
        if held is None:
            held = {}
            self._held.locks = held
        return held

    def on_acquire(self, lock_id: int) -> None:
        held = self._held_map()
        held[lock_id] = held.get(lock_id, 0) + 1

    def on_release(self, lock_id: int) -> None:
        held = self._held_map()
        n = held.get(lock_id, 0)
        if n <= 1:
            held.pop(lock_id, None)
        else:
            held[lock_id] = n - 1

    # -- access events (called by instrumented methods) ----------------------
    def on_access(self, obj: Any, label: str, method: str,
                  write: bool) -> None:
        tid = threading.get_ident()
        held = frozenset(self._held_map())
        stack = _capture_stack()
        tname = threading.current_thread().name
        with self._meta:
            entry = self._resources.get(id(obj))
            if entry is None or entry[0] is not obj:
                st = _ResourceState(label, tid)
                self._resources[id(obj)] = (obj, st)
            else:
                st = entry[1]
            if not st.shared:
                if st.owner == tid:  # still exclusive
                    st.modified = st.modified or write
                    st.last_thread, st.last_stack = tname, stack
                    return
                # second thread: exclusive -> shared / shared-modified
                st.shared = True
                st.candidate = held
                st.modified = write  # reads forgive the init-phase writes
            else:
                st.candidate = st.candidate & held
                st.modified = st.modified or write
            if st.modified and not st.candidate and not st.reported:
                st.reported = True
                self.reports.append(RaceReport(
                    st.label, method, st.last_thread, st.last_stack,
                    tname, stack))
            st.last_thread, st.last_stack = tname, stack

    # -- results -------------------------------------------------------------
    def clear(self) -> None:
        with self._meta:
            self._resources.clear()
            self.reports = []

    def assert_clean(self) -> None:
        if self.reports:
            raise AssertionError(
                f"{len(self.reports)} lockset race(s) detected:\n\n"
                + "\n\n".join(r.format() for r in self.reports))


class TrackedLock:
    """An RLock that feeds the checker's per-thread lockset.  Reentrant so
    an instrumented method wrapper can take the instance lock *around* the
    original method's own ``with self._lock`` body."""

    __slots__ = ("_lock",)

    def __init__(self) -> None:
        self._lock = threading.RLock()

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        ok = self._lock.acquire(blocking, timeout)
        if ok:
            _checker().on_acquire(id(self))
        return ok

    def release(self) -> None:
        _checker().on_release(id(self))
        self._lock.release()

    def __enter__(self) -> "TrackedLock":
        self.acquire()
        return self

    def __exit__(self, *exc: object) -> None:
        self.release()


class TrackedNullLock:
    """Placeholder for a store constructed with ``locked=False``: holds
    nothing, so accesses through it are protected only by whatever locks
    the caller already holds — exactly what the checker must observe."""

    __slots__ = ()

    def __enter__(self) -> "TrackedNullLock":
        return self

    def __exit__(self, *exc: object) -> None:
        return None


#: the process-wide checker; None when the detector is disabled.
CHECKER: LocksetChecker | None = LocksetChecker() if RACE_CHECK else None


def _checker() -> LocksetChecker:
    assert CHECKER is not None
    return CHECKER


if RACE_CHECK:
    def make_lock() -> Any:
        """Tracked lock for engine-side channel senders (and anything else
        that wants its lock discipline observed)."""
        return TrackedLock()
else:
    # zero-cost disabled path: the factory IS threading.Lock — call sites
    # bind it once at import and pay nothing per construction or per use.
    make_lock = threading.Lock


# ---------------------------------------------------------------------------
# Class instrumentation (applied by core modules at import, enabled only)
# ---------------------------------------------------------------------------


def _wrap_locked(cls: type, name: str, write: bool) -> None:
    """Wrap a method of a class whose instances carry ``self._lock``: take
    the (tracked, reentrant) instance lock around the original call and
    record the access inside it."""
    orig = getattr(cls, name)

    def wrapper(self: Any, *args: Any, **kwargs: Any) -> Any:
        with self._lock:
            _checker().on_access(self, cls.__name__, name, write)
            return orig(self, *args, **kwargs)

    wrapper.__name__ = name
    wrapper.__qualname__ = f"{cls.__name__}.{name}"
    setattr(cls, name, wrapper)


def _wrap_plain(cls: type, name: str, write: bool) -> None:
    """Wrap a method of a lock-less class (protection, if any, is the
    caller's responsibility — which is precisely what is being checked)."""
    orig = getattr(cls, name)

    def wrapper(self: Any, *args: Any, **kwargs: Any) -> Any:
        _checker().on_access(self, cls.__name__, name, write)
        return orig(self, *args, **kwargs)

    wrapper.__name__ = name
    wrapper.__qualname__ = f"{cls.__name__}.{name}"
    setattr(cls, name, wrapper)


def instrument_state_store(cls: type) -> None:
    """StateStore: swap the instance lock for a tracked one at construction
    and record every keyed access.  A ``locked=True`` store then shows a
    non-empty candidate lockset on every access (clean); a ``locked=False``
    store touched by two threads without an external lock is reported."""
    orig_init: Callable[..., None] = cls.__init__

    def __init__(self: Any, *args: Any, **kwargs: Any) -> None:
        orig_init(self, *args, **kwargs)
        # the original init chose threading.Lock() or the null lock; mirror
        # that choice with the tracked equivalents (duck-typed: the null
        # lock has no acquire()).
        if hasattr(self._lock, "acquire"):
            self._lock = TrackedLock()
        else:
            self._lock = TrackedNullLock()

    __init__.__name__ = "__init__"
    cls.__init__ = __init__
    for m in ("get", "keys", "items", "__len__", "__contains__"):
        _wrap_locked(cls, m, write=False)
    for m in ("put", "bump", "pop", "snapshot", "restore"):
        _wrap_locked(cls, m, write=True)


def instrument_output_buffer(cls: type) -> None:
    """OutputBuffer has no lock of its own — the engine guards each buffer
    with its ChannelSender lock (a ``make_lock`` tracked lock)."""
    for m in ("room_for",):
        _wrap_plain(cls, m, write=False)
    for m in ("append", "append_run", "take", "try_update_size"):
        _wrap_plain(cls, m, write=True)


def instrument_key_router(cls: type) -> None:
    """KeyRouter: only the rescale-side table writes are instrumented.
    Emit-path reads of ``table`` are bare attribute loads against an
    atomically swapped immutable tuple — lock-free *by design* (see
    core/routing.py) — so instrumenting them would only manufacture false
    positives.  Two uncoordinated committers, however, are a real race."""
    _wrap_plain(cls, "plan", write=False)
    _wrap_plain(cls, "commit", write=True)
