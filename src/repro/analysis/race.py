"""Eraser-style lockset race detector for the threaded engine.

Enable with ``REPRO_RACE_CHECK=1`` **before the process imports repro**:
the flag is read exactly once, at import time.  When it is unset this
module costs nothing — ``make_lock`` *is* ``threading.Lock`` and the core
classes (``StateStore``, ``OutputBuffer``, ``KeyRouter``) are left
completely untouched, so the hot paths run the very same bytecode as
without the detector (the keyed_burst_sim events/sec canary in
scripts/ci.sh pins that).

When enabled, ``core/routing.py`` / ``core/buffers.py`` instrument their
shared-state classes at import (``instrument_*`` below) and the engine's
``ChannelSender`` takes a tracked lock from ``make_lock``:

* every tracked lock acquire/release maintains a per-thread *lockset*;
* every instrumented method call records an access event (read or write)
  against its instance;
* per instance, the classic Eraser state machine runs: *exclusive* while a
  single thread touches it, *shared* once a second thread reads, and
  *shared-modified* on any write after sharing.  The *candidate lockset*
  — the intersection of the locksets held at every shared access — going
  empty in shared-modified state means no single lock protected the
  conflicting accesses: a ``RaceReport`` with both stack traces is
  recorded (once per instance).

The init-then-publish idiom (one thread fills a structure, others only
read it afterwards) stays silent, as in the original Eraser paper.

The same tracked-lock stream also feeds **deadlock detection**:

* a lock-order acquisition graph (GoodLock-style): acquiring ``b`` while
  holding ``a`` adds edge ``a -> b``; an acquisition that would close a
  cycle is a lock-order inversion and yields a ``DeadlockReport`` with
  *both* acquisition stacks — the one that established the first order
  and the one that closed the cycle;
* a blocked-drain watchdog: when the engine times out waiting for a task
  to drain/park (chaining, unchaining, state migration), it calls
  ``CHECKER.report_blocked_drain`` and the stuck threads' held tracked
  locks (with their acquire stacks) are recorded.

Reports are collected, never raised mid-run — call ``CHECKER.reports`` /
``CHECKER.deadlocks`` / ``CHECKER.assert_clean()`` after the scenario
(see tests/test_analysis_race.py and the race step of scripts/ci.sh).

Stdlib-only and free of ``repro.core`` imports: the core modules import
*us* at their own import time.
"""
from __future__ import annotations

import os
import sys
import threading
import traceback
from dataclasses import dataclass
from typing import Any, Callable

#: read once at import: instrumentation is selected here and never again.
RACE_CHECK: bool = os.environ.get("REPRO_RACE_CHECK", "") == "1"


@dataclass(frozen=True)
class RaceReport:
    """One unsynchronized conflicting-access pair on one instance."""

    resource: str
    method: str
    first_thread: str
    first_stack: str
    second_thread: str
    second_stack: str

    def format(self) -> str:
        return (
            f"RACE on {self.resource}.{self.method}: no common lock "
            f"protects accesses from threads "
            f"{self.first_thread!r} and {self.second_thread!r}\n"
            f"--- earlier access ({self.first_thread}) ---\n"
            f"{self.first_stack}"
            f"--- conflicting access ({self.second_thread}) ---\n"
            f"{self.second_stack}"
        )


@dataclass(frozen=True)
class DeadlockReport:
    """One deadlock finding: a lock-order inversion (two locks acquired in
    opposite orders on different code paths — threads interleaving those
    paths block each other forever, GoodLock-style) or a blocked drain (a
    thread stuck past the drain timeout while holding tracked locks)."""

    kind: str  # "lock-order" | "blocked-drain"
    description: str
    first_stack: str = ""
    second_stack: str = ""

    def format(self) -> str:
        s = f"DEADLOCK ({self.kind}): {self.description}"
        if self.first_stack:
            s += (f"\n--- earlier acquisition (established the first "
                  f"order) ---\n{self.first_stack}")
        if self.second_stack:
            s += (f"--- conflicting acquisition (closed the cycle) ---\n"
                  f"{self.second_stack}")
        return s


def _lock_name(lock_id: int) -> str:
    return f"lock#{lock_id & 0xffffff:06x}"


class _ResourceState:
    """Eraser per-instance state (virgin/exclusive handled by creation)."""

    __slots__ = ("label", "owner", "shared", "modified", "candidate",
                 "last_thread", "last_stack", "reported")

    def __init__(self, label: str, owner: int) -> None:
        self.label = label
        self.owner = owner
        self.shared = False
        self.modified = False
        self.candidate: frozenset[int] = frozenset()
        self.last_thread = ""
        self.last_stack = ""
        self.reported = False


def _capture_stack() -> str:
    # lookup_lines=False defers linecache reads; format() fills them in
    # only for the few stacks that end up inside a report.
    frame = sys._getframe(2)
    summary = traceback.StackSummary.extract(
        traceback.walk_stack(frame), limit=10, lookup_lines=False)
    summary.reverse()
    return "".join(summary.format())


class LocksetChecker:
    """Central event sink: per-thread locksets + per-instance lockset
    intersection.  Internally serialized by one meta lock (debug mode —
    throughput is not the point here)."""

    def __init__(self) -> None:
        self._meta = threading.Lock()
        self._held = threading.local()
        #: id(obj) -> (obj, state).  The instance reference is kept on
        #: purpose: it pins ``id`` stability for the process lifetime.
        self._resources: dict[int, tuple[Any, _ResourceState]] = {}
        self.reports: list[RaceReport] = []
        # -- deadlock detection state (all guarded by _meta) ----------------
        #: lock-order graph: a -> {b} means some thread acquired b while
        #: holding a.  A path b ~> a at (a -> b) time is an inversion.
        self._order: dict[int, set[int]] = {}
        #: (a, b) -> stack of the first acquisition of b while holding a.
        self._edge_stacks: dict[tuple[int, int], str] = {}
        #: global holdings (the thread-local ``_held`` can't be read from
        #: the watchdog's thread): tid -> {lock_id: first-acquire stack}.
        self._held_by_tid: dict[int, dict[int, str]] = {}
        self._reported_cycles: set[frozenset[int]] = set()
        self.deadlocks: list[DeadlockReport] = []

    # -- lockset maintenance (called by TrackedLock) -------------------------
    def _held_map(self) -> dict[int, int]:
        held = getattr(self._held, "locks", None)
        if held is None:
            held = {}
            self._held.locks = held
        return held

    def _path_exists(self, src: int, dst: int) -> bool:
        """DFS over the lock-order graph (caller holds ``_meta``)."""
        stack, seen = [src], {src}
        while stack:
            node = stack.pop()
            if node == dst:
                return True
            for nxt in self._order.get(node, ()):
                if nxt not in seen:
                    seen.add(nxt)
                    stack.append(nxt)
        return False

    def on_acquire(self, lock_id: int, stack: str = "") -> None:
        held = self._held_map()
        n = held.get(lock_id, 0)
        held[lock_id] = n + 1
        if n:
            return  # reentrant re-acquire: holdings and order unchanged
        tid = threading.get_ident()
        with self._meta:
            self._held_by_tid.setdefault(tid, {})[lock_id] = stack
            for h in held:
                if h == lock_id or lock_id in self._order.get(h, ()):
                    continue
                if self._path_exists(lock_id, h):
                    # adding h -> lock_id would close a cycle: somewhere an
                    # earlier thread acquired these locks in the opposite
                    # order.  Report once per lock pair; keep the graph
                    # acyclic so later acquires diagnose against it too.
                    cycle = frozenset((h, lock_id))
                    if cycle not in self._reported_cycles:
                        self._reported_cycles.add(cycle)
                        first = self._edge_stacks.get((lock_id, h)) or next(
                            (s for (a, _), s in self._edge_stacks.items()
                             if a == lock_id), "")
                        self.deadlocks.append(DeadlockReport(
                            "lock-order",
                            f"{_lock_name(lock_id)} was acquired while "
                            f"holding {_lock_name(h)}, but an earlier path "
                            f"acquired them in the opposite order; threads "
                            f"interleaving these paths deadlock",
                            first_stack=first, second_stack=stack))
                    continue
                self._order.setdefault(h, set()).add(lock_id)
                self._edge_stacks.setdefault((h, lock_id), stack)

    def on_release(self, lock_id: int) -> None:
        held = self._held_map()
        n = held.get(lock_id, 0)
        if n <= 1:
            held.pop(lock_id, None)
            tid = threading.get_ident()
            with self._meta:
                holdings = self._held_by_tid.get(tid)
                if holdings is not None:
                    holdings.pop(lock_id, None)
                    if not holdings:
                        self._held_by_tid.pop(tid, None)
        else:
            held[lock_id] = n - 1

    # -- blocked-drain watchdog (called by the engine on drain timeout) ------
    def report_blocked_drain(self, description: str, threads) -> None:
        """Record threads stuck past a drain/park timeout together with the
        tracked locks each still holds (and where it acquired them) — the
        forensic complement to the static lock-order pass."""
        parts = []
        with self._meta:
            for t in threads:
                if t is None or t.ident is None:
                    continue
                holdings = self._held_by_tid.get(t.ident, {})
                if holdings:
                    for lid, stk in holdings.items():
                        parts.append(
                            f"thread {t.name!r} holds {_lock_name(lid)}, "
                            f"acquired at:\n{stk}")
                else:
                    parts.append(
                        f"thread {t.name!r} holds no tracked locks "
                        f"(blocked on a queue/event, not a lock)")
            self.deadlocks.append(DeadlockReport(
                "blocked-drain",
                description + ("\n" + "".join(parts) if parts else "")))

    # -- access events (called by instrumented methods) ----------------------
    def on_access(self, obj: Any, label: str, method: str,
                  write: bool) -> None:
        tid = threading.get_ident()
        held = frozenset(self._held_map())
        stack = _capture_stack()
        tname = threading.current_thread().name
        with self._meta:
            entry = self._resources.get(id(obj))
            if entry is None or entry[0] is not obj:
                st = _ResourceState(label, tid)
                self._resources[id(obj)] = (obj, st)
            else:
                st = entry[1]
            if not st.shared:
                if st.owner == tid:  # still exclusive
                    st.modified = st.modified or write
                    st.last_thread, st.last_stack = tname, stack
                    return
                # second thread: exclusive -> shared / shared-modified
                st.shared = True
                st.candidate = held
                st.modified = write  # reads forgive the init-phase writes
            else:
                st.candidate = st.candidate & held
                st.modified = st.modified or write
            if st.modified and not st.candidate and not st.reported:
                st.reported = True
                self.reports.append(RaceReport(
                    st.label, method, st.last_thread, st.last_stack,
                    tname, stack))
            st.last_thread, st.last_stack = tname, stack

    # -- results -------------------------------------------------------------
    def clear(self) -> None:
        with self._meta:
            self._resources.clear()
            self.reports = []
            self._order.clear()
            self._edge_stacks.clear()
            self._held_by_tid.clear()
            self._reported_cycles.clear()
            self.deadlocks = []

    def assert_clean(self) -> None:
        parts = []
        if self.reports:
            parts.append(
                f"{len(self.reports)} lockset race(s) detected:\n\n"
                + "\n\n".join(r.format() for r in self.reports))
        if self.deadlocks:
            parts.append(
                f"{len(self.deadlocks)} deadlock finding(s):\n\n"
                + "\n\n".join(d.format() for d in self.deadlocks))
        if parts:
            raise AssertionError("\n\n".join(parts))


class TrackedLock:
    """An RLock that feeds the checker's per-thread lockset.  Reentrant so
    an instrumented method wrapper can take the instance lock *around* the
    original method's own ``with self._lock`` body."""

    __slots__ = ("_lock",)

    def __init__(self) -> None:
        self._lock = threading.RLock()

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        ok = self._lock.acquire(blocking, timeout)
        if ok:
            # stack feeds the lock-order graph's edge evidence and the
            # blocked-drain holdings; reentrant re-acquires discard it
            _checker().on_acquire(id(self), _capture_stack())
        return ok

    def release(self) -> None:
        _checker().on_release(id(self))
        self._lock.release()

    def __enter__(self) -> "TrackedLock":
        self.acquire()
        return self

    def __exit__(self, *exc: object) -> None:
        self.release()


class TrackedNullLock:
    """Placeholder for a store constructed with ``locked=False``: holds
    nothing, so accesses through it are protected only by whatever locks
    the caller already holds — exactly what the checker must observe."""

    __slots__ = ()

    def __enter__(self) -> "TrackedNullLock":
        return self

    def __exit__(self, *exc: object) -> None:
        return None


#: the process-wide checker; None when the detector is disabled.
CHECKER: LocksetChecker | None = LocksetChecker() if RACE_CHECK else None


def _checker() -> LocksetChecker:
    assert CHECKER is not None
    return CHECKER


if RACE_CHECK:
    def make_lock() -> Any:
        """Tracked lock for engine-side channel senders (and anything else
        that wants its lock discipline observed)."""
        return TrackedLock()
else:
    # zero-cost disabled path: the factory IS threading.Lock — call sites
    # bind it once at import and pay nothing per construction or per use.
    make_lock = threading.Lock


# ---------------------------------------------------------------------------
# Class instrumentation (applied by core modules at import, enabled only)
# ---------------------------------------------------------------------------


def _wrap_locked(cls: type, name: str, write: bool) -> None:
    """Wrap a method of a class whose instances carry ``self._lock``: take
    the (tracked, reentrant) instance lock around the original call and
    record the access inside it."""
    orig = getattr(cls, name)

    def wrapper(self: Any, *args: Any, **kwargs: Any) -> Any:
        with self._lock:
            _checker().on_access(self, cls.__name__, name, write)
            return orig(self, *args, **kwargs)

    wrapper.__name__ = name
    wrapper.__qualname__ = f"{cls.__name__}.{name}"
    setattr(cls, name, wrapper)


def _wrap_plain(cls: type, name: str, write: bool) -> None:
    """Wrap a method of a lock-less class (protection, if any, is the
    caller's responsibility — which is precisely what is being checked)."""
    orig = getattr(cls, name)

    def wrapper(self: Any, *args: Any, **kwargs: Any) -> Any:
        _checker().on_access(self, cls.__name__, name, write)
        return orig(self, *args, **kwargs)

    wrapper.__name__ = name
    wrapper.__qualname__ = f"{cls.__name__}.{name}"
    setattr(cls, name, wrapper)


def instrument_state_store(cls: type) -> None:
    """StateStore: swap the instance lock for a tracked one at construction
    and record every keyed access.  A ``locked=True`` store then shows a
    non-empty candidate lockset on every access (clean); a ``locked=False``
    store touched by two threads without an external lock is reported."""
    orig_init: Callable[..., None] = cls.__init__

    def __init__(self: Any, *args: Any, **kwargs: Any) -> None:
        orig_init(self, *args, **kwargs)
        # the original init chose threading.Lock() or the null lock; mirror
        # that choice with the tracked equivalents (duck-typed: the null
        # lock has no acquire()).
        if hasattr(self._lock, "acquire"):
            self._lock = TrackedLock()
        else:
            self._lock = TrackedNullLock()

    __init__.__name__ = "__init__"
    cls.__init__ = __init__
    for m in ("get", "keys", "items", "__len__", "__contains__"):
        _wrap_locked(cls, m, write=False)
    for m in ("put", "bump", "pop", "snapshot", "restore"):
        _wrap_locked(cls, m, write=True)


def instrument_output_buffer(cls: type) -> None:
    """OutputBuffer has no lock of its own — the engine guards each buffer
    with its ChannelSender lock (a ``make_lock`` tracked lock)."""
    for m in ("room_for",):
        _wrap_plain(cls, m, write=False)
    for m in ("append", "append_run", "take", "try_update_size"):
        _wrap_plain(cls, m, write=True)


def instrument_key_router(cls: type) -> None:
    """KeyRouter: only the rescale-side table writes are instrumented.
    Emit-path reads of ``table`` are bare attribute loads against an
    atomically swapped immutable tuple — lock-free *by design* (see
    core/routing.py) — so instrumenting them would only manufacture false
    positives.  Two uncoordinated committers, however, are a real race."""
    _wrap_plain(cls, "plan", write=False)
    _wrap_plain(cls, "commit", write=True)
