"""Repo-specific AST lint rules (hot-path discipline as executable policy).

Five PRs of engine/simulator/elastic machinery rest on conventions that
nothing enforced until now: the simulator must never read wall-clock time
(determinism), the keyed-state handoff codec must stay stdlib-only (the
rescale hot path must not pay heavyweight imports), key routing must go
through ``KeyRouter.table`` (a bare ``key % n`` re-homes every key on
rescale — the exact bug class core/routing.py exists to kill), designated
hot modules must keep ``__slots__`` on their per-item classes, and the
core/checkpoint zones must not import numpy-class libraries at module
level.  Each rule is a small function over an ``ast`` tree producing the
same structured ``Diagnostic`` records as the graph validator.

Run via ``scripts/lint.py`` (wired into scripts/ci.sh: ERROR fails CI,
WARN prints).  Rules are pluggable: append a ``LintRule`` to ``RULES``
(see docs/analysis.md for a walk-through).
"""
from __future__ import annotations

import ast
import sys
from dataclasses import dataclass
from pathlib import Path
from typing import Callable, Iterable

from .diagnostics import Diagnostic, ERROR, WARN, diag, register

# ---------------------------------------------------------------------------
# Rule catalog (registered alongside the graph rules in diagnostics.REGISTRY)
# ---------------------------------------------------------------------------

register("NS-L001", ERROR, "wall-clock read in a simulated-time module",
         "route every timestamp through core/clock (SimClock); wall-clock "
         "reads break the simulator's bit-exact determinism contract")
register("NS-L002", ERROR, "non-stdlib import in a stdlib-only module",
         "checkpoint/state_codec.py is imported on the rescale hot path "
         "and must stay dependency-free (stdlib absolute imports only)")
register("NS-L003", ERROR, "modulo key routing outside core/routing.py",
         "route keys through KeyRouter.table (key & mask); a bare "
         "`key % n` re-homes every key on rescale and detaches keyed state")
register("NS-L004", ERROR, "missing __slots__ in a hot module",
         "classes in designated hot modules are built once per task/channel "
         "or touched per item; give them __slots__ (or "
         "@dataclass(slots=True)), or add them to the module's exempt list")
register("NS-L005", WARN, "heavyweight module-level import in a lazy zone",
         "import numpy/jax/... inside the function that needs it; the "
         "core/checkpoint zones are imported by latency-sensitive paths")
register("NS-L006", ERROR, "raw lock construction in a race-instrumented "
         "module",
         "construct locks via analysis.race.make_lock() (it IS "
         "threading.Lock when the detector is off); a raw "
         "threading.Lock()/RLock() is invisible to the lockset race "
         "detector and the lock-order deadlock pass")
register("NS-L007", ERROR, "heapq use outside core/eventq.py",
         "core/eventq.py is the event core's single ordering authority; "
         "import the re-exported heappush/heappop from there (or use an "
         "event queue class) so every priority queue in the tree shares "
         "one verified total-order contract")

# -- per-rule configuration (paths are repo-relative, POSIX separators) ------

#: modules that must never read wall-clock time directly
WALLCLOCK_FREE_MODULES = frozenset({
    "src/repro/core/simulator.py",
})
_WALLCLOCK_TIME_FNS = frozenset(
    {"time", "monotonic", "perf_counter", "process_time", "time_ns",
     "monotonic_ns", "perf_counter_ns"})
_WALLCLOCK_DT_FNS = frozenset({"now", "utcnow", "today"})

#: modules restricted to absolute stdlib imports
STDLIB_ONLY_MODULES = frozenset({
    "src/repro/checkpoint/state_codec.py",
})

#: the one module allowed to spell modulo key routing
KEY_MOD_EXEMPT = frozenset({
    "src/repro/core/routing.py",
})

#: hot modules -> class names exempt from the __slots__ requirement
#: (cold configuration/result/facade objects constructed once per run)
SLOTS_REQUIRED_MODULES: dict[str, frozenset[str]] = {
    "src/repro/core/routing.py": frozenset(),
    "src/repro/core/buffers.py": frozenset(),
    "src/repro/core/eventq.py": frozenset(),
    "src/repro/core/simulator.py": frozenset(
        {"StreamSimulator", "SimNetConfig", "SimSourceSpec", "SimResult"}),
}

#: zones whose module level must not import heavyweight libraries
LAZY_IMPORT_ZONES = ("src/repro/core/", "src/repro/checkpoint/")
HEAVY_MODULES = frozenset(
    {"numpy", "jax", "jaxlib", "scipy", "pandas", "torch", "tensorflow"})

#: modules whose lock discipline the race/deadlock checkers observe — every
#: lock they construct must come from analysis.race.make_lock() so the
#: checkers see its acquire/release stream
RACE_LOCK_MODULES = frozenset({
    "src/repro/core/engine.py",
    "src/repro/core/routing.py",
    "src/repro/core/buffers.py",
    "src/repro/core/elastic.py",
})
_RAW_LOCK_NAMES = frozenset({"Lock", "RLock"})


@dataclass(frozen=True)
class LintContext:
    """One file under lint: repo-relative path + parsed tree + source."""

    path: str  # repo-relative, POSIX separators
    tree: ast.Module
    source: str

    def loc(self, node: ast.AST) -> str:
        return f"{self.path}:{getattr(node, 'lineno', 0)}"


@dataclass(frozen=True)
class LintRule:
    """A pluggable rule: id + checker.  ``applies`` keeps whole-file rules
    from walking files they can never fire on."""

    id: str
    check: Callable[[LintContext], list[Diagnostic]]
    applies: Callable[[str], bool] = lambda path: True


# ---------------------------------------------------------------------------
# NS-L001: no wall-clock reads in simulated-time modules
# ---------------------------------------------------------------------------


def _check_wallclock(ctx: LintContext) -> list[Diagnostic]:
    out: list[Diagnostic] = []
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.ImportFrom) and node.module == "time":
            for alias in node.names:
                if alias.name in _WALLCLOCK_TIME_FNS:
                    out.append(diag("NS-L001", ctx.loc(node),
                                    f"imports time.{alias.name} — wall "
                                    f"clock in a simulated-time module"))
        elif isinstance(node, ast.Call):
            f = node.func
            if not isinstance(f, ast.Attribute):
                continue
            base = f.value
            if (isinstance(base, ast.Name) and base.id == "time"
                    and f.attr in _WALLCLOCK_TIME_FNS):
                out.append(diag("NS-L001", ctx.loc(node),
                                f"calls time.{f.attr}()"))
            elif f.attr in _WALLCLOCK_DT_FNS and (
                    (isinstance(base, ast.Name)
                     and base.id in ("datetime", "date"))
                    or (isinstance(base, ast.Attribute)
                        and base.attr in ("datetime", "date"))):
                out.append(diag("NS-L001", ctx.loc(node),
                                f"calls datetime {f.attr}()"))
    return out


# ---------------------------------------------------------------------------
# NS-L002: stdlib-only import allowlist
# ---------------------------------------------------------------------------


def _check_stdlib_only(ctx: LintContext) -> list[Diagnostic]:
    out: list[Diagnostic] = []
    stdlib = sys.stdlib_module_names
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                root = alias.name.split(".")[0]
                if root not in stdlib:
                    out.append(diag("NS-L002", ctx.loc(node),
                                    f"imports non-stdlib module "
                                    f"{alias.name!r}"))
        elif isinstance(node, ast.ImportFrom):
            if node.level:
                out.append(diag("NS-L002", ctx.loc(node),
                                "relative import in a stdlib-only module"))
            elif node.module and node.module.split(".")[0] not in stdlib:
                out.append(diag("NS-L002", ctx.loc(node),
                                f"imports non-stdlib module "
                                f"{node.module!r}"))
    return out


# ---------------------------------------------------------------------------
# NS-L003: no `key % n` routing outside core/routing.py
# ---------------------------------------------------------------------------


def _is_key_expr(node: ast.expr) -> bool:
    return ((isinstance(node, ast.Name) and node.id == "key")
            or (isinstance(node, ast.Attribute) and node.attr == "key"))


def _check_key_mod(ctx: LintContext) -> list[Diagnostic]:
    out: list[Diagnostic] = []
    for node in ast.walk(ctx.tree):
        if (isinstance(node, ast.BinOp) and isinstance(node.op, ast.Mod)
                and _is_key_expr(node.left)):
            out.append(diag("NS-L003", ctx.loc(node),
                            "modulo routing on a key expression"))
    return out


# ---------------------------------------------------------------------------
# NS-L004: __slots__ required in hot modules
# ---------------------------------------------------------------------------


def _has_slots(cls: ast.ClassDef) -> bool:
    for stmt in cls.body:
        if isinstance(stmt, ast.Assign) and any(
                isinstance(t, ast.Name) and t.id == "__slots__"
                for t in stmt.targets):
            return True
        if (isinstance(stmt, ast.AnnAssign)
                and isinstance(stmt.target, ast.Name)
                and stmt.target.id == "__slots__"):
            return True
    for dec in cls.decorator_list:
        if isinstance(dec, ast.Call):
            name = dec.func
            is_dc = ((isinstance(name, ast.Name) and name.id == "dataclass")
                     or (isinstance(name, ast.Attribute)
                         and name.attr == "dataclass"))
            if is_dc and any(
                    kw.arg == "slots"
                    and isinstance(kw.value, ast.Constant)
                    and kw.value.value is True
                    for kw in dec.keywords):
                return True
    return False


def _check_slots(ctx: LintContext) -> list[Diagnostic]:
    exempt = SLOTS_REQUIRED_MODULES.get(ctx.path, frozenset())
    out: list[Diagnostic] = []
    for node in ctx.tree.body:
        if not isinstance(node, ast.ClassDef) or node.name in exempt:
            continue
        if not _has_slots(node):
            out.append(diag("NS-L004", ctx.loc(node),
                            f"class {node.name} in a hot module has no "
                            f"__slots__"))
    return out


# ---------------------------------------------------------------------------
# NS-L005: heavyweight module-level imports in lazy-import zones
# ---------------------------------------------------------------------------


def _module_level_stmts(tree: ast.Module) -> Iterable[ast.stmt]:
    """Module body plus conditional blocks at module level (an import under
    ``if TYPE_CHECKING:`` is still flagged — the guard is free at runtime,
    but typing-only imports of heavy modules belong behind it, so allow
    that single idiom)."""
    for stmt in tree.body:
        if isinstance(stmt, (ast.If, ast.Try)):
            # allow `if TYPE_CHECKING:` blocks — never executed at runtime
            test = getattr(stmt, "test", None)
            if (isinstance(test, ast.Name)
                    and test.id == "TYPE_CHECKING"):
                continue
            for sub in ast.walk(stmt):
                if isinstance(sub, (ast.Import, ast.ImportFrom)):
                    yield sub
        elif isinstance(stmt, (ast.Import, ast.ImportFrom)):
            yield stmt


def _check_heavy_imports(ctx: LintContext) -> list[Diagnostic]:
    out: list[Diagnostic] = []
    for stmt in _module_level_stmts(ctx.tree):
        if isinstance(stmt, ast.Import):
            names = [a.name for a in stmt.names]
        elif isinstance(stmt, ast.ImportFrom) and not stmt.level:
            names = [stmt.module or ""]
        else:
            continue
        for name in names:
            if name.split(".")[0] in HEAVY_MODULES:
                out.append(diag("NS-L005", ctx.loc(stmt),
                                f"module-level import of {name!r} in a "
                                f"lazy-import zone"))
    return out


# ---------------------------------------------------------------------------
# NS-L006: no raw lock construction in race-instrumented modules
# ---------------------------------------------------------------------------


def _check_raw_locks(ctx: LintContext) -> list[Diagnostic]:
    """Flag ``threading.Lock()`` / ``threading.RLock()`` calls (and bare
    ``Lock()`` / ``RLock()`` when imported from threading) in modules the
    race/deadlock checkers instrument."""
    from_threading: set[str] = set()
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.ImportFrom) and node.module == "threading":
            for alias in node.names:
                if alias.name in _RAW_LOCK_NAMES:
                    from_threading.add(alias.asname or alias.name)
    out: list[Diagnostic] = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        raw = None
        if (isinstance(f, ast.Attribute) and f.attr in _RAW_LOCK_NAMES
                and isinstance(f.value, ast.Name)
                and f.value.id == "threading"):
            raw = f"threading.{f.attr}"
        elif isinstance(f, ast.Name) and f.id in from_threading:
            raw = f.id
        if raw is not None:
            out.append(diag("NS-L006", ctx.loc(node),
                            f"constructs {raw}() directly in a "
                            f"race-instrumented module"))
    return out


# ---------------------------------------------------------------------------
# NS-L007: heapq stays inside core/eventq.py (the ordering authority)
# ---------------------------------------------------------------------------


def _check_heapq(ctx: LintContext) -> list[Diagnostic]:
    """Flag ``import heapq`` / ``from heapq import ...`` and any
    ``heapq.xxx(...)`` call outside the event-queue module.  Code that
    needs heap ops imports the re-exports from core/eventq.py instead,
    so the event core keeps a single verified ordering contract."""
    out: list[Diagnostic] = []
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name.split(".")[0] == "heapq":
                    out.append(diag("NS-L007", ctx.loc(node),
                                    "imports heapq outside core/eventq.py"))
        elif isinstance(node, ast.ImportFrom):
            if not node.level and node.module == "heapq":
                out.append(diag("NS-L007", ctx.loc(node),
                                "imports from heapq outside core/eventq.py"))
        elif isinstance(node, ast.Call):
            f = node.func
            if (isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name)
                    and f.value.id == "heapq"):
                out.append(diag("NS-L007", ctx.loc(node),
                                f"calls heapq.{f.attr}() outside "
                                f"core/eventq.py"))
    return out


#: the one module allowed to touch heapq
HEAPQ_EXEMPT = frozenset({
    "src/repro/core/eventq.py",
})


# ---------------------------------------------------------------------------
# Registry + runners
# ---------------------------------------------------------------------------

RULES: list[LintRule] = [
    LintRule("NS-L001", _check_wallclock,
             lambda p: p in WALLCLOCK_FREE_MODULES),
    LintRule("NS-L002", _check_stdlib_only,
             lambda p: p in STDLIB_ONLY_MODULES),
    LintRule("NS-L003", _check_key_mod,
             lambda p: p.startswith("src/repro/") and p not in KEY_MOD_EXEMPT),
    LintRule("NS-L004", _check_slots,
             lambda p: p in SLOTS_REQUIRED_MODULES),
    LintRule("NS-L005", _check_heavy_imports,
             lambda p: p.startswith(LAZY_IMPORT_ZONES)),
    LintRule("NS-L006", _check_raw_locks,
             lambda p: p in RACE_LOCK_MODULES),
    LintRule("NS-L007", _check_heapq,
             lambda p: p.startswith("src/repro/") and p not in HEAPQ_EXEMPT),
]


def lint_source(source: str, rel_path: str) -> list[Diagnostic]:
    """Lint one file's source against every applicable rule."""
    try:
        tree = ast.parse(source, filename=rel_path)
    except SyntaxError as e:
        return [Diagnostic("NS-L000", ERROR, f"{rel_path}:{e.lineno}",
                           f"syntax error: {e.msg}")]
    ctx = LintContext(rel_path, tree, source)
    out: list[Diagnostic] = []
    for rule in RULES:
        if rule.applies(rel_path):
            out.extend(rule.check(ctx))
    return out


def lint_tree(root: Path, subdir: str = "src/repro") -> list[Diagnostic]:
    """Lint every ``*.py`` under ``root/subdir``; paths are reported
    relative to ``root``."""
    out: list[Diagnostic] = []
    for path in sorted((root / subdir).rglob("*.py")):
        rel = path.relative_to(root).as_posix()
        out.extend(lint_source(path.read_text(), rel))
    return out
