"""Static QoS-feasibility pass (NS-F***): can *any* configuration meet the
declared constraints?

The paper's QoS managers are reactive — an infeasible constraint (a latency
bound below the graph's irreducible service time, a throughput target no
admissible parallelism can reach) only surfaces at runtime as an endless
GiveUp/ScaleRequest loop.  Deciding whether an SLO is satisfiable at any
parallelism is a *model* question, answerable before execution: this pass
is an abstract interpretation over the job graph that

* propagates declared source rates (``SimSourceSpec.rate_items_per_s`` /
  ``SourceSpec.rate_per_s``) through fan-in/fan-out to a per-stage arrival
  rate (unknown sources propagate ``None`` — rate-dependent rules stay
  silent rather than guess);
* evaluates the §3 latency model — summed task latencies (§3.2.1/§3.2.3)
  plus per-channel transport and output-buffer residency under the Eq. 2–3
  sizing floor (§3.2.2/§3.5.1) — across the admissible configuration
  lattice: every subset of chain-eligible adjacent pairs (reusing
  graph_check's §3.5.2 pre-computation), buffer size down to the policy
  floor, parallelism up to the vertex cap;
* checks each ThroughputConstraint target against the stage's maximum
  service capacity at its largest admissible parallelism.

Every per-item term is evaluated at its *optimistic* bound (chained where
chaining is ever possible, buffers at the floor, transport over the
cheapest link), so an NS-F001/NS-F003 ERROR is sound: no runtime
configuration can do better than the reported best-achievable figure.
Parallelism never lowers the per-item bound in this model — it buys
*stability*, which is what the WARN rules (NS-F002/NS-F004) reason about
via utilization rho = lambda * service_time / parallelism.

Complexity is O(graph x configurations) — chain subsets are capped at
2**10 per sequence (beyond that only the lattice extremes are evaluated,
which is exact for the minimum since every channel term is >= 0).  Nothing
is simulated, nothing random is consumed, nothing is mutated.
"""
from __future__ import annotations

from typing import Any, Mapping, Sequence

from repro.core.graphs import ALL_TO_ALL, JobGraph
from repro.core.routing import NUM_KEY_RANGES

from .diagnostics import ERROR, WARN, Diagnostic, diag, register
from .graph_check import _adjacent_task_pairs, _pair_chainable, _split

__all__ = ["check_feasibility"]

#: relative slack on strict comparisons so a bound that equals the limit to
#: within float noise is not flagged.
_REL_TOL = 1e-9

#: Eq. 2 buffer floor when no sizing policy is passed (BufferSizingPolicy
#: default; kept literal so this module needs no core.buffers import).
_DEFAULT_EPS_BYTES = 200

register("NS-F001", ERROR, "latency constraint statically infeasible",
         "the irreducible per-item latency (summed service times + cheapest "
         "transport, chained wherever §3.5.2 allows, buffers at the policy "
         "floor) already exceeds the bound; raise latency_limit_ms, cut "
         "sim_cpu_ms, or shorten the constrained sequence")
register("NS-F002", WARN, "QoS goal reachable only at near-max scale-out",
         "the smallest workable parallelism is within 10% of the admissible "
         "cap; raise max_parallelism / num_key_ranges headroom or the "
         "ScaleRequest countermeasure will have no room left to react")
register("NS-F003", ERROR, "throughput target exceeds stage capacity",
         "even at the largest admissible parallelism the stage cannot serve "
         "min_items_per_s; lower the target, cut sim_cpu_ms, or raise "
         "max_parallelism / num_key_ranges")
register("NS-F004", WARN, "stage saturated at every admissible parallelism",
         "declared source rates keep utilization >= 1 at every parallelism "
         "the runtime may reach — queues grow without bound and every "
         "latency constraint through this stage will degrade to GiveUp")


def check_feasibility(
    jg: JobGraph,
    constraints: Sequence[Any] = (),
    *,
    sources: Mapping[str, Any] | None = None,
    net: Any = None,
    num_workers: int | None = None,
    num_key_ranges: int | None = None,
    policy: Any = None,
    max_buffer_lifetime_ms: float | None = None,
) -> list[Diagnostic]:
    """Feasibility findings for one job description (never raises).

    ``sources`` maps source vertex name -> spec (duck-typed: any object
    with ``rate_items_per_s`` or ``rate_per_s``); ``net`` is the
    simulator's ``SimNetConfig`` (None for the threaded engine: transport
    is then not priced, which only makes bounds more optimistic).
    """
    out: list[Diagnostic] = []
    latency, throughput = _split(constraints)
    lam_in, lam_out = _stage_rates(jg, sources)
    caps = {name: _allowed_max(jg, name, throughput, num_key_ranges)
            for name in jg.vertices}

    for c in latency:
        out.extend(_check_latency(jg, c, net, num_workers, policy,
                                  max_buffer_lifetime_ms, lam_out))
    for c in throughput:
        out.extend(_check_throughput(jg, c, caps))
    out.extend(_check_saturation(jg, lam_in, caps))
    return out


# ---------------------------------------------------------------------------
# Rate propagation (abstract interpretation over the DAG)
# ---------------------------------------------------------------------------


def _source_rate(spec: Any) -> float | None:
    for attr in ("rate_items_per_s", "rate_per_s"):
        rate = getattr(spec, attr, None)
        if isinstance(rate, (int, float)):
            return float(rate)
    return None


def _stage_rates(
    jg: JobGraph, sources: Mapping[str, Any] | None,
) -> tuple[dict[str, float | None], dict[str, float | None]]:
    """Items/s entering and leaving each stage (all subtasks summed).

    Declared rates are per *subtask* (``SimSourceSpec`` semantics), so a
    source stage offers rate x parallelism.  A stage with ``sim_fan_in=k``
    aggregates k inputs into one output.  ``None`` means unknown and is
    absorbing — rate-dependent rules skip rather than guess.  Rate
    schedules (``rate_fn``) are ignored: the declared base rate is the
    steady-state figure the constraints were written against.
    """
    lam_in: dict[str, float | None] = {}
    lam_out: dict[str, float | None] = {}
    try:
        order = jg.topological_order()
    except Exception:  # cyclic/broken graph: NS-G004 already reported
        return ({n: None for n in jg.vertices},
                {n: None for n in jg.vertices})
    for name in order:
        jv = jg.vertices[name]
        if jv.is_source or not jg.in_edges(name):
            spec = (sources or {}).get(name)
            rate = _source_rate(spec) if spec is not None else None
            lam: float | None = (
                rate * jv.parallelism if rate is not None else None)
        else:
            lam = 0.0
            for e in jg.in_edges(name):
                up = lam_out.get(e.src)
                if up is None:
                    lam = None
                    break
                lam += up
        lam_in[name] = lam
        fan = max(1, int(getattr(jv, "sim_fan_in", 1) or 1))
        lam_out[name] = None if lam is None else lam / fan
    return lam_in, lam_out


# ---------------------------------------------------------------------------
# Admissible parallelism (mirrors the NS-C005 scalability conditions)
# ---------------------------------------------------------------------------


def _scalable(jg: JobGraph, name: str) -> bool:
    jv = jg.vertices[name]
    if jv.is_source or not jg.in_edges(name):
        return False
    return all(e.pattern == ALL_TO_ALL
               for e in jg.in_edges(name) + jg.out_edges(name))


def _allowed_max(jg: JobGraph, name: str, throughput: Sequence[Any],
                 num_key_ranges: int | None) -> int:
    """Largest parallelism any scaling authority may ever set for ``name``:
    declared parallelism for unscalable stages, else key-range width capped
    by the tightest ThroughputConstraint.max_parallelism (the replica
    budget binds both the controller and the ScaleRequest countermeasure).
    """
    declared = jg.vertices[name].parallelism
    if not _scalable(jg, name):
        return declared
    cap = NUM_KEY_RANGES if num_key_ranges is None else max(1, num_key_ranges)
    for c in throughput:
        mp = getattr(c, "max_parallelism", None)
        if c.job_vertex == name and mp is not None:
            cap = min(cap, mp)
    return max(declared, cap)


# ---------------------------------------------------------------------------
# NS-F001 — §3 latency model over the configuration lattice
# ---------------------------------------------------------------------------


def _transport_ms(jg: JobGraph, src: str, spec: Any, net: Any,
                  num_workers: int | None) -> float:
    """Cheapest per-item transport for one channel out of ``src``:
    min(same-worker hand-off, cross-worker ship at line rate) on a
    multi-worker deployment, same-worker only when num_workers == 1.
    With no network model (threaded engine) transport is not priced."""
    if net is None:
        return 0.0
    nbytes = _item_bytes(jg, src, spec)
    same = float(net.same_worker_overhead_ms)
    cross = (float(net.per_buffer_overhead_ms)
             + nbytes / float(net.bandwidth_bytes_per_ms)
             + float(net.propagation_ms))
    if num_workers is not None and num_workers <= 1:
        return same
    return min(same, cross)


def _item_bytes(jg: JobGraph, src: str, spec: Any) -> int:
    if spec is not None:
        b = getattr(spec, "item_bytes", None)
        if isinstance(b, int) and b > 0:
            return b
    return max(0, int(getattr(jg.vertices[src], "sim_item_bytes", 0) or 0))


def _residency_ms(jg: JobGraph, src: str, spec: Any, eps_bytes: int,
                  lam_out: Mapping[str, float | None],
                  max_buffer_lifetime_ms: float | None) -> float:
    """Mean output-buffer residency with the buffer shrunk to the Eq. 2
    floor: a buffer holding k items ships when the k-th arrives, so the
    mean item waits (k-1)/2 inter-emission gaps.  Optimistically assumes
    the whole stage output funnels into the observed channel (densest
    fill, shortest wait) and returns 0 when the rate is unknown."""
    nbytes = _item_bytes(jg, src, spec)
    if nbytes <= 0:
        return 0.0
    k = -(-eps_bytes // nbytes)  # ceil: items until the floor capacity trips
    if k <= 1:
        return 0.0
    lam = lam_out.get(src)
    if lam is None or lam <= 0:
        return 0.0
    wait = (k - 1) / 2.0 * (1000.0 / lam)
    if max_buffer_lifetime_ms is not None:
        wait = min(wait, max_buffer_lifetime_ms / 2.0)  # obl = oblt/2
    return wait


def _check_latency(jg: JobGraph, c: Any, net: Any, num_workers: int | None,
                   policy: Any, max_buffer_lifetime_ms: float | None,
                   lam_out: Mapping[str, float | None],
                   ) -> list[Diagnostic]:
    seq = c.sequence
    limit = float(c.latency_limit_ms)
    if not limit > 0:
        return []  # NS-C003 already reported
    edges_in_graph = {(e.src, e.dst) for e in jg.edges}
    verts = seq.vertices()
    seq_edges = seq.edges()
    if (any(v not in jg.vertices for v in verts)
            or any(v not in jg.vertices for e in seq_edges for v in e)
            or any(e not in edges_in_graph for e in seq_edges)):
        return []  # structurally broken sequence: NS-C001/NS-C002 own it

    svc_sum = sum(float(jg.vertices[v].sim_cpu_ms) for v in verts)
    eps = int(getattr(policy, "eps_bytes", _DEFAULT_EPS_BYTES)
              or _DEFAULT_EPS_BYTES)
    # per-channel cost at the lattice's buffer floor; chain-eligible pairs
    # (adjacent *task* elements, §3.5.2 pre-computation) may zero theirs.
    # No net model (the threaded engine) means item sizes and transport are
    # runtime facts of user code — channel terms are then not priced, which
    # only makes the bound more optimistic (ERRORs stay sound).
    cost = {
        (s, d): 0.0 if net is None else (
            _transport_ms(jg, s, None, net, num_workers)
            + _residency_ms(jg, s, None, eps, lam_out,
                            max_buffer_lifetime_ms))
        for (s, d) in seq_edges
    }
    task_pairs = set(_adjacent_task_pairs(seq))
    chainable = [e for e in seq_edges
                 if e in task_pairs and _pair_chainable(jg, *e)]
    fixed = sum(v for e, v in cost.items() if e not in set(chainable))

    # walk the chain-subset lattice (exact min: every cost is >= 0, so the
    # all-chained corner is the optimum — the walk also yields the best
    # configuration for the message); cap the enumeration, extremes are
    # enough for the minimum
    n = len(chainable)
    masks = range(1 << n) if n <= 10 else (0, (1 << n) - 1)
    best = float("inf")
    best_mask = 0
    for mask in masks:
        bound = fixed + svc_sum + sum(
            cost[e] for i, e in enumerate(chainable) if not mask >> i & 1)
        if bound < best:
            best, best_mask = bound, mask
    if best <= limit * (1.0 + _REL_TOL):
        return []
    chained = [e for i, e in enumerate(chainable) if best_mask >> i & 1]
    how = (f"chained {','.join(f'{s}->{d}' for s, d in chained)}"
           if chained else "no chainable pair")
    return [diag(
        "NS-F001", f"constraint {getattr(c, 'name', '?')!r}",
        f"no configuration can satisfy latency_limit_ms={limit:g}: best "
        f"achievable ~= {best:.3f} ms ({svc_sum:.3f} ms summed service "
        f"time + {best - svc_sum:.3f} ms channel floor; {how}, buffers at "
        f"the {eps}B policy floor)")]


# ---------------------------------------------------------------------------
# NS-F003 / NS-F002 — throughput targets vs stage capacity
# ---------------------------------------------------------------------------


def _check_throughput(jg: JobGraph, c: Any,
                      caps: Mapping[str, int]) -> list[Diagnostic]:
    name = c.job_vertex
    if name not in jg.vertices:
        return []  # NS-C004 owns it
    target = float(getattr(c, "min_items_per_s", 0.0) or 0.0)
    svc = float(getattr(jg.vertices[name], "sim_cpu_ms", 0.0) or 0.0)
    if target <= 0 or svc <= 0:
        return []  # no target, or service time unknown: nothing to bound
    allowed = caps[name]
    capacity = allowed * 1000.0 / svc
    loc = f"throughput constraint {getattr(c, 'name', '?')!r}"
    if capacity < target * (1.0 - _REL_TOL):
        return [diag(
            "NS-F003", loc,
            f"min_items_per_s={target:g} for {name!r} is unreachable: best "
            f"achievable capacity ~= {capacity:.1f} items/s at the largest "
            f"admissible parallelism {allowed} "
            f"(sim_cpu_ms={svc:g} per item)")]
    declared = jg.vertices[name].parallelism
    required = 1  # smallest p with p * 1000/svc >= target (p <= allowed here)
    while required * 1000.0 / svc < target * (1.0 - _REL_TOL):
        required += 1
    if required > declared and required >= 0.9 * allowed:
        return [diag(
            "NS-F002", loc,
            f"min_items_per_s={target:g} for {name!r} needs parallelism "
            f">= {required} — within 10% of the admissible cap {allowed} "
            f"(declared {declared})")]
    return []


# ---------------------------------------------------------------------------
# NS-F004 / NS-F002 — stability under the declared rates
# ---------------------------------------------------------------------------


def _check_saturation(jg: JobGraph, lam_in: Mapping[str, float | None],
                      caps: Mapping[str, int]) -> list[Diagnostic]:
    out: list[Diagnostic] = []
    for name, jv in jg.vertices.items():
        lam = lam_in.get(name)
        svc = float(getattr(jv, "sim_cpu_ms", 0.0) or 0.0)
        if lam is None or lam <= 0 or svc <= 0 or jv.is_sink:
            continue
        allowed = caps[name]
        declared = jv.parallelism
        loc = f"job vertex {name!r}"
        stable_p = None
        for p in range(declared, allowed + 1):
            if (lam / p) * (svc / 1000.0) < 1.0 - _REL_TOL:
                stable_p = p
                break
        if stable_p is None:
            rho = (lam / allowed) * (svc / 1000.0)
            out.append(diag(
                "NS-F004", loc,
                f"declared rates offer {lam:g} items/s against "
                f"sim_cpu_ms={svc:g}: utilization {rho:.2f} >= 1 even at "
                f"the largest admissible parallelism {allowed}"))
        elif stable_p > declared and stable_p >= 0.9 * allowed:
            out.append(diag(
                "NS-F002", loc,
                f"declared rates ({lam:g} items/s, sim_cpu_ms={svc:g}) "
                f"need parallelism >= {stable_p} for utilization < 1 — "
                f"within 10% of the admissible cap {allowed} "
                f"(declared {declared})"))
    return out
