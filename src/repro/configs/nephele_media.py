"""The paper's evaluation job (§4.1, Fig. 5): live video aggregation.

Partitioner -> Decoder -> Merger -> Overlay -> Encoder -> RTP Server

Wiring (consistent with the paper's m^3 = 512e6 constrained-sequence count at
m = 800): Partitioner->Decoder and Encoder->RTPServer are all-to-all (m^2 and
m channel choices respectively), the middle edges are pointwise (a Decoder
owns whole stream groups, so the grouped frames flow subtask-to-subtask).

Per-item CPU costs and item sizes model the workload: H.264 packets are small
(~1.4 KB), decoded frames are large (320x240 YUV ~= 115 KB), merged/overlaid
frames likewise, encoded packets small again.  The simulator reproduces the
Fig. 7/8/9 behaviour with these numbers; the threaded engine uses real user
code (JAX image ops) from examples/media_pipeline_qos.py instead.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core import ALL_TO_ALL, POINTWISE, JobConstraint, JobGraph, JobSequence, JobVertex

# Paper evaluation parameters (§4.2).
PAPER_NODES = 200
PAPER_PARALLELISM = 800
PAPER_STREAMS = 6400
PAPER_GROUP_SIZE = 4
PAPER_LATENCY_LIMIT_MS = 300.0
PAPER_WINDOW_MS = 15_000.0
PAPER_INITIAL_BUFFER = 32 * 1024

# Simulator workload model (per item).
H264_PACKET_BYTES = 350            # compressed video NAL packet
FRAME_BYTES = 320 * 240 * 3 // 2   # decoded YUV frame ~= 115 KB
ENCODED_BYTES = 1_400

DECODE_CPU_MS = 0.9
MERGE_CPU_MS = 0.25
OVERLAY_CPU_MS = 0.35
ENCODE_CPU_MS = 1.1
PARTITION_CPU_MS = 0.02
SINK_CPU_MS = 0.02


@dataclass
class MediaJobParams:
    parallelism: int = 8
    num_workers: int = 2
    streams: int = 64
    fps: float = 25.0
    latency_limit_ms: float = PAPER_LATENCY_LIMIT_MS
    window_ms: float = PAPER_WINDOW_MS
    group_size: int = PAPER_GROUP_SIZE
    #: §3.6 fault-tolerance veto demo: forbid chaining across the Encoder
    unchainable_encoder: bool = False


def build_media_job(p: MediaJobParams) -> tuple[JobGraph, list[JobConstraint]]:
    jg = JobGraph("nephele-media")
    jg.add_vertex(JobVertex(
        "Partitioner", p.parallelism, sim_cpu_ms=PARTITION_CPU_MS,
        sim_item_bytes=H264_PACKET_BYTES, is_source=True))
    jg.add_vertex(JobVertex(
        "Decoder", p.parallelism, sim_cpu_ms=DECODE_CPU_MS,
        sim_item_bytes=FRAME_BYTES))
    jg.add_vertex(JobVertex(
        "Merger", p.parallelism, sim_cpu_ms=MERGE_CPU_MS,
        sim_item_bytes=FRAME_BYTES, sim_fan_in=p.group_size))
    jg.add_vertex(JobVertex(
        "Overlay", p.parallelism, sim_cpu_ms=OVERLAY_CPU_MS,
        sim_item_bytes=FRAME_BYTES))
    jg.add_vertex(JobVertex(
        "Encoder", p.parallelism, sim_cpu_ms=ENCODE_CPU_MS,
        sim_item_bytes=ENCODED_BYTES, chainable=not p.unchainable_encoder))
    jg.add_vertex(JobVertex(
        "RTPServer", p.parallelism, sim_cpu_ms=SINK_CPU_MS,
        sim_item_bytes=ENCODED_BYTES, is_sink=True))

    jg.add_edge("Partitioner", "Decoder", ALL_TO_ALL)
    jg.add_edge("Decoder", "Merger", POINTWISE)
    jg.add_edge("Merger", "Overlay", POINTWISE)
    jg.add_edge("Overlay", "Encoder", POINTWISE)
    jg.add_edge("Encoder", "RTPServer", ALL_TO_ALL)

    # §4.2: one constraint per runtime sequence of
    # S = (e1, v_D, e2, v_M, e3, v_O, e4, v_E, e5), l = 300 ms, t = 15 s.
    seq = JobSequence.of(
        ("Partitioner", "Decoder"), "Decoder",
        ("Decoder", "Merger"), "Merger",
        ("Merger", "Overlay"), "Overlay",
        ("Overlay", "Encoder"), "Encoder",
        ("Encoder", "RTPServer"),
    )
    jc = JobConstraint(seq, p.latency_limit_ms, p.window_ms, name="e2e-300ms")
    return jg, [jc]
