"""dbrx-132b [moe]: 40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352,
MoE 16 experts top-4, fine-grained.  [hf:databricks/dbrx-base; unverified]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b", family="moe",
        num_layers=40, d_model=6144, num_heads=48, num_kv_heads=8,
        d_ff=10752, vocab_size=100352,
        num_experts=16, experts_per_token=4,
        rope_theta=5e5, optimizer="adafactor", scan_remat_groups=8,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=384,
        num_experts=4, experts_per_token=2,
        attn_chunk=32, remat=False,
    )
