"""llama3-405b [dense]: 126L d_model=16384 128H (GQA kv=8) d_ff=53248
vocab=128256.  [arXiv:2407.21783; unverified]  Adafactor keeps optimizer
state within the 16 GB/chip HBM budget at 256 chips."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3-405b", family="dense",
        num_layers=126, d_model=16384, num_heads=128, num_kv_heads=8,
        d_ff=53248, vocab_size=128256,
        rope_theta=5e5, optimizer="adafactor", scan_remat_groups=14,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama3-405b-smoke", family="dense",
        num_layers=3, d_model=64, num_heads=8, num_kv_heads=2,
        d_ff=160, vocab_size=384,
        attn_chunk=32, remat=False,
    )
