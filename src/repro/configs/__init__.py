"""Architecture + job configs.

* ``nephele_media``  — the paper's own evaluation job (§4.1, Fig. 5).
* one ``<arch>.py`` per assigned architecture (``ARCHS`` registry below).
* ``shapes``         — the assigned input-shape sets.
"""

from .registry import ARCHS, get_config, list_archs  # noqa: F401
