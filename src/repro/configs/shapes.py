"""Assigned input shapes (LM-family: seq_len x global_batch).

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV
cache of seq_len), not ``train_step``.  ``long_500k`` requires sub-quadratic
attention — skipped for pure full-attention archs (see DESIGN.md
§Arch-applicability)."""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

#: archs with sub-quadratic sequence handling run long_500k; pure
#: full-attention archs skip it (noted in DESIGN.md).
LONG_CONTEXT_ARCHS = {"mixtral-8x7b", "mamba2-130m", "zamba2-7b"}


def cells(archs: list[str]) -> list[tuple[str, str]]:
    """All (arch, shape) dry-run cells, with the documented skips applied."""
    out = []
    for a in archs:
        for s in SHAPES:
            if s == "long_500k" and a not in LONG_CONTEXT_ARCHS:
                continue
            out.append((a, s))
    return out
