"""Registry of assigned architectures (populated by the per-arch modules)."""
from __future__ import annotations

import importlib

ARCHS: dict[str, str] = {
    # arch id -> module name under repro.configs
    "dbrx-132b": "dbrx_132b",
    "mixtral-8x7b": "mixtral_8x7b",
    "qwen3-1.7b": "qwen3_1p7b",
    "llama3.2-3b": "llama3p2_3b",
    "llama3-405b": "llama3_405b",
    "yi-6b": "yi_6b",
    "phi-3-vision-4.2b": "phi3_vision_4p2b",
    "mamba2-130m": "mamba2_130m",
    "zamba2-7b": "zamba2_7b",
    "whisper-tiny": "whisper_tiny",
}


def get_config(arch: str, smoke: bool = False):
    """Return the ModelConfig for an assigned architecture id."""
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch]}")
    return mod.smoke_config() if smoke else mod.config()


def list_archs() -> list[str]:
    return sorted(ARCHS)
