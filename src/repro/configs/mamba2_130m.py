"""mamba2-130m [ssm]: 24L d_model=768 (attention-free) vocab=50280,
ssm_state=128, SSD (state-space duality).  [arXiv:2405.21060; unverified]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m", family="ssm",
        num_layers=24, d_model=768, num_heads=0, num_kv_heads=0,
        d_ff=0, vocab_size=50280,
        ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_chunk=128,
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke", family="ssm",
        num_layers=2, d_model=64, num_heads=0, num_kv_heads=0,
        d_ff=0, vocab_size=384,
        ssm_state=16, ssm_expand=2, ssm_head_dim=16, ssm_chunk=16,
        tie_embeddings=True, remat=False,
    )
