"""qwen3-1.7b [dense]: 28L d_model=2048 16H (GQA kv=8) d_ff=6144
vocab=151936, qk_norm.  [hf:Qwen/Qwen3-8B; hf]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-1.7b", family="dense",
        num_layers=28, d_model=2048, num_heads=16, num_kv_heads=8,
        d_ff=6144, vocab_size=151936, d_head=128,
        qk_norm=True, rope_theta=1e6, tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=384, qk_norm=True, tie_embeddings=True,
        attn_chunk=32, remat=False,
    )
