"""phi-3-vision-4.2b [vlm]: 32L d_model=3072 32H (GQA kv=32) d_ff=8192
vocab=32064; phi3-mini backbone + CLIP frontend.
[hf:microsoft/Phi-3-vision-128k-instruct; hf]

The CLIP image tower is a STUB per the assignment: input_specs() provides
576 precomputed patch embeddings (336px / 14px CLIP grid) which the backbone
projects and prepends to the token sequence."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-4.2b", family="vlm",
        num_layers=32, d_model=3072, num_heads=32, num_kv_heads=32,
        d_ff=8192, vocab_size=32064,
        num_patches=576, rope_theta=1e4,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="phi3v-smoke", family="vlm",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=384, num_patches=16,
        attn_chunk=32, remat=False,
    )
