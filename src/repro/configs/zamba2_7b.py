"""zamba2-7b [hybrid]: 81L d_model=3584 32H (GQA kv=32) d_ff=14336
vocab=32000, ssm_state=64; Mamba-2 backbone + shared attention block.
[arXiv:2411.15242; unverified]

The shared attention+MLP block (one set of weights) is applied after every
6 backbone layers (13 applications + 3 tail layers); see zamba.py for the
recorded simplifications.  At 500k decode the shared attention uses a
rolling 4096 window (the SSM carries long-range state)."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b", family="hybrid",
        num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
        d_ff=14336, vocab_size=32000,
        ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_chunk=64,
        attn_every=6, sliding_window=4096, optimizer="adafactor",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke", family="hybrid",
        num_layers=5, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=384,
        ssm_state=16, ssm_expand=2, ssm_head_dim=16, ssm_chunk=16,
        attn_every=2, sliding_window=32, attn_chunk=16, remat=False,
    )
