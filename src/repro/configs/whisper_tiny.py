"""whisper-tiny [audio]: enc-dec, 4L d_model=384 6H d_ff=1536 vocab=51865;
conv frontend is a STUB (input_specs provides precomputed frame embeddings).
[arXiv:2212.04356; unverified]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny", family="encdec",
        num_layers=4, d_model=384, num_heads=6, num_kv_heads=6,
        d_ff=1536, vocab_size=51865,
        encoder_layers=4, max_source_positions=1500,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke", family="encdec",
        num_layers=2, d_model=48, num_heads=3, num_kv_heads=3,
        d_ff=96, vocab_size=384,
        encoder_layers=2, max_source_positions=32,
        attn_chunk=16, remat=False,
    )
