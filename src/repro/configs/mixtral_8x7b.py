"""mixtral-8x7b [moe]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, MoE 8 experts top-2, sliding-window attention (4096).
[arXiv:2401.04088; hf]  SWA makes long_500k runnable (rolling KV cache)."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b", family="moe",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
        d_ff=14336, vocab_size=32000,
        num_experts=8, experts_per_token=2,
        sliding_window=4096, rope_theta=1e6, optimizer="adafactor",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=384,
        num_experts=4, experts_per_token=2,
        sliding_window=16, attn_chunk=16, remat=False,
    )
