"""Streaming token data pipeline.

Two front-ends over the same stages (read -> tokenize -> pack -> batch):

* ``PackedBatchIterator`` — the fast in-process iterator used by the train
  driver; deterministic, replayable from an offset (the checkpointing story
  for data: a restore replays from the recorded document offset, the
  log-based rollback-recovery analogue from paper §3.6),
* ``build_streaming_pipeline_job`` — the same stages as a Nephele JobGraph
  running on the core streaming engine with QoS constraints attached, which
  is how the paper's technique manages the *input* side of training at
  scale (benchmarks/serving_qos.py exercises it).
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass

import numpy as np

from ..core import ALL_TO_ALL, POINTWISE, JobConstraint, JobGraph, JobSequence, JobVertex


class ByteTokenizer:
    """UTF-8 byte tokenizer with a small reserved-id header (pad/bos/eos)."""

    PAD, BOS, EOS = 0, 1, 2
    OFFSET = 3

    @property
    def vocab_size(self) -> int:
        return 256 + self.OFFSET

    def encode(self, text: str) -> list[int]:
        return [self.BOS] + [b + self.OFFSET for b in text.encode("utf-8")] + [
            self.EOS]

    def decode(self, ids) -> str:
        bs = bytes(max(0, int(i) - self.OFFSET) for i in ids
                   if int(i) >= self.OFFSET)
        return bs.decode("utf-8", errors="replace")


@dataclass
class SyntheticCorpus:
    """Deterministic synthetic corpus: structured pseudo-text documents (so
    a ~100M model has something learnable: repeated n-gram structure)."""

    num_documents: int = 100_000
    seed: int = 0

    _WORDS = (
        "stream process latency throughput buffer chain task channel qos "
        "constraint vertex edge worker manager report tag window adaptive "
        "dynamic graph sequence violation measure interval cluster node"
    ).split()

    def document(self, idx: int) -> str:
        h = int.from_bytes(
            hashlib.blake2b(
                f"{self.seed}:{idx}".encode(), digest_size=8
            ).digest(),
            "little",
        )
        rng = np.random.default_rng(h)
        n = int(rng.integers(20, 200))
        words = rng.choice(self._WORDS, size=n)
        # inject learnable bigram structure
        out = []
        for i, w in enumerate(words):
            out.append(str(w))
            if w == "qos" and rng.random() < 0.9:
                out.append("constraint")
        return " ".join(out)

    def __iter__(self):
        for i in range(self.num_documents):
            yield i, self.document(i)


class PackedBatchIterator:
    """Documents -> token stream -> packed [batch, seq_len] next-token pairs.

    ``state()``/``restore()`` expose the replay offset for checkpointing.
    """

    def __init__(self, corpus: SyntheticCorpus, tokenizer: ByteTokenizer,
                 batch: int, seq_len: int, start_doc: int = 0) -> None:
        self.corpus = corpus
        self.tok = tokenizer
        self.batch = batch
        self.seq_len = seq_len
        self.doc_idx = start_doc
        self._buf: list[int] = []

    def state(self) -> dict:
        # the partial token buffer is part of the replay state: doc_idx alone
        # would skip the already-consumed tail of the current document
        return {"doc_idx": self.doc_idx, "buf": list(self._buf)}

    def restore(self, state: dict) -> None:
        self.doc_idx = int(state["doc_idx"])
        self._buf = [int(t) for t in state.get("buf", [])]

    def _fill(self, need: int) -> None:
        while len(self._buf) < need:
            self._buf.extend(
                self.tok.encode(self.corpus.document(self.doc_idx)))
            self.doc_idx = (self.doc_idx + 1) % self.corpus.num_documents

    def __iter__(self):
        return self

    def __next__(self):
        n = self.batch * (self.seq_len + 1)
        self._fill(n)
        flat = np.asarray(self._buf[:n], dtype=np.int32)
        self._buf = self._buf[n:]
        arr = flat.reshape(self.batch, self.seq_len + 1)
        return {"tokens": arr[:, :-1], "labels": arr[:, 1:]}


# ---------------------------------------------------------------------------
# The same pipeline as a QoS-managed streaming job (paper-style)
# ---------------------------------------------------------------------------


def build_streaming_pipeline_job(
    parallelism: int = 4,
    latency_limit_ms: float = 100.0,
    window_ms: float = 5_000.0,
) -> tuple[JobGraph, list[JobConstraint]]:
    """Reader -> Tokenizer -> Packer -> BatchSink as a job graph with a
    latency constraint on the tokenize->pack path; run it on
    core.StreamEngine / StreamSimulator."""
    tok = ByteTokenizer()
    corpus = SyntheticCorpus()

    def tokenize(payload, emit, ctx):
        idx, text = payload
        emit((idx, tok.encode(text)), size_bytes=len(text) + 16)

    def pack(payload, emit, ctx):
        # stateful packing per task instance
        st = getattr(ctx, "_pack_buf", None)
        if st is None:
            st = ctx._pack_buf = []
        idx, ids = payload
        st.extend(ids)
        seq = 257
        while len(st) >= seq:
            emit((idx, st[:seq]), size_bytes=seq * 4)
            del st[:seq]

    jg = JobGraph("data-pipeline")
    jg.add_vertex(JobVertex("Reader", parallelism, is_source=True,
                            sim_cpu_ms=0.01, sim_item_bytes=512))
    jg.add_vertex(JobVertex("Tokenizer", parallelism, fn=tokenize,
                            sim_cpu_ms=0.05, sim_item_bytes=1024))
    jg.add_vertex(JobVertex("Packer", parallelism, fn=pack,
                            sim_cpu_ms=0.02, sim_item_bytes=1028))
    jg.add_vertex(JobVertex("BatchSink", parallelism, is_sink=True,
                            sim_cpu_ms=0.01, sim_item_bytes=1028))
    jg.add_edge("Reader", "Tokenizer", ALL_TO_ALL)
    jg.add_edge("Tokenizer", "Packer", POINTWISE)
    jg.add_edge("Packer", "BatchSink", ALL_TO_ALL)

    seq = JobSequence.of(
        ("Reader", "Tokenizer"), "Tokenizer", ("Tokenizer", "Packer"),
        "Packer", ("Packer", "BatchSink"),
    )
    jc = JobConstraint(seq, latency_limit_ms, window_ms, name="pipeline-lat")
    return jg, [jc]
