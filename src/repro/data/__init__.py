"""Streaming data pipeline."""

from .pipeline import (  # noqa: F401
    ByteTokenizer,
    PackedBatchIterator,
    SyntheticCorpus,
    build_streaming_pipeline_job,
)
