"""Job graph / runtime graph formalism (paper §3.1).

A *job graph* ``JG = (JV, JE)`` is the compact, user-provided description of a
streaming job: vertices carry user code and a degree of parallelism, edges
declare who talks to whom and with which wiring pattern.

The *runtime graph* ``G = (V, E)`` is the parallelized expansion used by the
execution framework: each job vertex becomes ``parallelism`` runtime vertices
(tasks), each job edge becomes a set of channels.  Every runtime vertex is
allocated to a *worker node*; ``worker(v)`` denotes that mapping, and the
mapping itself is owned by a ``WorkerPool`` (core/placement.py) whose
placement policy decides where expansion and elastic growth land — and
whether a saturated pool acquires a fresh worker.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import cached_property
from typing import Any, Callable, Iterable, Sequence

from ..analysis.diagnostics import GraphValidationError, diag, fail
from .placement import WorkerPool
from .routing import KeyRouter

# ---------------------------------------------------------------------------
# Job graph
# ---------------------------------------------------------------------------

#: Wiring patterns for job edges.  ``ALL_TO_ALL`` connects every subtask of the
#: producer to every subtask of the consumer (the paper's Partitioner->Decoder
#: edges); ``POINTWISE`` connects subtask i to subtask i (requires equal
#: parallelism on both sides).
ALL_TO_ALL = "all_to_all"
POINTWISE = "pointwise"


@dataclass(frozen=True)
class JobVertex:
    """A vertex of the job graph: user code + degree of parallelism.

    ``chainable=False`` is the §3.6 fault-tolerance annotation: it vetoes
    dynamic task chaining *into or out of* this vertex so that materialization
    points for log-based rollback-recovery stay intact.
    """

    name: str
    parallelism: int = 1
    #: user code: fn(item, emit, ctx) -> None.  ``emit(out_item)`` forwards.
    fn: Callable[..., Any] | None = None
    #: per-item CPU cost in ms (used by the simulator; ignored by the
    #: threaded engine, which measures real CPU time).
    sim_cpu_ms: float = 0.0
    #: average emitted item size in bytes (simulator only).
    sim_item_bytes: int = 128
    #: how many input items produce one output item (simulator only);
    #: e.g. the Merger consumes 4 frames -> 1 merged frame.
    sim_fan_in: int = 1
    chainable: bool = True
    is_source: bool = False
    is_sink: bool = False
    #: batch mode: the task consumes a whole delivered output buffer at once
    #: (fn receives the list of payloads) — serving stages batch this way,
    #: which is exactly what makes the output-buffer size the batch-size
    #: knob (DESIGN.md §2.2)
    batch_fn: bool = False
    #: keyed state: each subtask holds a per-key ``StateStore``
    #: (core/routing.py) and key ownership is enforced at processing time, so
    #: elastic rescaling migrates the moved key ranges' state.  The threaded
    #: engine exposes the store to user code as ``ctx.state``; the simulator
    #: maintains a per-key processed-item count automatically (its tasks are
    #: cost models without user code).  Stateful vertices also veto dynamic
    #: task chaining (a fused stage bypasses KeyRouter ownership), like
    #: ``chainable=False``.  Stateful sources are not supported.  A stateful
    #: ``batch_fn`` stage has each delivered buffer split at key-ownership
    #: boundaries before its fn runs (foreign sub-batches are forwarded to
    #: their owners), so even mixed-key batches keep single-owner state.
    stateful: bool = False

    def __repr__(self) -> str:  # compact
        return f"JobVertex({self.name} x{self.parallelism})"


@dataclass(frozen=True)
class JobEdge:
    src: str
    dst: str
    pattern: str = ALL_TO_ALL

    def __repr__(self) -> str:
        return f"JobEdge({self.src}->{self.dst}, {self.pattern})"


class JobGraph:
    """DAG of job vertices and job edges (paper §3.1.1)."""

    def __init__(self, name: str = "job") -> None:
        self.name = name
        self.vertices: dict[str, JobVertex] = {}
        self.edges: list[JobEdge] = []

    # -- construction -------------------------------------------------------
    # build-time checks raise through the shared analysis rule registry
    # (analysis/diagnostics.py) so their rule ids and wording match the
    # pre-flight validator's (analysis/graph_check.py) exactly.
    def add_vertex(self, v: JobVertex) -> JobVertex:
        if v.name in self.vertices:
            fail("NS-G001", f"job vertex {v.name!r}",
                 f"duplicate job vertex {v.name!r}")
        self.vertices[v.name] = v
        return v

    def add_edge(self, src: str, dst: str, pattern: str = ALL_TO_ALL) -> JobEdge:
        for name in (src, dst):
            if name not in self.vertices:
                fail("NS-G002", f"job edge {src}->{dst}",
                     f"unknown job vertex {name!r}")
        if pattern == POINTWISE and (
            self.vertices[src].parallelism != self.vertices[dst].parallelism
        ):
            fail("NS-G003", f"job edge {src}->{dst}",
                 f"POINTWISE edge requires equal parallelism "
                 f"({src} x{self.vertices[src].parallelism} vs "
                 f"{dst} x{self.vertices[dst].parallelism})")
        e = JobEdge(src, dst, pattern)
        self.edges.append(e)
        self._check_acyclic()
        return e

    # -- queries -------------------------------------------------------------
    def out_edges(self, name: str) -> list[JobEdge]:
        return [e for e in self.edges if e.src == name]

    def in_edges(self, name: str) -> list[JobEdge]:
        return [e for e in self.edges if e.dst == name]

    def edge(self, src: str, dst: str) -> JobEdge:
        for e in self.edges:
            if e.src == src and e.dst == dst:
                return e
        raise KeyError(f"no job edge {src}->{dst}")

    def topological_order(self) -> list[str]:
        indeg = {n: 0 for n in self.vertices}
        for e in self.edges:
            indeg[e.dst] += 1
        stack = sorted(n for n, d in indeg.items() if d == 0)
        order: list[str] = []
        while stack:
            n = stack.pop()
            order.append(n)
            for e in self.out_edges(n):
                indeg[e.dst] -= 1
                if indeg[e.dst] == 0:
                    stack.append(e.dst)
        if len(order) != len(self.vertices):
            fail("NS-G004", f"job graph {self.name!r}",
                 "job graph contains a cycle")
        return order

    def _check_acyclic(self) -> None:
        self.topological_order()

    def sources(self) -> list[str]:
        return [n for n in self.vertices if not self.in_edges(n)]

    def sinks(self) -> list[str]:
        return [n for n in self.vertices if not self.out_edges(n)]


# ---------------------------------------------------------------------------
# Runtime graph
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RuntimeVertex:
    """A task: one parallel instance of a job vertex (paper §3.1.2).

    ``id`` is cached on first access: both execution backends key telemetry
    by it on their per-item hot paths, and recomputing the f-string
    dominated simulator profiles before it was memoized.
    """

    job_vertex: str
    index: int

    @cached_property
    def id(self) -> str:
        return f"{self.job_vertex}[{self.index}]"

    def __repr__(self) -> str:
        return self.id


@dataclass(frozen=True)
class Channel:
    """A runtime edge: a channel along which ``src`` sends items to ``dst``.

    ``id`` is cached for the same hot-path reason as ``RuntimeVertex.id``.
    """

    src: RuntimeVertex
    dst: RuntimeVertex

    @cached_property
    def id(self) -> str:
        return f"{self.src.id}->{self.dst.id}"

    @property
    def job_edge(self) -> tuple[str, str]:
        return (self.src.job_vertex, self.dst.job_vertex)

    def __repr__(self) -> str:
        return self.id


class RuntimeGraph:
    """Parallelized job graph + worker allocation (paper §3.1.2).

    ``worker(v)`` maps every runtime vertex to a worker node.  Placement is
    delegated to a ``WorkerPool`` (core/placement.py): the default pool uses
    the ``modulo`` policy, spreading each job vertex's subtasks evenly across
    a fixed fleet the way the paper's evaluation does ("eight tasks of each
    type per node"); elastic pools (``packed``/``spread`` + per-vertex
    affinity) additionally acquire workers when placement saturates and let
    the re-wiring layer release them once emptied.
    """

    def __init__(self, job_graph: JobGraph, num_workers: int | None = None,
                 allocator: Callable[[RuntimeVertex, int], int] | None = None,
                 pool: WorkerPool | None = None,
                 num_key_ranges: int | None = None):
        self.job_graph = job_graph
        if pool is None:
            if num_workers is None:
                raise ValueError("need num_workers or an explicit pool")
            pool = WorkerPool(num_workers)
        self.pool = pool
        #: virtual key ranges per consumer-group router.  The default
        #: (routing.NUM_KEY_RANGES = 128) caps a keyed stage's addressable
        #: parallelism at 128 subtasks; paper-scale jobs (m >= 200, e.g.
        #: benchmarks/scale.py) pass a larger power of two.  Keep the
        #: default for anything covered by the determinism goldens — the
        #: range count changes which keys migrate on rescale.
        self.num_key_ranges = num_key_ranges
        #: size of the initial fleet (legacy attribute; live count is
        #: ``pool.size()`` / ``stats()["workers"]``)
        self.num_workers = pool.initial_workers
        self.vertices: list[RuntimeVertex] = []
        self.channels: list[Channel] = []
        self._by_job_vertex: dict[str, list[RuntimeVertex]] = {}
        self._worker: dict[RuntimeVertex, int] = {}
        self._out: dict[RuntimeVertex, list[Channel]] = {}
        self._in: dict[RuntimeVertex, list[Channel]] = {}
        self._by_job_edge: dict[tuple[str, str], list[Channel]] = {}
        #: one KeyRouter per consumer group (job vertex): the single
        #: key-range -> subtask table both backends route keyed items with.
        #: Rescaling goes plan -> migrate state -> commit (core/elastic.py);
        #: grow_vertex/shrink_vertex deliberately do NOT touch the routers.
        self.routers: dict[str, KeyRouter] = {}
        self._expand(allocator)

    # -- expansion -----------------------------------------------------------
    def _place(self, rv: RuntimeVertex,
               allocator: Callable[[RuntimeVertex, int], int] | None) -> int:
        """Placement for one task: the pool's policy, unless a legacy custom
        allocator decides (its choice is still recorded with the pool so
        load/release bookkeeping stays truthful)."""
        if allocator is not None:
            w = allocator(rv, self.num_workers)
            self.pool.assign(rv, w)
            return w
        return self.pool.place(rv)

    def _expand(self, allocator: Callable[[RuntimeVertex, int], int] | None
                ) -> None:
        jg = self.job_graph
        for name, jv in jg.vertices.items():
            group = []
            for i in range(jv.parallelism):
                rv = RuntimeVertex(name, i)
                self.vertices.append(rv)
                self._worker[rv] = self._place(rv, allocator)
                self._out[rv] = []
                self._in[rv] = []
                group.append(rv)
            self._by_job_vertex[name] = group
            try:
                self.routers[name] = (
                    KeyRouter(jv.parallelism) if self.num_key_ranges is None
                    else KeyRouter(jv.parallelism, self.num_key_ranges))
            except ValueError as e:
                # unaddressable parallelism (more subtasks than key ranges;
                # core/routing.py fails fast) — name the graph-level knob
                raise GraphValidationError([diag(
                    "NS-R001", f"job vertex {name!r}",
                    f"{e}; pass num_key_ranges >= {jv.parallelism} "
                    f"(a power of two) to RuntimeGraph / StreamSimulator / "
                    f"StreamEngine")]) from None
        for je in jg.edges:
            chans: list[Channel] = []
            src_group = self._by_job_vertex[je.src]
            dst_group = self._by_job_vertex[je.dst]
            if je.pattern == POINTWISE:
                pairs = zip(src_group, dst_group)
            else:
                pairs = ((s, d) for s in src_group for d in dst_group)
            for s, d in pairs:
                ch = Channel(s, d)
                chans.append(ch)
                self.channels.append(ch)
                self._out[s].append(ch)
                self._in[d].append(ch)
            self._by_job_edge[(je.src, je.dst)] = chans

    # -- queries -------------------------------------------------------------
    def worker(self, v: RuntimeVertex) -> int:
        return self._worker[v]

    def tasks_of(self, job_vertex: str) -> list[RuntimeVertex]:
        return self._by_job_vertex[job_vertex]

    def channels_of(self, src_jv: str, dst_jv: str) -> list[Channel]:
        return self._by_job_edge[(src_jv, dst_jv)]

    def out_channels(self, v: RuntimeVertex) -> list[Channel]:
        return self._out[v]

    def in_channels(self, v: RuntimeVertex) -> list[Channel]:
        return self._in[v]

    def vertices_on_worker(self, w: int) -> list[RuntimeVertex]:
        return [v for v in self.vertices if self._worker[v] == w]

    def worker_ids(self) -> list[int]:
        """Live worker ids (the pool's fleet), plus any worker still
        referenced by a vertex (covers custom-allocator placements)."""
        ids = set(self.pool.worker_ids())
        ids.update(self._worker.values())
        return sorted(ids)

    def num_runtime_edges(self, je_src: str, je_dst: str) -> int:
        return len(self._by_job_edge[(je_src, je_dst)])

    def stats(self) -> dict[str, int]:
        return {
            "vertices": len(self.vertices),
            "channels": len(self.channels),
            "workers": self.pool.size(),
        }

    # -- elastic re-parallelization (paper §6 future work; core/elastic.py) --
    def _check_elastic_edges(self, job_vertex: str, verb: str) -> None:
        jg = self.job_graph
        for e in jg.in_edges(job_vertex) + jg.out_edges(job_vertex):
            if e.pattern != ALL_TO_ALL:
                raise ValueError(
                    f"cannot {verb} {job_vertex}: edge {e} is {e.pattern}")

    def grow_vertex(self, job_vertex: str, new_parallelism: int
                    ) -> tuple[list[RuntimeVertex], list[Channel]]:
        """Add subtasks to ``job_vertex`` and wire them with the existing
        job-edge patterns.  Only ALL_TO_ALL neighbourhoods are growable
        (POINTWISE wiring pins parallelism to the peer's)."""
        jg = self.job_graph
        self._check_elastic_edges(job_vertex, "grow")
        group = self._by_job_vertex[job_vertex]
        old_n = len(group)
        if new_parallelism <= old_n:
            return [], []
        new_vs: list[RuntimeVertex] = []
        new_cs: list[Channel] = []
        for i in range(old_n, new_parallelism):
            rv = RuntimeVertex(job_vertex, i)
            # policy placement first (it may raise PoolSaturated on an
            # unmatchable affinity): an elastic pool may acquire a fresh
            # worker here when every matching worker is at capacity
            w = self.pool.place(rv)
            self.vertices.append(rv)
            self._worker[rv] = w
            self._out[rv] = []
            self._in[rv] = []
            group.append(rv)
            new_vs.append(rv)
            for e in jg.in_edges(job_vertex):
                for src in self._by_job_vertex[e.src]:
                    ch = Channel(src, rv)
                    self.channels.append(ch)
                    self._out[src].append(ch)
                    self._in[rv].append(ch)
                    self._by_job_edge[(e.src, job_vertex)].append(ch)
                    new_cs.append(ch)
            for e in jg.out_edges(job_vertex):
                for dst in self._by_job_vertex[e.dst]:
                    ch = Channel(rv, dst)
                    self.channels.append(ch)
                    self._out[rv].append(ch)
                    self._in[dst].append(ch)
                    self._by_job_edge[(job_vertex, e.dst)].append(ch)
                    new_cs.append(ch)
        return new_vs, new_cs

    def shrink_vertex(self, job_vertex: str, new_parallelism: int
                      ) -> tuple[list[RuntimeVertex], list[Channel]]:
        """Retire the highest-index subtasks of ``job_vertex`` down to
        ``new_parallelism`` and unlink their channels.  Returns the retired
        vertices and removed channels; the execution layer is responsible for
        draining the retired tasks before it stops them.

        The ``worker(v)`` mapping of retired vertices is intentionally kept
        (in-flight items and late telemetry may still reference them while
        the backend quiesces), but their pool slots are dropped so emptied
        workers become releasable by the re-wiring layer.
        """
        self._check_elastic_edges(job_vertex, "shrink")
        group = self._by_job_vertex[job_vertex]
        old_n = len(group)
        if new_parallelism >= old_n or new_parallelism < 1:
            return [], []
        retired = group[new_parallelism:]
        del group[new_parallelism:]
        retired_set = set(retired)
        removed_cs = [c for c in self.channels
                      if c.src in retired_set or c.dst in retired_set]
        removed_set = set(removed_cs)
        self.vertices = [v for v in self.vertices if v not in retired_set]
        self.channels = [c for c in self.channels if c not in removed_set]
        for v in retired:
            self._out.pop(v, None)
            self._in.pop(v, None)
            self.pool.unassign(v)
        for c in removed_cs:
            if c.src not in retired_set:
                self._out[c.src] = [x for x in self._out[c.src] if x != c]
            if c.dst not in retired_set:
                self._in[c.dst] = [x for x in self._in[c.dst] if x != c]
        for key, chans in self._by_job_edge.items():
            if job_vertex in key:
                self._by_job_edge[key] = [
                    c for c in chans if c not in removed_set
                ]
        return retired, removed_cs


# ---------------------------------------------------------------------------
# Subgraphs (QoS manager scope)
# ---------------------------------------------------------------------------


@dataclass
class RuntimeSubgraph:
    """A subgraph ``G_i = (V_i, E_i)`` assigned to one QoS manager (§3.4).

    ``job_path`` records the constrained job-graph path this subgraph was
    expanded for, which lets the manager enumerate the sequences it owns.
    """

    vertices: set[RuntimeVertex] = field(default_factory=set)
    channels: set[Channel] = field(default_factory=set)
    job_paths: list[tuple[str, ...]] = field(default_factory=list)

    def merge(self, other: "RuntimeSubgraph") -> None:
        self.vertices |= other.vertices
        self.channels |= other.channels
        for p in other.job_paths:
            if p not in self.job_paths:
                self.job_paths.append(p)

    def out_channels(self, v: RuntimeVertex) -> list[Channel]:
        return [c for c in self.channels if c.src == v]

    def in_channels(self, v: RuntimeVertex) -> list[Channel]:
        return [c for c in self.channels if c.dst == v]

    def __contains__(self, item: RuntimeVertex | Channel) -> bool:
        if isinstance(item, RuntimeVertex):
            return item in self.vertices
        return item in self.channels

    def size(self) -> tuple[int, int]:
        return len(self.vertices), len(self.channels)
