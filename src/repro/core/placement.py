"""Worker placement layer: first-class workers with elastic acquire/release.

The paper's runtime graph allocates every task to a *worker node* (§3.1.2:
``worker(v)``), and dynamic task chaining (§3.5.2) is only legal within one
worker — yet until this module the mapping was a bare ``index %
num_workers`` expression, so placement could not spread load, scale-in could
not retire chained tasks, and nothing modeled the cloud's ability to add or
remove machines (§6: "exploit the capability of a cloud to elastically
scale on demand").  Röger & Mayer's elasticity survey (PAPERS.md) identifies
operator placement and live reconfiguration as the two mechanisms that must
compose for elastic stream processing; this module is the placement half.

* ``Worker`` — a first-class runtime entity: id, task-slot capacity, and a
  tag set (machine class / capability labels, e.g. ``{"accel"}``).
* ``WorkerPool`` — owns the live worker set and the task -> worker
  assignment load.  ``acquire()`` models cloud worker acquisition (new id,
  never reused, bounded by ``max_workers``); ``release(w)`` returns an
  **empty** worker to the cloud — releasing a worker that still hosts tasks
  raises, which is the invariant the property tests pin down.  Workers of
  the initial fleet are never released, so a grow -> shrink round trip
  returns the pool to its initial size.
* placement policies (``place(v)``):
    - ``MODULO`` ("modulo") — the paper's testbed layout, ``index %
      initial_fleet`` ("eight tasks of each type per node"); never acquires.
      This is the default and reproduces the historical allocation exactly.
    - ``PACKED`` ("packed") — fill the lowest-id worker with a free slot
      before touching the next; acquires only when every worker is full.
      Maximizes co-location (chaining opportunity), minimizes fleet size.
    - ``SPREAD`` ("spread") — least-loaded worker first; acquires as soon
      as every worker is at capacity.  Maximizes load spreading at the cost
      of cross-worker channels.
  Both elastic policies honour per-vertex **affinity**: ``affinity`` maps a
  job vertex to the tag set its tasks require, candidate workers are
  filtered to those carrying every required tag, and a worker acquired on
  behalf of such a vertex is provisioned with exactly those tags (the cloud
  hands you the machine class you asked for).  Affinity also expresses
  constraint-aware co-location: two job vertices that share an exclusive
  tag can only ever land on the same (tagged) workers, which is what makes
  their tasks chainable.

The execution layers consume this through ``RuntimeGraph`` (which delegates
``worker(v)`` to the pool) and ``RuntimeRewirer`` (core/elastic.py), which
places spawned subtasks through the policy on ``scale_out`` — acquiring a
worker when the pool saturates — and releases emptied non-initial workers on
``scale_in``.  Both executors derive their local-vs-remote channel cost
(same-worker shared-memory hand-over vs. serialize + ship) from the same
``worker(v)`` mapping, so the QoS manager's latency estimates see placement
locality, and the §3.5.2 co-location precondition for chaining is evaluated
against it.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Iterable, Mapping

from ..analysis import diagnostics as _diagnostics

if TYPE_CHECKING:  # pragma: no cover - import cycle guard (graphs -> placement)
    from .graphs import RuntimeVertex

MODULO = "modulo"
PACKED = "packed"
SPREAD = "spread"

POLICIES = (MODULO, PACKED, SPREAD)


@dataclass(frozen=True)
class Worker:
    """One worker node: identity, capacity, and capability tags."""

    id: int
    #: task slots; None = unbounded (the legacy modulo fleet)
    slots: int | None = None
    tags: frozenset[str] = frozenset()

    def __repr__(self) -> str:
        t = f",tags={set(self.tags)}" if self.tags else ""
        return f"Worker({self.id},slots={self.slots}{t})"


@dataclass(frozen=True)
class PoolEvent:
    """Acquire/release/death audit record (the pool has no clock; the
    re-wiring layer stamps its ScaleDecision / recovery logs instead)."""

    kind: str  # "acquire" | "release" | "dead"
    worker: int
    reason: str = ""


class PoolSaturated(RuntimeError):
    """Placement needed a new worker but ``max_workers`` was reached and no
    existing worker matched the vertex's affinity tags."""


class WorkerPool:
    """Live worker set + task assignments + pluggable placement policy.

    Thread-safe: the threaded engine places/unassigns from its control and
    rescale paths concurrently with telemetry reads.
    """

    def __init__(
        self,
        initial_workers: int,
        *,
        policy: str = MODULO,
        slots_per_worker: int | None = None,
        max_workers: int | None = None,
        affinity: Mapping[str, Iterable[str]] | None = None,
        worker_tags: Mapping[int, Iterable[str]] | None = None,
    ) -> None:
        if initial_workers < 1:
            raise ValueError("initial_workers must be >= 1")
        if policy not in POLICIES:
            raise ValueError(f"unknown placement policy {policy!r}")
        if policy != MODULO and slots_per_worker is None:
            raise ValueError(f"policy {policy!r} needs slots_per_worker "
                             f"(capacity is what triggers acquisition)")
        self.policy = policy
        self.slots_per_worker = slots_per_worker
        self.initial_workers = initial_workers
        self.max_workers = max_workers
        self.affinity: dict[str, frozenset[str]] = {
            jv: frozenset(tags) for jv, tags in (affinity or {}).items()
        }
        self._lock = threading.Lock()
        worker_tags = worker_tags or {}
        self.workers: dict[int, Worker] = {
            w: Worker(w, slots_per_worker,
                      frozenset(worker_tags.get(w, ())))
            for w in range(initial_workers)
        }
        self._next_id = initial_workers
        #: worker -> ids of tasks currently assigned there
        self._assigned: dict[int, set[str]] = {
            w: set() for w in self.workers
        }
        #: task id -> worker (reverse index; authoritative load bookkeeping)
        self._task_worker: dict[str, int] = {}
        #: workers declared dead by the recovery path; their ids are
        #: quarantined forever (never placement candidates, never reused)
        self._dead: set[int] = set()
        #: dead worker -> the replacement acquired for it, so the MODULO
        #: policy's ``index % initial_fleet`` arithmetic keeps resolving
        #: after a member of the initial fleet dies
        self._reincarnation: dict[int, int] = {}
        self.events: list[PoolEvent] = []

    # -- queries -------------------------------------------------------------
    def worker_ids(self) -> list[int]:
        with self._lock:
            return sorted(self.workers)

    def size(self) -> int:
        with self._lock:
            return len(self.workers)

    def load(self, worker: int) -> int:
        with self._lock:
            return len(self._assigned.get(worker, ()))

    def loads(self) -> dict[int, int]:
        with self._lock:
            return {w: len(ts) for w, ts in self._assigned.items()}

    def worker_of(self, task_id: str) -> int | None:
        with self._lock:
            return self._task_worker.get(task_id)

    def acquired_workers(self) -> list[int]:
        """Workers acquired beyond the initial fleet (release candidates)."""
        with self._lock:
            return sorted(w for w in self.workers
                          if w >= self.initial_workers)

    # -- placement -----------------------------------------------------------
    def place(self, v: "RuntimeVertex") -> int:
        """Choose a worker for ``v`` per the policy (acquiring one if the
        pool is saturated and may still grow), record the assignment, and
        return the worker id."""
        with self._lock:
            w = self._choose_locked(v)
            self._assigned[w].add(v.id)
            self._task_worker[v.id] = w
            return w

    def _choose_locked(self, v: "RuntimeVertex") -> int:
        if self.policy == MODULO:
            w = v.index % self.initial_workers
            while w in self._reincarnation:  # dead fleet member: its heir
                w = self._reincarnation[w]
            return w
        need = self.affinity.get(v.job_vertex, frozenset())
        cands = [w for w, wk in self.workers.items() if need <= wk.tags]
        cap = self.slots_per_worker
        free = [w for w in cands if len(self._assigned[w]) < cap]
        if free:
            if self.policy == PACKED:
                return min(free)  # fill lowest-id worker first
            # SPREAD: least-loaded matching worker, lowest id on ties
            return min(free, key=lambda w: (len(self._assigned[w]), w))
        # every matching worker is at capacity: acquire if allowed
        if self._may_acquire_locked():
            return self._acquire_locked(need, reason=f"place {v.id}").id
        if cands:  # capped fleet, all over capacity: least-overloaded match
            return min(cands, key=lambda w: (len(self._assigned[w]), w))
        raise PoolSaturated(
            f"no worker matches affinity {sorted(need)} for {v.id} and the "
            f"pool is capped at max_workers={self.max_workers}")

    def _may_acquire_locked(self) -> bool:
        return (self.max_workers is None
                or len(self.workers) < self.max_workers)

    # -- elastic acquire / release -------------------------------------------
    def acquire(self, tags: Iterable[str] = (),
                reason: str = "manual") -> Worker:
        """Explicitly acquire a new worker (cloud provisioning).  Ids are
        monotonic and never reused so late telemetry can't alias."""
        with self._lock:
            if not self._may_acquire_locked():
                raise PoolSaturated(
                    f"max_workers={self.max_workers} reached")
            return self._acquire_locked(frozenset(tags), reason)

    def _acquire_locked(self, tags: frozenset[str], reason: str) -> Worker:
        w = Worker(self._next_id, self.slots_per_worker, tags)
        self._next_id += 1
        self.workers[w.id] = w
        self._assigned[w.id] = set()
        self.events.append(PoolEvent("acquire", w.id, reason))
        return w

    def release(self, worker: int, reason: str = "manual") -> None:
        """Return an EMPTY non-initial worker to the cloud.  Releasing a
        worker that still hosts tasks, or one of the initial fleet, is a
        caller bug and raises."""
        with self._lock:
            if worker not in self.workers:
                raise KeyError(f"unknown worker {worker}")
            if worker < self.initial_workers:
                raise ValueError(
                    f"worker {worker} belongs to the initial fleet")
            if self._assigned[worker]:
                raise ValueError(
                    f"worker {worker} still hosts "
                    f"{sorted(self._assigned[worker])}")
            del self.workers[worker]
            del self._assigned[worker]
            self.events.append(PoolEvent("release", worker, reason))

    def release_if_empty(self, worker: int, reason: str = "scale_in") -> bool:
        """Release ``worker`` iff it is empty and not part of the initial
        fleet; returns whether it was released."""
        with self._lock:
            if (worker not in self.workers
                    or worker < self.initial_workers
                    or self._assigned[worker]):
                return False
            del self.workers[worker]
            del self._assigned[worker]
            self.events.append(PoolEvent("release", worker, reason))
            return True

    # -- failure quarantine (crash recovery, core/elastic.py) ----------------
    def mark_dead(self, worker: int, reason: str = "crash") -> None:
        """Quarantine a crashed worker: it leaves the live set immediately
        (so capacity accounting and placement never see it again), its slot
        bookkeeping is wiped (the re-wiring layer reassigns the lost tasks
        to a replacement), and its id is remembered as dead forever —
        ``assign`` to it is an NS-G008 violation, not a silent respawn onto
        a ghost."""
        with self._lock:
            if worker in self._dead:
                return
            self._dead.add(worker)
            self.workers.pop(worker, None)
            for t in self._assigned.pop(worker, set()):
                self._task_worker.pop(t, None)
            self.events.append(PoolEvent("dead", worker, reason))

    def acquire_replacement(self, for_worker: int, tags: Iterable[str] = (),
                            reason: str = "recovery") -> Worker:
        """Acquire the replacement for a dead worker.  Bypasses the
        ``max_workers`` gate on purpose: a replacement restores the fleet to
        its pre-crash size, it does not grow it.  Records the dead ->
        replacement lineage so MODULO placement arithmetic keeps working."""
        with self._lock:
            if for_worker not in self._dead:
                raise ValueError(
                    f"worker {for_worker} is not dead; use acquire()")
            w = self._acquire_locked(frozenset(tags), reason)
            self._reincarnation[for_worker] = w.id
            return w

    def is_dead(self, worker: int) -> bool:
        with self._lock:
            return worker in self._dead

    def dead_ids(self) -> list[int]:
        with self._lock:
            return sorted(self._dead)

    # -- assignment bookkeeping ----------------------------------------------
    def assign(self, v: "RuntimeVertex", worker: int) -> None:
        """Record an externally decided placement (custom allocators)."""
        with self._lock:
            if worker in self._dead:
                _diagnostics.fail(
                    "NS-G008", f"worker {worker}",
                    f"respawn/assign of {v.id} targets dead worker "
                    f"{worker}")
            if worker not in self.workers:
                raise KeyError(f"unknown worker {worker}")
            self._assigned[worker].add(v.id)
            self._task_worker[v.id] = worker

    def unassign(self, v: "RuntimeVertex") -> None:
        """Drop ``v``'s slot (task retired).  Idempotent; the worker itself
        stays acquired until the re-wiring layer decides to release it."""
        with self._lock:
            w = self._task_worker.pop(v.id, None)
            if w is not None:
                self._assigned.get(w, set()).discard(v.id)

    def stats(self) -> dict[str, int]:
        with self._lock:
            return {
                "workers": len(self.workers),
                "acquired": sum(1 for e in self.events
                                if e.kind == "acquire"),
                "released": sum(1 for e in self.events
                                if e.kind == "release"),
                "dead": len(self._dead),
                "tasks": len(self._task_worker),
            }
