"""Threaded streaming executor (real time) — paper §2.1 processing pattern.

Implements the common design principles the paper identifies (Fig. 1):
tasks = threads, channels = producer/consumer queues, items collected in
byte-capacity output buffers that ship when full.  On top sit the QoS
roles: per-worker QoS Reporters and the QoS Managers computed by setup.py,
applying adaptive output-buffer sizing and dynamic task chaining at
runtime.

Serialize-once shipping (PR-4 hot-path overhaul): a cross-worker shipped
item is pickled exactly ONCE no matter how many cross-worker receivers its
fan-out has — the blob is cached on the ``StreamItem`` at the first flush
that needs it and reused by sibling channels — and every cross-worker
receiver unpickles its OWN payload copy (true wire semantics: a sink
mutating its payload can never leak the mutation into a sibling receiver
or back into the sender).  Same-worker channels ship the original objects
with NO pickle round-trip at all (shared-memory hand-over).  Per-item key
routing on the emit path is the O(1) dense-table lookup of
core/routing.py (``router.table[key & router.mask]``).

This executor is used at laptop scale (tests, examples); the discrete-event
simulator (simulator.py) runs the identical control plane at paper scale.

Elastic re-parallelization (paper §6, core/elastic.py): the engine inherits
the shared ``RuntimeRewirer`` layer, so ``scale_out``/``scale_in`` mutate a
RUNNING job — task threads are spawned/retired mid-run, channel senders are
re-wired per job-edge pattern (atomic routing-list swaps, no locks on the
hot path), retiring tasks are drained before their thread stops (no
in-flight item is lost), and the QoS manager/reporter scopes are refreshed
via ``compute_qos_setup``.  Both the manager's ``ScaleRequest``
countermeasure and attached ``ElasticController``s drive this path —
exactly the same code the simulator executes at paper scale.

``run(duration)`` is now ``start()`` + sleep + ``stop()``; tests and
long-lived servers can call start/stop directly and mutate in between.
"""
from __future__ import annotations

import pickle
import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable

from ..analysis import race as _race
from ..analysis.race import make_lock as _make_tracked_lock
from .buffers import BufferSizingPolicy, OutputBuffer
from .chaining import ChainRequest, DRAIN_QUEUES
from .clock import Clock, RealClock
from .constraints import JobConstraint
from .elastic import (
    DrainTimeout, RuntimeRewirer, ScaleRequest, split_constraints)
from .estimation import ProactiveConfig
from .faults import (
    ChannelBlackhole, DelaySpike, FaultPlan, KillOwnerOf, KillWorker)
from .graphs import ALL_TO_ALL, Channel, JobGraph, RuntimeGraph, RuntimeVertex
from .manager import Action, BufferSizeUpdate, GiveUp, QoSManager
from .measurement import QoSReporter, Tag, latency_percentile
from .placement import WorkerPool
from .routing import StateStore
from .setup import compute_qos_setup, compute_reporter_setup


@dataclass
class StreamItem:
    payload: Any
    size_bytes: int
    created_at_ms: float
    key: int = 0
    tag: Tag | None = None
    #: serialize-once cache: the payload's pickle, computed lazily at the
    #: FIRST cross-worker flush that ships this item and reused by every
    #: other cross-worker channel of the fan-out — one serialization per
    #: item no matter how many receivers.  Never set on receiver-side
    #: copies (their payload may be mutated downstream).
    blob: bytes | None = None


@dataclass
class SourceSpec:
    """Pacing + item factory for a source job vertex (per subtask)."""

    rate_items_per_s: float
    make_payload: Callable[[int], tuple[Any, int]]  # seq -> (payload, size_bytes)
    key_of: Callable[[int], int] = lambda seq: seq
    #: optional bursty pacing: elapsed_ms -> items/s, overrides the fixed
    #: rate (same contract as SimSourceSpec.rate_fn — shared benchmark
    #: scenarios run unchanged on both backends)
    rate_fn: Callable[[float], float] | None = None

    def rate_at(self, elapsed_ms: float) -> float:
        if self.rate_fn is not None:
            return self.rate_fn(elapsed_ms)
        return self.rate_items_per_s


@dataclass
class EngineResult:
    duration_ms: float
    sink_latencies_ms: list[float]
    items_at_sinks: int
    bytes_shipped: int
    buffers_shipped: int
    final_buffer_sizes: dict[str, int]
    manager_history: list
    give_ups: list[GiveUp]
    chained_groups: list[tuple[str, ...]]
    scale_log: list = field(default_factory=list)
    drain_failures: list = field(default_factory=list)
    #: chains dissolved live (unchain-before-retire): (task ids, reason)
    unchain_log: list = field(default_factory=list)
    #: worker-pool acquire/release audit (core/placement.py PoolEvent)
    pool_events: list = field(default_factory=list)
    #: pre-flight WARN diagnostics (analysis/graph_check.py) carried onto
    #: the result so benchmark harnesses can surface them per row
    preflight_diagnostics: list = field(default_factory=list)
    #: crash-recovery metrics (docs/robustness.md): None on fault-free runs
    time_to_detect_ms: float | None = None
    time_to_recover_ms: float | None = None
    time_to_slo_recovery_ms: float | None = None
    #: core/faults.py RecoveryEvent / FaultRecord audit trails
    recovery_events: list = field(default_factory=list)
    fault_log: list = field(default_factory=list)
    #: per-key conservation ledger (fault runs only):
    #: emitted[k] == sink_count[k] + dropped[k], with duplicates at the
    #: sinks bounded by the replay window recorded in replayed_by_key
    emitted_by_key: dict = field(default_factory=dict)
    dropped_by_key: dict = field(default_factory=dict)
    replayed_by_key: dict = field(default_factory=dict)
    sink_count_by_key: dict = field(default_factory=dict)
    #: bucket index -> mean sink latency in that bucket (bucket width =
    #: latency_bucket_ms, elapsed since start()) — the engine counterpart
    #: of SimResult.latency_timeline, for SLO-violation-time accounting
    latency_timeline: dict = field(default_factory=dict)

    @property
    def mean_latency_ms(self) -> float:
        if not self.sink_latencies_ms:
            return float("nan")
        return sum(self.sink_latencies_ms) / len(self.sink_latencies_ms)

    def latency_percentile(self, q: float) -> float:
        """Shared nearest-rank definition (core/measurement.py), so engine
        and simulator percentiles are the same order statistic."""
        return latency_percentile(self.sink_latencies_ms, q)

    @property
    def throughput_items_per_s(self) -> float:
        return self.items_at_sinks / max(self.duration_ms / 1e3, 1e-9)


# ---------------------------------------------------------------------------
# Channel sender (sender-side endpoint: output buffer or chained direct call)
# ---------------------------------------------------------------------------


class ChannelSender:
    def __init__(
        self,
        channel: Channel,
        engine: "StreamEngine",
        initial_buffer_bytes: int,
    ) -> None:
        self.channel = channel
        self.engine = engine
        self.cid = channel.id
        self.buffer = OutputBuffer(channel.id, initial_buffer_bytes)
        src_worker = engine.rg.worker(channel.src)
        self.cross_worker = src_worker != engine.rg.worker(channel.dst)
        # cached per-sender reference: a vertex's worker never changes and
        # reporter objects persist per worker id (QoS-scope refreshes mutate
        # them in place), so the per-send dict chase is pure overhead
        self.src_reporter = engine.reporters[src_worker]
        self.chained = False
        #: set when the src task's worker was crash-killed (core/faults.py):
        #: the process that owned this buffer is gone, so subsequent emits
        #: into the channel are swallowed and counted as crash drops
        self.dead = False
        #: ChannelBlackhole fault: while now < blackhole_until flushes are
        #: withheld — items keep buffering exactly like a network partition
        #: and ship when it heals (stale sweep / next full-buffer flush)
        self.blackhole_until = 0.0
        # the per-sender lock guards the buffer; _make_tracked_lock IS
        # threading.Lock unless REPRO_RACE_CHECK=1 selected the lockset-
        # tracked variant at import (analysis/race.py)
        self._lock = _make_tracked_lock()

    def send(self, item: StreamItem) -> None:
        eng = self.engine
        if self.dead:
            eng._count_drop(item.key)
            return
        now = eng.clock.now()
        # tag on exit of sender user code (§3.3), one per interval
        cid = self.cid
        if cid in eng.measured_channels and self.src_reporter.should_tag(cid):
            item.tag = Tag(cid, now)
        if self.chained:
            # direct invocation in the caller's thread — no queue, no buffer
            dst = eng.executors[self.channel.dst]
            if dst.batch_mode:
                dst.process_batch([item], self.channel.id)
            else:
                dst.process(item, self.channel.id)
            return
        with self._lock:
            if self.dead:
                # re-check under the lock: the crash wipe (dead set, then
                # buffer emptied under this lock) may have raced the check
                # above — appending now would strand the item forever
                eng._count_drop(item.key)
                return
            full = self.buffer.append(item, item.size_bytes, now)
            if full:
                self._flush_locked(now)

    def flush(self) -> None:
        with self._lock:
            if not self.buffer.empty:
                self._flush_locked(self.engine.clock.now())

    def flush_if_stale(self, now_ms: float, max_lifetime_ms: float) -> bool:
        """Max-buffer-lifetime flush (§3.5.1 companion): ship an under-filled
        buffer once it has been open longer than ``max_lifetime_ms``, so low
        rates cannot strand items until shutdown."""
        if self.chained:
            return False
        with self._lock:
            opened = self.buffer.opened_at_ms
            if (self.buffer.empty or opened is None
                    or now_ms - opened < max_lifetime_ms):
                return False
            self._flush_locked(now_ms)
            return True

    def _flush_locked(self, now: float) -> None:
        eng = self.engine
        if now < self.blackhole_until and not eng._stop.is_set():
            return  # partitioned: hold the buffer until the blackhole heals
        items, nbytes, lifetime = self.buffer.take(now)
        if self.cid in eng.measured_channels:
            self.src_reporter.record_output_buffer_lifetime(
                self.cid, lifetime, self.buffer.capacity_bytes,
                self.buffer.version,
            )
        if self.cross_worker:
            # serialize-once shipping: each item's payload is pickled at
            # most ONCE across the whole fan-out (the blob is cached on the
            # item, so sibling cross-worker channels reuse it), and every
            # receiver unpickles its OWN copy — payload isolation across
            # workers, exactly like a real wire.  Same-worker channels skip
            # serialization entirely (shared-memory hand-over, below).
            shipped = []
            for it in items:
                blob = it.blob
                if blob is None:
                    blob = pickle.dumps(it.payload)
                    it.blob = blob
                shipped.append(StreamItem(
                    payload=pickle.loads(blob),
                    size_bytes=it.size_bytes,
                    created_at_ms=it.created_at_ms,
                    key=it.key,
                    tag=it.tag,
                ))
            items = shipped
        eng.stats_lock_inc(nbytes, len(items))
        eng.deliver(self.channel, items)

    def try_update_size(self, new_size: int, base_version: int) -> bool:
        with self._lock:
            return self.buffer.try_update_size(new_size, base_version)


# ---------------------------------------------------------------------------
# Task executor
# ---------------------------------------------------------------------------


class TaskExecutor:
    def __init__(self, vertex: RuntimeVertex, engine: "StreamEngine") -> None:
        self.vertex = vertex
        self.vid = vertex.id
        self.engine = engine
        # cached per-executor references (same rationale as ChannelSender):
        # placement is fixed for a vertex's lifetime and the worker's
        # reporter object persists across QoS-scope refreshes, so the
        # per-item rg.worker()/reporters[] chase is pure overhead
        self.worker = engine.rg.worker(vertex)
        self.reporter = engine.reporters[self.worker]
        jv = engine.jg.vertices[vertex.job_vertex]
        self.fn = jv.fn
        self.batch_mode = jv.batch_fn
        self.stateful = jv.stateful
        #: per-key state, exposed to user code as ``ctx.state``; for stateful
        #: vertices it is migrated along key ranges on elastic rescaling
        #: (sliced with the group router's range width)
        self.state = StateStore(
            engine.rg.routers[vertex.job_vertex].num_ranges)
        self.is_sink = jv.is_sink or not engine.jg.out_edges(vertex.job_vertex)
        self.inbox: queue.Queue[tuple[str, list[StreamItem]] | None] = queue.Queue()
        self.senders: dict[str, list[ChannelSender]] = {}  # dst job vertex -> senders
        self._rr: dict[str, int] = {}
        self.chained = False          # this task was pulled into another thread
        self.retired = False          # elastically scaled in (thread stopped)
        #: worker crash-killed this task (implies retired, core/faults.py):
        #: its thread aborts WITHOUT draining; queued and in-flight items are
        #: destroyed and counted per key by the crash machinery
        self.crashed = False
        self.paused = threading.Event()
        self.paused.set()             # set == running
        self.parked = threading.Event()  # thread is waiting at the pause gate
        self.idle = threading.Event()
        self.idle.set()
        self.stop_flag = False
        self.drained = threading.Event()
        self._pending_task_sample: float | None = None
        self._busy_ms = 0.0
        self.busy_ms_total = 0.0      # lifetime busy time (elastic telemetry)
        self.emitted = 0              # lifetime emissions (elastic telemetry)
        #: spawn/retire wall timestamps (engine clock): per-replica gauges
        #: (e.g. token throughput) denominate by LIVE duration, not the
        #: whole run — a replica scaled out mid-run was not idle before it
        #: existed
        self.spawned_at_ms = engine.clock.now()
        self.retired_at_ms: float | None = None
        self._window_start = engine.clock.now()
        self.thread: threading.Thread | None = None
        #: source replay machinery (docs/robustness.md): the pacing loop
        #: mirrors its next sequence number here (checkpoint offsets read
        #: it), and recovery posts a rollback target that the loop applies
        #: at its next iteration
        self.src_seq = 0
        self.rollback_to: int | None = None
        #: DelaySpike fault: extra per-item service sleep active while
        #: clock.now() < spike_until
        self.spike_until = 0.0
        self.spike_sleep_s = 0.0

    # -- emit routing ------------------------------------------------------------
    def emit(self, payload: Any, size_bytes: int | None = None,
             key: int | None = None, created_at_ms: float | None = None) -> None:
        eng = self.engine
        now = eng.clock.now()
        if self._pending_task_sample is not None:
            vid = self.vid
            if vid in eng.measured_tasks:
                self.reporter.record_task_latency(
                    vid, now - self._pending_task_sample
                )
            self._pending_task_sample = None
        cur = self._current_item
        item = StreamItem(
            payload=payload,
            size_bytes=size_bytes if size_bytes is not None else (
                cur.size_bytes if cur else 128),
            created_at_ms=created_at_ms if created_at_ms is not None else (
                cur.created_at_ms if cur else now),
            key=key if key is not None else (cur.key if cur else 0),
        )
        self.emitted += 1
        routers = eng.rg.routers
        for dst_jv, senders in self.senders.items():
            if len(senders) == 1:
                senders[0].send(item)
            else:
                # O(1) key-range routing: one masked index into the group's
                # dense lookup table (core/routing.py; senders are sorted by
                # dst index, and the group is always contiguous from 0).
                # Mid-rescale a sender list may transiently disagree with
                # the atomically-swapped table; clamp, and ownership is
                # enforced at the receiver.
                router = routers[dst_jv]
                mask = router.mask
                key = item.key
                # non-int keys (hash-routed, see routing.range_of_key)
                # can't take the masked fast path
                idx = (router.table[key & mask]
                       if mask is not None and isinstance(key, int)
                       else router.owner(key))
                if idx >= len(senders):
                    idx = len(senders) - 1
                senders[idx].send(item)

    _current_item: StreamItem | None = None

    def _forward_if_not_owner(self, item: StreamItem,
                              in_channel_id: str) -> bool:
        """Re-home ``item`` to its key range's owner if that is not us."""
        eng = self.engine
        router = eng.rg.routers.get(self.vertex.job_vertex)
        if router is None:
            return False
        owner = router.owner(item.key)
        if owner == self.vertex.index:
            return False
        target = eng.executors.get(
            RuntimeVertex(self.vertex.job_vertex, owner))
        if target is not None and target.crashed:
            # the owner died with its keyed state: the item is lost with it
            # (counted; source replay regenerates it post-recovery).
            # Processing it here would put the key in a second store
            # (NS-S005 ownership exclusivity).
            eng._count_drop(item.key)
            return True
        if target is None or target is self or target.retired:
            return False  # owner unreachable: process here rather than drop
        if target.chained:
            target.process(item, in_channel_id)
        else:
            target.inbox.put((in_channel_id, [item]))
        return True

    # -- item processing -----------------------------------------------------------
    def process(self, item: StreamItem, in_channel_id: str) -> None:
        eng = self.engine
        if self.crashed:
            # a real crash kills the process mid-item: anything still routed
            # here is lost with it (counted; source replay makes up the gap)
            eng._count_drop(item.key)
            return
        now = eng.clock.now()
        # evaluate tag just before entering user code (§3.3)
        if item.tag is not None:
            self.reporter.record_channel_latency(
                item.tag.channel_id, now - item.tag.created_at_ms
            )
            item.tag = None
        # key-ownership enforcement (stateful stages): an item whose key
        # range was migrated away (or that raced a routing-table swap) is
        # forwarded to the range's owner — its state lives there, so no key
        # is ever served by two owners
        if self.stateful and self._forward_if_not_owner(item, in_channel_id):
            return
        vid = self.vid
        if (
            self._pending_task_sample is None
            and vid in eng.measured_tasks
            and self.reporter.should_sample_task(vid)
        ):
            self._pending_task_sample = now
        if self.is_sink:
            eng.record_sink_latency(now - item.created_at_ms, item.key)
        t0 = time.perf_counter()
        self._current_item = item
        try:
            if self.spike_until and now < self.spike_until:
                time.sleep(self.spike_sleep_s)  # injected service-time spike
            if self.fn is not None:
                self.fn(item.payload, self.emit, self)
            elif not self.is_sink:
                self.emit(item.payload)  # identity
        finally:
            self._current_item = None
            dt = (time.perf_counter() - t0) * 1e3
            self._busy_ms += dt
            self.busy_ms_total += dt

    def _split_batch_by_owner(self, items: list[StreamItem],
                              in_channel_id: str) -> list[StreamItem]:
        """Key-ownership enforcement for batch stages: a delivered buffer may
        mix keys whose ranges live on different owners (it was keyed by its
        first item, or raced a routing-table swap).  Split it at ownership
        boundaries, forward every foreign sub-batch to its range's owner,
        and return only the sub-batch this task owns — so stateful batch
        stages keep strict single-owner per-key state, exactly like per-item
        stages do via ``_forward_if_not_owner``."""
        eng = self.engine
        router = eng.rg.routers.get(self.vertex.job_vertex)
        if router is None:
            return items
        mine: list[StreamItem] = []
        foreign: dict[int, list[StreamItem]] = {}
        for it in items:
            owner = router.owner(it.key)
            if owner == self.vertex.index:
                mine.append(it)
            else:
                foreign.setdefault(owner, []).append(it)
        for owner, batch in foreign.items():
            target = eng.executors.get(
                RuntimeVertex(self.vertex.job_vertex, owner))
            if target is not None and target.crashed:
                # owner died with its state: lost + counted, never processed
                # by a second store (see _forward_if_not_owner)
                for it in batch:
                    eng._count_drop(it.key)
            elif target is None or target is self or target.retired:
                mine.extend(batch)  # owner unreachable: keep, never drop
            elif target.chained:
                target.process_batch(batch, in_channel_id)
            else:
                target.inbox.put((in_channel_id, batch))
        return mine

    def process_batch(self, items: list[StreamItem], in_channel_id: str) -> None:
        """Batch mode: one fn call per delivered output buffer — the buffer
        size IS the batch size (the serving-plane reading of §2.2.1)."""
        eng = self.engine
        if self.crashed:
            for it in items:
                eng._count_drop(it.key)
            return
        now = eng.clock.now()
        if self.stateful:
            items = self._split_batch_by_owner(items, in_channel_id)
            if not items:
                return
        rep = self.reporter
        is_sink = self.is_sink
        for item in items:
            if item.tag is not None:
                rep.record_channel_latency(
                    item.tag.channel_id, now - item.tag.created_at_ms
                )
                item.tag = None
            if is_sink:
                eng.record_sink_latency(now - item.created_at_ms, item.key)
        vid = self.vid
        if (
            self._pending_task_sample is None
            and vid in eng.measured_tasks
            and rep.should_sample_task(vid)
        ):
            self._pending_task_sample = now
        t0 = time.perf_counter()
        self._current_item = items[0] if items else None
        try:
            if self.spike_until and now < self.spike_until:
                time.sleep(self.spike_sleep_s)  # injected service-time spike
            if self.fn is not None:
                self.fn([it.payload for it in items], self.emit, self)
        finally:
            self._current_item = None
            dt = (time.perf_counter() - t0) * 1e3
            self._busy_ms += dt
            self.busy_ms_total += dt

    # -- thread body ------------------------------------------------------------------
    def run(self) -> None:
        eng = self.engine
        while not self.stop_flag:
            if not self.paused.is_set():
                # park visibly: a quiescing migration knows no further item
                # can start until paused is set again
                self.parked.set()
                self.paused.wait()
                self.parked.clear()
            try:
                got = self.inbox.get(timeout=0.02)
            except queue.Empty:
                if self.chained:
                    break
                continue
            if got is None:
                break
            self.idle.clear()
            ch_id, items = got
            if self.batch_mode:
                self.process_batch(items, ch_id)
            else:
                for it in items:
                    self.process(it, ch_id)
            self.idle.set()
        # drain remaining work before exiting (chaining handshake).  A
        # CRASHED task must NOT drain: its in-flight state dies with the
        # process; this exit sweep counts any delivery that raced past the
        # injector's inbox wipe so per-key conservation still closes.
        while True:
            try:
                got = self.inbox.get_nowait()
            except queue.Empty:
                break
            if got is None:
                continue
            ch_id, items = got
            if self.crashed:
                for it in items:
                    eng._count_drop(it.key)
            elif self.batch_mode:
                self.process_batch(items, ch_id)
            else:
                for it in items:
                    self.process(it, ch_id)
        self.drained.set()

    def cpu_utilization(self) -> float:
        now = self.engine.clock.now()
        span = max(now - self._window_start, 1.0)
        util = self._busy_ms / span
        self._busy_ms = 0.0
        self._window_start = now
        return min(util, 1.0)


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------


class StreamEngine(RuntimeRewirer):
    def __init__(
        self,
        jg: JobGraph,
        constraints: list,
        num_workers: int | None = None,
        sources: dict[str, SourceSpec] | None = None,
        initial_buffer_bytes: int = 32 * 1024,
        measurement_interval_ms: float = 1_000.0,
        enable_qos: bool = True,
        enable_chaining: bool = True,
        policy: BufferSizingPolicy | None = None,
        clock: Clock | None = None,
        max_buffer_lifetime_ms: float | None = 5_000.0,
        pool: WorkerPool | None = None,
        num_key_ranges: int | None = None,
        preflight: bool = True,
        fault_plan: FaultPlan | None = None,
        checkpointer=None,
        heartbeat_timeout_ms: float = 1_500.0,
        proactive: ProactiveConfig | None = None,
        latency_bucket_ms: float = 1_000.0,
    ) -> None:
        self.jg = jg
        # pre-flight validation (analysis/graph_check.py): structured
        # diagnostics over the job-level description.  ERRORs raise
        # GraphValidationError (a ValueError) before anything is expanded;
        # WARNs are kept for inspection.  Opt out with preflight=False.
        # Imported lazily: graph_check itself imports repro.core.
        if preflight:
            from ..analysis.graph_check import run_preflight
            self.preflight_diagnostics = run_preflight(
                jg, constraints, pool=pool, num_workers=num_workers,
                num_key_ranges=num_key_ranges,
                initial_buffer_bytes=initial_buffer_bytes,
                max_buffer_lifetime_ms=max_buffer_lifetime_ms,
                policy=policy, sources=sources, proactive=proactive,
                measurement_interval_ms=measurement_interval_ms)
        else:
            self.preflight_diagnostics = []
        #: max output-buffer lifetime (§3.5.1 companion): with QoS off and a
        #: low rate, an undersized buffer would otherwise strand items until
        #: shutdown; None disables (e.g. for pure Fig. 2 sweeps)
        self.max_buffer_lifetime_ms = max_buffer_lifetime_ms
        # latency (JobConstraint) and throughput (ThroughputConstraint) goals
        # may be mixed in ``constraints``; only latency ones go through the
        # §3.4.2 setup — throughput ones arm the scale-out countermeasure.
        self.constraints, self.throughput_constraints = split_constraints(
            constraints)
        # worker placement: an explicit WorkerPool (elastic policies,
        # acquire/release) or a fixed modulo fleet of ``num_workers``;
        # num_key_ranges widens the routers for m > 128 stages
        self.rg = RuntimeGraph(jg, num_workers, pool=pool,
                               num_key_ranges=num_key_ranges)
        self.sources = sources or {}
        self.clock = clock or RealClock()
        self.enable_qos = enable_qos
        self.enable_chaining = enable_chaining
        self.interval_ms = measurement_interval_ms
        self.initial_buffer_bytes = initial_buffer_bytes
        self.policy = policy
        # predictive QoS (core/estimation.py): set BEFORE manager
        # construction so the estimator registry dict the managers hold is
        # the same object _estimator_tick feeds (_init_rewirer preserves it)
        self.proactive = proactive
        self._rate_estimators: dict = {}
        self.latency_bucket_ms = latency_bucket_ms
        #: bucket index -> (latency sum, count); bucketed by wall time since
        #: start() so benchmark harnesses can compute SLO-violation seconds
        #: (the engine-side analogue of SimResult.latency_timeline)
        self._lat_timeline: dict[int, tuple[float, int]] = {}

        # QoS setup (master, §3.4.2)
        self.allocations = compute_qos_setup(jg, self.constraints, self.rg)
        self.reporter_setup = compute_reporter_setup(self.allocations, self.rg)
        self.reporters: dict[int, QoSReporter] = {
            w: QoSReporter(w, self.clock, measurement_interval_ms)
            for w in self.rg.worker_ids()
        }
        for w, routes in self.reporter_setup.task_routes.items():
            for mgr, tasks in routes.items():
                self.reporters[w].assign_manager(mgr, (), tasks)
        for w, routes in self.reporter_setup.channel_routes.items():
            for mgr, chans in routes.items():
                self.reporters[w].assign_manager(mgr, chans, ())
        self.managers: dict[int, QoSManager] = {
            w: QoSManager(alloc, self.rg, self.clock, policy=policy,
                          throughput_constraints=self.throughput_constraints,
                          proactive=proactive,
                          estimators=self._rate_estimators)
            for w, alloc in self.allocations.items()
        }
        self.measured_channels: set[str] = set()
        self.measured_tasks: set[str] = set()
        for r in self.reporters.values():
            self.measured_channels |= r.interested_channels()
            self.measured_tasks |= r.interested_tasks()

        # runtime structures
        self.executors: dict[RuntimeVertex, TaskExecutor] = {
            v: TaskExecutor(v, self) for v in self.rg.vertices
        }
        self.senders: dict[str, ChannelSender] = {}
        for c in self.rg.channels:
            s = ChannelSender(c, self, initial_buffer_bytes)
            self.senders[c.id] = s
            self.executors[c.src].senders.setdefault(c.dst.job_vertex, []).append(s)

        self._sink_lat: list[float] = []
        self._sink_lock = _make_tracked_lock()
        self._bytes = 0
        self._buffers = 0
        self._stats_lock = _make_tracked_lock()
        self._stop = threading.Event()
        self._chained_groups: list[tuple[str, ...]] = []
        self._give_ups: list[GiveUp] = []
        self._threads: list[threading.Thread] = []
        self._closed_senders: list[ChannelSender] = []
        self._ctrl: threading.Thread | None = None
        self._running = False
        self._t0 = 0.0
        self._init_rewirer()

        # fault injection + crash recovery (core/faults.py,
        # docs/robustness.md).  The conservation ledgers are only populated
        # on fault runs (_fault_acct) — fault-free behaviour is unchanged.
        self.fault_plan = fault_plan
        self._fault_acct = fault_plan is not None
        self.emitted_by_key: dict = {}
        self.dropped_by_key: dict = {}
        self.replayed_by_key: dict = {}
        self.sink_count_by_key: dict = {}
        self._acct_lock = _make_tracked_lock()
        self._injector: threading.Thread | None = None
        #: executors respawned by crash recovery, held at the pause gate
        #: until _replay_sources releases them (control thread only)
        self._respawn_held: list[TaskExecutor] = []
        if fault_plan is not None or checkpointer is not None:
            self.attach_recovery(checkpointer, heartbeat_timeout_ms)

    # -- stats ---------------------------------------------------------------------
    def record_sink_latency(self, lat_ms: float, key: int | None = None) -> None:
        bucket = int((self.clock.now() - self._t0) // self.latency_bucket_ms)
        with self._sink_lock:
            self._sink_lat.append(lat_ms)
            s, c0 = self._lat_timeline.get(bucket, (0.0, 0))
            self._lat_timeline[bucket] = (s + lat_ms, c0 + 1)
            if key is not None:
                c = self.sink_count_by_key
                c[key] = c.get(key, 0) + 1

    def _count_drop(self, key, n: int = 1) -> None:
        """Per-key crash-drop accounting (fault runs only): every item an
        injected fault destroys is counted here, closing the conservation
        ledger emitted == sunk + dropped (modulo replay)."""
        if not self._fault_acct:
            return
        with self._acct_lock:
            d = self.dropped_by_key
            d[key] = d.get(key, 0) + n

    def stats_lock_inc(self, nbytes: int, nitems: int) -> None:
        with self._stats_lock:
            self._bytes += nbytes
            self._buffers += 1

    # -- delivery ---------------------------------------------------------------------
    def deliver(self, channel: Channel, items: list[StreamItem]) -> None:
        dst = self.executors[channel.dst]
        if dst.crashed:
            # destination's worker crash-killed: the delivery hits a dead
            # socket and is lost (counted; source replay makes up the gap)
            for it in items:
                self._count_drop(it.key)
            return
        if dst.retired:
            # straggler delivery to an elastically retired task: hand each
            # item to its key range's surviving owner so nothing is lost and
            # keyed state stays with its one owner
            jv = channel.dst.job_vertex
            group = self.rg.tasks_of(jv)
            if not group:
                return
            router = self.rg.routers[jv]
            for it in items:
                owner = router.owner(it.key)
                sibling = self.executors.get(group[min(owner,
                                                       len(group) - 1)])
                if sibling is None or sibling.retired:
                    # routing table and group transiently disagree: any
                    # surviving member beats dropping the item
                    sibling = next(
                        (ex for g in group
                         if (ex := self.executors.get(g)) is not None
                         and not ex.retired), None)
                if sibling is not None:
                    self._hand_to(sibling, channel.id, [it])
                else:
                    # whole group gone (crash window): lost, but counted
                    self._count_drop(it.key)
            return
        self._hand_to(dst, channel.id, items)

    def _hand_to(self, dst: TaskExecutor, channel_id: str,
                 items: list[StreamItem]) -> None:
        if dst.chained:
            # the task was pulled into a chain: its thread is gone, items are
            # handed over synchronously in the caller's thread
            if dst.batch_mode:
                dst.process_batch(items, channel_id)
            else:
                for it in items:
                    dst.process(it, channel_id)
            return
        dst.inbox.put((channel_id, items))

    # -- source pacing ------------------------------------------------------------------
    def _source_body(self, v: RuntimeVertex, spec: SourceSpec) -> None:
        ex = self.executors[v]
        next_t = time.monotonic()
        while not self._stop.is_set() and not ex.crashed:
            ex.paused.wait()
            if ex.crashed or self._stop.is_set():
                break
            rb = ex.rollback_to
            if rb is not None:
                # recovery posted a replay offset: rewind to the checkpoint
                # (or fast-forward a respawned source past its checkpointed
                # prefix) — docs/robustness.md, replay-window semantics
                ex.rollback_to = None
                ex.src_seq = rb
            now = time.monotonic()
            if now < next_t:
                time.sleep(min(next_t - now, 0.05))
                continue
            seq = ex.src_seq
            rate = spec.rate_at(self.clock.now() - self._t0)
            next_t += 1.0 / max(rate, 1e-9)
            payload, size = spec.make_payload(seq)
            item = StreamItem(payload, size, self.clock.now(), key=spec.key_of(seq))
            if self._fault_acct:
                with self._acct_lock:
                    e = self.emitted_by_key
                    e[item.key] = e.get(item.key, 0) + 1
            t0 = time.perf_counter()
            ex._current_item = item
            try:
                if ex.fn is not None:
                    ex.fn(payload, ex.emit, ex)
                else:
                    ex.emit(payload)
            finally:
                ex._current_item = None
                dt = (time.perf_counter() - t0) * 1e3
                ex._busy_ms += dt
                ex.busy_ms_total += dt
            ex.src_seq = seq + 1

    # -- QoS control loop ------------------------------------------------------------------
    def _control_body(self) -> None:
        while not self._stop.is_set():
            time.sleep(self.interval_ms / 1e3 / 4)
            # max-buffer-lifetime sweep: ship under-filled buffers that have
            # been open too long (runs regardless of enable_qos — it is a
            # liveness guarantee, not a countermeasure)
            if self.max_buffer_lifetime_ms is not None:
                now = self.clock.now()
                for s in list(self.senders.values()):
                    s.flush_if_stale(now, self.max_buffer_lifetime_ms)
            # crash detection -> recovery (core/faults.py): the monitor's
            # clock is the engine clock, so detection latency is wall time;
            # periodic checkpoints ride the same tick
            if self._monitor is not None:
                self._liveness_tick(self.clock.now())
            self._maybe_checkpoint(self.clock.now())
            # cpu utilization sampling feeds the chaining precondition
            # (snapshot: elastic re-wiring swaps these dicts live; a dead
            # worker's reporter is gone — skip, don't resurrect)
            measured = self.measured_tasks
            for v, ex in list(self.executors.items()):
                if v.id in measured and not ex.retired:
                    rep = self.reporters.get(self.rg.worker(v))
                    if rep is not None:
                        rep.record_task_cpu(
                            v.id, ex.cpu_utilization(), ex.chained
                        )
            # reporters -> managers
            managers = self.managers
            for rep in list(self.reporters.values()):
                for mgr_id, report in rep.maybe_flush():
                    mgr = managers.get(mgr_id)
                    if mgr is not None:
                        mgr.receive_report(report)
            # predictive QoS: feed the rate estimators on the control tick
            # (no-op with proactive=None — _estimator_tick guards)
            if self.proactive is not None:
                self._estimator_tick(self.clock.now())
            # attached elastic controllers sample on their own cadence
            for st in list(self._elastic):
                if self.clock.now() >= st.get("next_ms", 0.0):
                    st["next_ms"] = self.clock.now() + st["period_ms"]
                    self.elastic_check(st)
            # time-to-SLO-recovery: first tick after a crash where every
            # latency constraint is evaluable and satisfied again
            if self._slo_pending_since is not None:
                self._slo_recovery_check(self.clock.now())
            if not self.enable_qos:
                continue
            # managers act
            for mgr in list(self.managers.values()):
                for action in mgr.check():
                    self._route_action(action)

    def _route_action(self, action: Action) -> None:
        if isinstance(action, BufferSizeUpdate):
            sender = self.senders.get(action.channel_id)
            if sender is not None:
                sender.try_update_size(
                    action.new_size_bytes, action.base_version
                )
        elif isinstance(action, ChainRequest):
            if self.enable_chaining:
                self.apply_chain(action)
        elif isinstance(action, ScaleRequest):
            try:
                if action.to_parallelism < action.from_parallelism:
                    # proactive give-back: the manager's forecast path may
                    # request a shrink; reactive requests only ever grow
                    self.scale_in(action.job_vertex, action.to_parallelism,
                                  reason=action.reason)
                else:
                    self.scale_out(action.job_vertex, action.to_parallelism,
                                   reason=action.reason)
            except (ValueError, DrainTimeout):
                # vertex not scalable (source / POINTWISE-pinned) or a
                # retiring task hung its drain: the countermeasure is
                # inapplicable/aborted, never fatal to the control loop
                pass
        elif isinstance(action, GiveUp):
            self._give_ups.append(action)

    # -- fault injection (core/faults.py; docs/robustness.md) ----------------------------
    def _injector_body(self) -> None:
        """Dedicated thread that fires each planned fault at its wall-clock
        offset from ``start()`` — the engine-side analogue of the
        simulator's scheduled ``_inject_fault`` events."""
        for f in self.fault_plan.ordered():
            while not self._stop.is_set():
                dt_s = (self._t0 + f.at_ms - self.clock.now()) / 1e3
                if dt_s <= 0:
                    break
                time.sleep(min(dt_s, 0.05))
            if self._stop.is_set():
                return
            self._inject_fault(f)

    def _inject_fault(self, fault) -> None:
        now = self.clock.now()
        rel = now - self._t0
        plan = self.fault_plan
        if isinstance(fault, KillWorker):
            w = fault.worker
            if w is None:
                live = [x for x in self.rg.pool.worker_ids()
                        if x not in self._crashed_workers]
                w = plan.pick_worker(live)
            if w is not None and w not in self._crashed_workers:
                self._crash_worker(w, now, rel)
        elif isinstance(fault, KillOwnerOf):
            group = self.rg.tasks_of(fault.job_vertex)
            target = next((v for v in group if v.index == fault.index),
                          group[-1] if group else None)
            if target is not None:
                w = self.rg.worker(target)
                if w not in self._crashed_workers:
                    plan.record(rel, "kill_owner_of",
                                f"{target.id} on worker {w}")
                    self._crash_worker(w, now, rel)
        elif isinstance(fault, ChannelBlackhole):
            until = now + fault.duration_ms
            n = 0
            for s in list(self.senders.values()):
                c = s.channel
                if (c.src.job_vertex == fault.src_vertex
                        and c.dst.job_vertex == fault.dst_vertex):
                    s.blackhole_until = until
                    n += 1
            plan.record(rel, "blackhole",
                        f"{fault.src_vertex}->{fault.dst_vertex} "
                        f"({n} channels, {fault.duration_ms:g}ms)")
        elif isinstance(fault, DelaySpike):
            until = now + fault.duration_ms
            # the engine has no synthetic service time; the spike sleeps
            # (factor - 1) x the vertex's nominal sim_cpu_ms per item, so
            # shared scenarios stress both backends comparably
            extra_s = (max(fault.factor - 1.0, 0.0)
                       * self.jg.vertices[fault.job_vertex].sim_cpu_ms / 1e3)
            n = 0
            for v in self.rg.tasks_of(fault.job_vertex):
                ex = self.executors.get(v)
                if ex is not None and not ex.crashed:
                    ex.spike_sleep_s = extra_s
                    ex.spike_until = until
                    n += 1
            plan.record(rel, "delay_spike",
                        f"{fault.job_vertex} x{fault.factor:g} "
                        f"for {fault.duration_ms:g}ms ({n} tasks)")

    def _crash_worker(self, w: int, now: float, rel_ms: float) -> None:
        """Kill every task resident on worker ``w`` the way a process crash
        would: threads abort without draining, queued items and un-shipped
        output buffers are destroyed (counted per key), in-flight emissions
        are swallowed, and the worker stops heartbeating.  Detection and
        recovery follow in the control loop (``_liveness_tick``)."""
        if self.fault_plan is not None:
            self.fault_plan.record(rel_ms, "kill_worker", f"worker {w}")
        self.note_crash(w, now)
        for v, ex in list(self.executors.items()):
            if ex.crashed or ex.retired or self.rg.worker(v) != w:
                continue
            ex.crashed = True
            ex.retired = True
            if ex.retired_at_ms is None:
                ex.retired_at_ms = now
            ex.stop_flag = True
            ex.paused.set()        # free a parked thread so it can exit
            # queued-but-unprocessed items die with the process
            while True:
                try:
                    got = ex.inbox.get_nowait()
                except queue.Empty:
                    break
                if got is not None:
                    for it in got[1]:
                        self._count_drop(it.key)
            ex.inbox.put(None)     # wake a blocked get()
            # un-shipped output buffers die with the process; later emits
            # into these channels are swallowed at the sender (dead flag)
            for senders_list in list(ex.senders.values()):
                for s in list(senders_list):
                    s.dead = True
                    with s._lock:
                        items, _, _ = s.buffer.take(now)
                    if items:
                        if _sanitize.SANITIZE:
                            _sanitize.CHECKER.note_crashed(s.buffer)
                        for it in items:
                            self._count_drop(it.key)

    # -- dynamic task chaining (§3.5.2) --------------------------------------------------
    def apply_chain(self, req: ChainRequest) -> None:
        tasks = [self.executors[v] for v in req.tasks]
        if any(t.chained for t in tasks):
            return
        # chaining is only legal for co-located tasks (§3.5.2 condition 1):
        # the manager's telemetry normally guarantees this, but re-wiring
        # may have raced the decision — re-check against the live placement
        workers = {self.rg.worker(v) for v in req.tasks}
        if len(workers) != 1:
            self.drain_failures.append(
                f"apply_chain({[v.id for v in req.tasks]}): tasks span "
                f"workers {sorted(workers)}; chain refused")
            return
        head = tasks[0]
        # 1. halt the first task in the series
        head.paused.clear()
        try:
            # 2. flush in-flight buffers between the chained tasks
            chain_channel_ids = set()
            for a, b in zip(req.tasks, req.tasks[1:]):
                for c in self.rg.out_channels(a):
                    if c.dst == b:
                        self.senders[c.id].flush()
                        chain_channel_ids.add(c.id)
            # 3. drain + stop the downstream tasks' threads
            if req.mode == DRAIN_QUEUES:
                for t in tasks[1:]:
                    t.chained = True  # thread exits after draining its inbox
                stuck = [t for t in tasks[1:]
                         if not t.drained.wait(timeout=self.drain_timeout_s)]
            else:  # drop
                for t in tasks[1:]:
                    t.chained = True
                    while True:
                        try:
                            t.inbox.get_nowait()
                        except queue.Empty:
                            break
                stuck = [t for t in tasks[1:]
                         if not t.drained.wait(timeout=self.drain_timeout_s)]
            if stuck:
                # a hung task never handed over its thread: abort the chain
                # loudly instead of fusing around an undrained inbox.  Tasks
                # that DID drain stay chained (deliver() hands to them
                # synchronously); the stuck ones resume their normal loop.
                for t in stuck:
                    t.chained = False
                    if t.drained.wait(timeout=0.25):
                        # it raced past the abort — saw chained=True, drained
                        # its inbox, and exited — so keep it fused: with its
                        # thread gone, only the synchronous deliver() path
                        # may serve it
                        t.chained = True
                self.drain_failures.append(
                    f"apply_chain({[v.id for v in req.tasks]}): drain "
                    f"timeout on "
                    f"{[t.vertex.id for t in stuck if not t.chained]} after "
                    f"{self.drain_timeout_s}s; chain aborted")
                if _race.CHECKER is not None:
                    # blocked-drain watchdog: record what each stuck thread
                    # still holds (deadlock forensics, analysis/race.py)
                    _race.CHECKER.report_blocked_drain(
                        f"apply_chain({[v.id for v in req.tasks]}): tasks "
                        f"failed to drain within {self.drain_timeout_s}s",
                        [t.thread for t in stuck if not t.chained])
                return
            # 4. flip the senders to direct invocation; flush any stragglers
            #    that raced in while draining (delivered synchronously via the
            #    chained-destination path in deliver()).
            for cid in chain_channel_ids:
                self.senders[cid].chained = True
            for cid in chain_channel_ids:
                self.senders[cid].flush()
            self._chained_groups.append(tuple(v.id for v in req.tasks))
            # live-chain registry: scale_in consults this to unchain a
            # retiring member (head included) before retiring it
            self.active_chains.append(tuple(req.tasks))
        finally:
            head.paused.set()

    def _dissolve_chain(self, chain) -> bool:
        """Reverse of apply_chain (unchaining, for scale-in): re-establish
        each fused member's own thread, then revert the chain channels to
        buffered hand-over.  No queue is dropped, so item conservation holds
        through an unchain exactly as through a drain."""
        head = self.executors.get(chain[0])
        members = [self.executors.get(v) for v in chain[1:]]
        if head is None or any(ex is None for ex in members):
            return False
        # 1. halt the head between items so no fused invocation is running
        #    down the chain while we flip it apart
        head.paused.clear()
        try:
            if (chain[0].job_vertex not in self.sources and not head.chained
                    and head.thread is not None and head.thread.is_alive()):
                if not head.parked.wait(timeout=self.drain_timeout_s):
                    # head stuck mid-item: a fused invocation may still be
                    # running down the chain — restarting member threads now
                    # would run the same task on two threads.  Abort; the
                    # caller surfaces the failure and the rescale stops.
                    if _race.CHECKER is not None:
                        _race.CHECKER.report_blocked_drain(
                            f"_dissolve_chain({[v.id for v in chain]}): "
                            f"head never parked within "
                            f"{self.drain_timeout_s}s",
                            [head.thread])
                    return False
            # 2. give the fused members their threads back FIRST, so the
            #    re-buffered channels have live consumers from the start
            for v, ex in zip(chain[1:], members):
                ex.chained = False
                ex.stop_flag = False
                ex.drained.clear()
                if self._running:
                    self._start_task_thread(v, ex)
            # 3. flip the chain channels back to buffered hand-over
            for a, b in zip(chain, chain[1:]):
                for c in self.rg.out_channels(a):
                    if c.dst == b:
                        s = self.senders.get(c.id)
                        if s is not None:
                            s.chained = False
        finally:
            head.paused.set()
        return True

    # -- elastic re-wiring hooks (RuntimeRewirer; see core/elastic.py) -------------------
    def _start_task_thread(self, v: RuntimeVertex, ex: TaskExecutor) -> None:
        if v.job_vertex in self.sources:
            th = threading.Thread(
                target=self._source_body,
                args=(v, self.sources[v.job_vertex]),
                daemon=True,
                name=f"src-{v.id}",
            )
        else:
            th = threading.Thread(target=ex.run, daemon=True, name=f"task-{v.id}")
        ex.thread = th
        self._threads.append(th)
        th.start()

    def _add_worker(self, w: int) -> None:
        # pool acquired a worker mid-run: give it a QoS reporter before any
        # task or channel on it reports (atomic dict swap, hot paths read)
        reporters = dict(self.reporters)
        reporters[w] = QoSReporter(w, self.clock, self.interval_ms)
        self.reporters = reporters

    def _spawn_task(self, v: RuntimeVertex) -> None:
        ex = TaskExecutor(v, self)
        executors = dict(self.executors)
        executors[v] = ex
        self.executors = executors  # atomic swap: hot paths never see a gap
        if self._running:
            self._start_task_thread(v, ex)

    def _open_channel(self, c: Channel) -> None:
        s = ChannelSender(c, self, self.initial_buffer_bytes)
        senders = dict(self.senders)
        senders[c.id] = s
        self.senders = senders
        src_ex = self.executors[c.src]
        cur = list(src_ex.senders.get(c.dst.job_vertex, ()))
        cur.append(s)
        cur.sort(key=lambda sd: sd.channel.dst.index)
        # atomic list swap — emitting threads either see the old or the new
        # routing group, never a half-built one
        src_ex.senders[c.dst.job_vertex] = cur

    def _unroute_channel(self, c: Channel) -> None:
        src_ex = self.executors.get(c.src)
        s = self.senders.get(c.id)
        if src_ex is not None and s is not None:
            cur = [x for x in src_ex.senders.get(c.dst.job_vertex, ())
                   if x is not s]
            src_ex.senders[c.dst.job_vertex] = cur
        if s is not None:
            # an emitting thread may have picked the old routing list just
            # before the swap; flush, give it a grace period, flush again so
            # its item still ships before the destination drains.  The sender
            # is kept on a closed list and flushed once more at stop() —
            # deliver() reroutes anything late to a surviving sibling, so no
            # item is ever lost to this race.
            s.flush()
            time.sleep(0.02)
            s.flush()
            self._closed_senders.append(s)
        senders = dict(self.senders)
        senders.pop(c.id, None)
        self.senders = senders

    def _drain_tasks(self, vs) -> bool:
        deadline = time.monotonic() + self.drain_timeout_s
        drained = True
        for v in vs:
            ex = self.executors.get(v)
            if ex is None:
                continue
            while not (ex.inbox.empty() and ex.idle.is_set()):
                if time.monotonic() >= deadline:
                    drained = False
                    break
                time.sleep(0.005)
        return drained

    def _retire_task(self, v: RuntimeVertex) -> None:
        ex = self.executors.get(v)
        if ex is None:
            return
        ex.retired = True  # deliver() reroutes stragglers to siblings
        if ex.retired_at_ms is None:
            ex.retired_at_ms = self.clock.now()
        ex.stop_flag = True
        ex.inbox.put(None)
        th = ex.thread
        if th is not None and th.is_alive():
            th.join(timeout=2.0)

    def _flush_task_outputs(self, v: RuntimeVertex) -> None:
        ex = self.executors.get(v)
        if ex is None:
            return
        closed: set[str] = set()
        for senders_list in list(ex.senders.values()):
            for s in list(senders_list):
                s.flush()
                closed.add(s.channel.id)
        if closed:
            self.senders = {
                k: s for k, s in self.senders.items() if k not in closed
            }

    def _quiesce_tasks(self, vs) -> bool:
        # pause the old owners and wait until each is between items, so the
        # state snapshot cannot race an in-flight per-key update (a chained
        # task runs in its caller's thread and cannot be paused; its store
        # lock still keeps every snapshot internally consistent)
        for v in vs:
            ex = self.executors.get(v)
            if ex is not None:
                ex.paused.clear()
        deadline = time.monotonic() + self.drain_timeout_s
        parked_all = True
        for v in vs:
            ex = self.executors.get(v)
            if (ex is None or ex.chained or ex.thread is None
                    or not ex.thread.is_alive()):
                continue
            if not ex.parked.wait(
                    timeout=max(deadline - time.monotonic(), 0.0)):
                parked_all = False
                if _race.CHECKER is not None:
                    _race.CHECKER.report_blocked_drain(
                        f"_quiesce_tasks: {v.id} never parked within "
                        f"{self.drain_timeout_s}s",
                        [ex.thread])
        return parked_all

    def _resume_tasks(self, vs) -> None:
        for v in vs:
            ex = self.executors.get(v)
            if ex is not None:
                ex.paused.set()

    def _task_state(self, v: RuntimeVertex) -> StateStore | None:
        ex = self.executors.get(v)
        return None if ex is None else ex.state

    # _reroute_queued: inherited no-op — the engine enforces key ownership at
    # processing time (TaskExecutor._forward_if_not_owner), so items of moved
    # ranges still queued at an old owner re-home themselves on resume.

    def _task_is_chained(self, v: RuntimeVertex) -> bool:
        ex = self.executors.get(v)
        return ex is not None and ex.chained

    def _task_emitted(self, v: RuntimeVertex) -> int:
        ex = self.executors.get(v)
        return 0 if ex is None else ex.emitted

    def _task_busy_ms(self, v: RuntimeVertex) -> float:
        ex = self.executors.get(v)
        return 0.0 if ex is None else ex.busy_ms_total

    # -- crash-recovery hooks (RuntimeRewirer.recover_worker) -----------------------------
    def _respawn_task(self, v: RuntimeVertex) -> None:
        # like _spawn_task, but the fresh executor starts HELD at the pause
        # gate: its out-channels are only opened (and its state restored)
        # after this returns, so an early item/fire would emit into an empty
        # sender table and vanish.  _replay_sources releases the holds once
        # the whole recovery (channels + state + offsets) is wired.
        ex = TaskExecutor(v, self)
        ex.paused.clear()
        self._respawn_held.append(ex)
        executors = dict(self.executors)
        executors[v] = ex
        self.executors = executors
        if self._running:
            self._start_task_thread(v, ex)

    # _repoint_in_channels: inherited no-op — deliver() resolves
    # executors[channel.dst] per call, so in-channels re-point the moment
    # _spawn_task swaps the fresh executor in.

    def _source_offsets(self) -> dict:
        out = {}
        for jv_name in self.sources:
            for v in self.rg.tasks_of(jv_name):
                ex = self.executors.get(v)
                if ex is not None:
                    out[(jv_name, v.index)] = ex.src_seq
        return out

    def _replay_sources(self, offsets, now: float) -> int:
        """Roll every source back to its checkpointed offset (None = no
        checkpoint: respawned sources restart from 0).  The rollback is a
        posted target the pacing thread applies at its next iteration; a
        source held by _respawn_task is released here."""
        replayed = 0
        for jv_name, spec in self.sources.items():
            for v in self.rg.tasks_of(jv_name):
                ex = self.executors.get(v)
                if ex is None or ex.retired:
                    continue
                target = (0 if offsets is None
                          else offsets.get((jv_name, v.index), 0))
                cur = ex.src_seq
                if cur != target:
                    ex.rollback_to = target
                if cur > target:
                    replayed += cur - target
                    if self._fault_acct:
                        with self._acct_lock:
                            r = self.replayed_by_key
                            for sq in range(target, cur):
                                k = spec.key_of(sq)
                                r[k] = r.get(k, 0) + 1
        # recovery fully wired (channels, state, offsets): release every
        # executor _respawn_task held at the pause gate
        for ex in self._respawn_held:
            ex.paused.set()
        self._respawn_held = []
        return replayed

    def _crash_dissolve_chain(self, chain) -> None:
        # every member of a chain is co-located (§3.5.2 condition 1), so a
        # crash that hit one member killed them all — their threads are gone
        # and recover_worker respawns fresh executors.  Just unfuse the
        # flags so the respawned group starts unchained.
        for v in chain[1:]:
            ex = self.executors.get(v)
            if ex is not None:
                ex.chained = False
        for a, b in zip(chain, chain[1:]):
            for c in self.rg.out_channels(a):
                if c.dst == b:
                    s = self.senders.get(c.id)
                    if s is not None:
                        s.chained = False

    def _schedule_elastic(self, st: dict, period_ms: float) -> None:
        # the QoS control thread polls attached controllers on their cadence
        st["period_ms"] = period_ms
        st["next_ms"] = self.clock.now() + period_ms

    # -- run --------------------------------------------------------------------------------
    def start(self) -> None:
        """Start all task/source threads and the QoS control loop; the job
        then runs until ``stop()`` and may be mutated live (scale_out/in)."""
        if self._running:
            raise RuntimeError("engine already running")
        self._running = True
        self._t0 = self.clock.now()
        for v, ex in list(self.executors.items()):
            self._start_task_thread(v, ex)
        self._ctrl = threading.Thread(
            target=self._control_body, daemon=True, name="qos-ctrl")
        self._ctrl.start()
        if self.fault_plan is not None and self.fault_plan.faults:
            self._injector = threading.Thread(
                target=self._injector_body, daemon=True,
                name="fault-injector")
            self._injector.start()

    def stop(self) -> EngineResult:
        """Stop sources, then drain layer by layer in topological order so
        every in-flight item reaches the sinks (item conservation), and
        collect the result."""
        self._stop.set()  # sources + control loop wind down
        for jv_name in self.jg.topological_order():
            group = list(self.rg.tasks_of(jv_name))
            for v in group:
                ex = self.executors.get(v)
                if ex is None:
                    continue
                if jv_name not in self.sources:
                    ex.stop_flag = True
                    ex.inbox.put(None)
                th = ex.thread
                if th is not None and th.is_alive():
                    th.join(timeout=2.0)
            # this layer is quiet: push its buffered output to the next one
            for v in group:
                ex = self.executors.get(v)
                if ex is None:
                    continue
                for senders_list in list(ex.senders.values()):
                    for s in list(senders_list):
                        s.flush()
            for s in self._closed_senders:
                if s.channel.src.job_vertex == jv_name:
                    s.flush()  # scale-in stragglers; deliver() reroutes
        if self._ctrl is not None:
            self._ctrl.join(timeout=2.0)
        if self._injector is not None:
            self._injector.join(timeout=2.0)
        self._running = False
        dur = self.clock.now() - self._t0
        history = list(self._manager_history_archive)
        for mgr in self.managers.values():
            history.extend(mgr.history)
        return EngineResult(
            duration_ms=dur,
            sink_latencies_ms=list(self._sink_lat),
            items_at_sinks=len(self._sink_lat),
            bytes_shipped=self._bytes,
            buffers_shipped=self._buffers,
            final_buffer_sizes={
                cid: s.buffer.capacity_bytes for cid, s in self.senders.items()
            },
            manager_history=history,
            give_ups=self._give_ups,
            chained_groups=self._chained_groups,
            scale_log=list(self.scale_log),
            drain_failures=list(self.drain_failures),
            unchain_log=list(self.unchain_log),
            pool_events=list(self.rg.pool.events),
            preflight_diagnostics=list(self.preflight_diagnostics),
            time_to_detect_ms=self.time_to_detect_ms,
            time_to_recover_ms=self.time_to_recover_ms,
            time_to_slo_recovery_ms=self.time_to_slo_recovery_ms,
            recovery_events=list(self.recovery_log),
            fault_log=(list(self.fault_plan.log)
                       if self.fault_plan is not None else []),
            emitted_by_key=dict(self.emitted_by_key),
            dropped_by_key=dict(self.dropped_by_key),
            replayed_by_key=dict(self.replayed_by_key),
            sink_count_by_key=dict(self.sink_count_by_key),
            latency_timeline={b: s / c
                              for b, (s, c) in self._lat_timeline.items()
                              if c},
        )

    def run(self, duration_ms: float) -> EngineResult:
        self.start()
        time.sleep(duration_ms / 1e3)
        return self.stop()


# -- runtime invariant sanitizer hook (analysis/sanitize.py) -----------------
# Per-operation buffer accounting comes from the OutputBuffer wrappers
# (core/buffers.py hook); this closes each run with a whole-channel ledger
# sweep at stop() (NS-S001).
from ..analysis import sanitize as _sanitize  # noqa: E402

if _sanitize.SANITIZE:  # pragma: no cover - exercised via subprocess tests
    _sanitize.instrument_engine(StreamEngine)
