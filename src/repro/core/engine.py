"""Threaded streaming executor (real time) — paper §2.1 processing pattern.

Implements the common design principles the paper identifies (Fig. 1):
tasks = threads, channels = producer/consumer queues, items collected in
byte-capacity output buffers that ship when full.  Cross-worker channels
pay real serialization (pickle) costs; same-worker channels hand over via
shared memory.  On top sit the QoS roles: per-worker QoS Reporters and the
QoS Managers computed by setup.py, applying adaptive output-buffer sizing
and dynamic task chaining at runtime.

This executor is used at laptop scale (tests, examples); the discrete-event
simulator (simulator.py) runs the identical control plane at paper scale.
"""
from __future__ import annotations

import pickle
import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable

from .buffers import BufferSizingPolicy, OutputBuffer
from .chaining import ChainRequest, DRAIN_QUEUES
from .clock import Clock, RealClock
from .constraints import JobConstraint
from .graphs import ALL_TO_ALL, Channel, JobGraph, RuntimeGraph, RuntimeVertex
from .manager import Action, BufferSizeUpdate, GiveUp, QoSManager
from .measurement import QoSReporter, Tag
from .setup import compute_qos_setup, compute_reporter_setup


@dataclass
class StreamItem:
    payload: Any
    size_bytes: int
    created_at_ms: float
    key: int = 0
    tag: Tag | None = None


@dataclass
class SourceSpec:
    """Pacing + item factory for a source job vertex (per subtask)."""

    rate_items_per_s: float
    make_payload: Callable[[int], tuple[Any, int]]  # seq -> (payload, size_bytes)
    key_of: Callable[[int], int] = lambda seq: seq


@dataclass
class EngineResult:
    duration_ms: float
    sink_latencies_ms: list[float]
    items_at_sinks: int
    bytes_shipped: int
    buffers_shipped: int
    final_buffer_sizes: dict[str, int]
    manager_history: list
    give_ups: list[GiveUp]
    chained_groups: list[tuple[str, ...]]

    @property
    def mean_latency_ms(self) -> float:
        if not self.sink_latencies_ms:
            return float("nan")
        return sum(self.sink_latencies_ms) / len(self.sink_latencies_ms)

    def latency_percentile(self, q: float) -> float:
        if not self.sink_latencies_ms:
            return float("nan")
        xs = sorted(self.sink_latencies_ms)
        idx = min(len(xs) - 1, int(q * len(xs)))
        return xs[idx]

    @property
    def throughput_items_per_s(self) -> float:
        return self.items_at_sinks / max(self.duration_ms / 1e3, 1e-9)


# ---------------------------------------------------------------------------
# Channel sender (sender-side endpoint: output buffer or chained direct call)
# ---------------------------------------------------------------------------


class ChannelSender:
    def __init__(
        self,
        channel: Channel,
        engine: "StreamEngine",
        initial_buffer_bytes: int,
    ) -> None:
        self.channel = channel
        self.engine = engine
        self.buffer = OutputBuffer(channel.id, initial_buffer_bytes)
        self.cross_worker = engine.rg.worker(channel.src) != engine.rg.worker(
            channel.dst
        )
        self.chained = False
        self._lock = threading.Lock()

    def send(self, item: StreamItem) -> None:
        eng = self.engine
        now = eng.clock.now()
        # tag on exit of sender user code (§3.3), one per interval
        reporter = eng.reporters[eng.rg.worker(self.channel.src)]
        if self.channel.id in eng.measured_channels and reporter.should_tag(
            self.channel.id
        ):
            item.tag = Tag(self.channel.id, now)
        if self.chained:
            # direct invocation in the caller's thread — no queue, no buffer
            dst = eng.executors[self.channel.dst]
            if dst.batch_mode:
                dst.process_batch([item], self.channel.id)
            else:
                dst.process(item, self.channel.id)
            return
        with self._lock:
            full = self.buffer.append(item, item.size_bytes, now)
            if full:
                self._flush_locked(now)

    def flush(self) -> None:
        with self._lock:
            if not self.buffer.empty:
                self._flush_locked(self.engine.clock.now())

    def _flush_locked(self, now: float) -> None:
        items, nbytes, lifetime = self.buffer.take(now)
        eng = self.engine
        src_worker = eng.rg.worker(self.channel.src)
        reporter = eng.reporters[src_worker]
        if self.channel.id in eng.measured_channels:
            reporter.record_output_buffer_lifetime(
                self.channel.id, lifetime, self.buffer.capacity_bytes,
                self.buffer.version,
            )
        if self.cross_worker:
            # realistic serialize/deserialize cost for crossing workers
            blob = pickle.dumps([i.payload for i in items])
            _ = pickle.loads(blob)
        eng.stats_lock_inc(nbytes, len(items))
        eng.deliver(self.channel, items)

    def try_update_size(self, new_size: int, base_version: int) -> bool:
        with self._lock:
            return self.buffer.try_update_size(new_size, base_version)


# ---------------------------------------------------------------------------
# Task executor
# ---------------------------------------------------------------------------


class TaskExecutor:
    def __init__(self, vertex: RuntimeVertex, engine: "StreamEngine") -> None:
        self.vertex = vertex
        self.engine = engine
        jv = engine.jg.vertices[vertex.job_vertex]
        self.fn = jv.fn
        self.batch_mode = jv.batch_fn
        self.is_sink = jv.is_sink or not engine.jg.out_edges(vertex.job_vertex)
        self.inbox: queue.Queue[tuple[str, list[StreamItem]] | None] = queue.Queue()
        self.senders: dict[str, list[ChannelSender]] = {}  # dst job vertex -> senders
        self._rr: dict[str, int] = {}
        self.chained = False          # this task was pulled into another thread
        self.paused = threading.Event()
        self.paused.set()             # set == running
        self.idle = threading.Event()
        self.idle.set()
        self.stop_flag = False
        self.drained = threading.Event()
        self._pending_task_sample: float | None = None
        self._busy_ms = 0.0
        self._window_start = engine.clock.now()
        self.thread: threading.Thread | None = None

    # -- emit routing ------------------------------------------------------------
    def emit(self, payload: Any, size_bytes: int | None = None,
             key: int | None = None, created_at_ms: float | None = None) -> None:
        eng = self.engine
        now = eng.clock.now()
        if self._pending_task_sample is not None:
            vid = self.vertex.id
            if vid in eng.measured_tasks:
                eng.reporters[eng.rg.worker(self.vertex)].record_task_latency(
                    vid, now - self._pending_task_sample
                )
            self._pending_task_sample = None
        cur = self._current_item
        item = StreamItem(
            payload=payload,
            size_bytes=size_bytes if size_bytes is not None else (
                cur.size_bytes if cur else 128),
            created_at_ms=created_at_ms if created_at_ms is not None else (
                cur.created_at_ms if cur else now),
            key=key if key is not None else (cur.key if cur else 0),
        )
        for dst_jv, senders in self.senders.items():
            if len(senders) == 1:
                senders[0].send(item)
            else:
                idx = item.key % len(senders)
                senders[idx].send(item)

    _current_item: StreamItem | None = None

    # -- item processing -----------------------------------------------------------
    def process(self, item: StreamItem, in_channel_id: str) -> None:
        eng = self.engine
        now = eng.clock.now()
        # evaluate tag just before entering user code (§3.3)
        if item.tag is not None:
            worker = eng.rg.worker(self.vertex)
            eng.reporters[worker].record_channel_latency(
                item.tag.channel_id, now - item.tag.created_at_ms
            )
            item.tag = None
        vid = self.vertex.id
        if (
            self._pending_task_sample is None
            and vid in eng.measured_tasks
            and eng.reporters[eng.rg.worker(self.vertex)].should_sample_task(vid)
        ):
            self._pending_task_sample = now
        if self.is_sink:
            eng.record_sink_latency(now - item.created_at_ms)
        t0 = time.perf_counter()
        self._current_item = item
        try:
            if self.fn is not None:
                self.fn(item.payload, self.emit, self)
            elif not self.is_sink:
                self.emit(item.payload)  # identity
        finally:
            self._current_item = None
            self._busy_ms += (time.perf_counter() - t0) * 1e3

    def process_batch(self, items: list[StreamItem], in_channel_id: str) -> None:
        """Batch mode: one fn call per delivered output buffer — the buffer
        size IS the batch size (the serving-plane reading of §2.2.1)."""
        eng = self.engine
        now = eng.clock.now()
        for item in items:
            if item.tag is not None:
                worker = eng.rg.worker(self.vertex)
                eng.reporters[worker].record_channel_latency(
                    item.tag.channel_id, now - item.tag.created_at_ms
                )
                item.tag = None
            if self.is_sink:
                eng.record_sink_latency(now - item.created_at_ms)
        vid = self.vertex.id
        if (
            self._pending_task_sample is None
            and vid in eng.measured_tasks
            and eng.reporters[eng.rg.worker(self.vertex)].should_sample_task(vid)
        ):
            self._pending_task_sample = now
        t0 = time.perf_counter()
        self._current_item = items[0] if items else None
        try:
            if self.fn is not None:
                self.fn([it.payload for it in items], self.emit, self)
        finally:
            self._current_item = None
            self._busy_ms += (time.perf_counter() - t0) * 1e3

    # -- thread body ------------------------------------------------------------------
    def run(self) -> None:
        eng = self.engine
        while not self.stop_flag:
            self.paused.wait()
            try:
                got = self.inbox.get(timeout=0.02)
            except queue.Empty:
                if self.chained:
                    break
                continue
            if got is None:
                break
            self.idle.clear()
            ch_id, items = got
            if self.batch_mode:
                self.process_batch(items, ch_id)
            else:
                for it in items:
                    self.process(it, ch_id)
            self.idle.set()
        # drain remaining work before exiting (chaining handshake)
        while True:
            try:
                got = self.inbox.get_nowait()
            except queue.Empty:
                break
            if got is None:
                continue
            ch_id, items = got
            if self.batch_mode:
                self.process_batch(items, ch_id)
            else:
                for it in items:
                    self.process(it, ch_id)
        self.drained.set()

    def cpu_utilization(self) -> float:
        now = self.engine.clock.now()
        span = max(now - self._window_start, 1.0)
        util = self._busy_ms / span
        self._busy_ms = 0.0
        self._window_start = now
        return min(util, 1.0)


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------


class StreamEngine:
    def __init__(
        self,
        jg: JobGraph,
        constraints: list[JobConstraint],
        num_workers: int,
        sources: dict[str, SourceSpec],
        initial_buffer_bytes: int = 32 * 1024,
        measurement_interval_ms: float = 1_000.0,
        enable_qos: bool = True,
        enable_chaining: bool = True,
        policy: BufferSizingPolicy | None = None,
        clock: Clock | None = None,
    ) -> None:
        self.jg = jg
        self.constraints = constraints
        self.rg = RuntimeGraph(jg, num_workers)
        self.sources = sources
        self.clock = clock or RealClock()
        self.enable_qos = enable_qos
        self.enable_chaining = enable_chaining
        self.interval_ms = measurement_interval_ms

        # QoS setup (master, §3.4.2)
        self.allocations = compute_qos_setup(jg, constraints, self.rg)
        self.reporter_setup = compute_reporter_setup(self.allocations, self.rg)
        self.reporters: dict[int, QoSReporter] = {
            w: QoSReporter(w, self.clock, measurement_interval_ms)
            for w in range(num_workers)
        }
        for w, routes in self.reporter_setup.task_routes.items():
            for mgr, tasks in routes.items():
                self.reporters[w].assign_manager(mgr, (), tasks)
        for w, routes in self.reporter_setup.channel_routes.items():
            for mgr, chans in routes.items():
                self.reporters[w].assign_manager(mgr, chans, ())
        self.managers: dict[int, QoSManager] = {
            w: QoSManager(alloc, self.rg, self.clock, policy=policy)
            for w, alloc in self.allocations.items()
        }
        self.measured_channels: set[str] = set()
        self.measured_tasks: set[str] = set()
        for r in self.reporters.values():
            self.measured_channels |= r.interested_channels()
            self.measured_tasks |= r.interested_tasks()

        # runtime structures
        self.executors: dict[RuntimeVertex, TaskExecutor] = {
            v: TaskExecutor(v, self) for v in self.rg.vertices
        }
        self.senders: dict[str, ChannelSender] = {}
        for c in self.rg.channels:
            s = ChannelSender(c, self, initial_buffer_bytes)
            self.senders[c.id] = s
            self.executors[c.src].senders.setdefault(c.dst.job_vertex, []).append(s)

        self._sink_lat: list[float] = []
        self._sink_lock = threading.Lock()
        self._bytes = 0
        self._buffers = 0
        self._stats_lock = threading.Lock()
        self._stop = threading.Event()
        self._chained_groups: list[tuple[str, ...]] = []
        self._give_ups: list[GiveUp] = []

    # -- stats ---------------------------------------------------------------------
    def record_sink_latency(self, lat_ms: float) -> None:
        with self._sink_lock:
            self._sink_lat.append(lat_ms)

    def stats_lock_inc(self, nbytes: int, nitems: int) -> None:
        with self._stats_lock:
            self._bytes += nbytes
            self._buffers += 1

    # -- delivery ---------------------------------------------------------------------
    def deliver(self, channel: Channel, items: list[StreamItem]) -> None:
        dst = self.executors[channel.dst]
        if dst.chained:
            # the task was pulled into a chain: its thread is gone, items are
            # handed over synchronously in the caller's thread
            if dst.batch_mode:
                dst.process_batch(items, channel.id)
            else:
                for it in items:
                    dst.process(it, channel.id)
            return
        dst.inbox.put((channel.id, items))

    # -- source pacing ------------------------------------------------------------------
    def _source_body(self, v: RuntimeVertex, spec: SourceSpec) -> None:
        ex = self.executors[v]
        period_s = 1.0 / max(spec.rate_items_per_s, 1e-9)
        seq = 0
        next_t = time.monotonic()
        while not self._stop.is_set():
            ex.paused.wait()
            now = time.monotonic()
            if now < next_t:
                time.sleep(min(next_t - now, 0.05))
                continue
            next_t += period_s
            payload, size = spec.make_payload(seq)
            item = StreamItem(payload, size, self.clock.now(), key=spec.key_of(seq))
            t0 = time.perf_counter()
            ex._current_item = item
            try:
                if ex.fn is not None:
                    ex.fn(payload, ex.emit, ex)
                else:
                    ex.emit(payload)
            finally:
                ex._current_item = None
                ex._busy_ms += (time.perf_counter() - t0) * 1e3
            seq += 1

    # -- QoS control loop ------------------------------------------------------------------
    def _control_body(self) -> None:
        while not self._stop.is_set():
            time.sleep(self.interval_ms / 1e3 / 4)
            # cpu utilization sampling feeds the chaining precondition
            for v, ex in self.executors.items():
                if v.id in self.measured_tasks:
                    self.reporters[self.rg.worker(v)].record_task_cpu(
                        v.id, ex.cpu_utilization(), ex.chained
                    )
            # reporters -> managers
            for rep in self.reporters.values():
                for mgr_id, report in rep.maybe_flush():
                    self.managers[mgr_id].receive_report(report)
            if not self.enable_qos:
                continue
            # managers act
            for mgr in self.managers.values():
                for action in mgr.check():
                    self._route_action(action)

    def _route_action(self, action: Action) -> None:
        if isinstance(action, BufferSizeUpdate):
            self.senders[action.channel_id].try_update_size(
                action.new_size_bytes, action.base_version
            )
        elif isinstance(action, ChainRequest):
            if self.enable_chaining:
                self.apply_chain(action)
        elif isinstance(action, GiveUp):
            self._give_ups.append(action)

    # -- dynamic task chaining (§3.5.2) --------------------------------------------------
    def apply_chain(self, req: ChainRequest) -> None:
        tasks = [self.executors[v] for v in req.tasks]
        if any(t.chained for t in tasks):
            return
        head = tasks[0]
        # 1. halt the first task in the series
        head.paused.clear()
        try:
            # 2. flush in-flight buffers between the chained tasks
            chain_channel_ids = set()
            for a, b in zip(req.tasks, req.tasks[1:]):
                for c in self.rg.out_channels(a):
                    if c.dst == b:
                        self.senders[c.id].flush()
                        chain_channel_ids.add(c.id)
            # 3. drain + stop the downstream tasks' threads
            if req.mode == DRAIN_QUEUES:
                for t in tasks[1:]:
                    t.chained = True  # thread exits after draining its inbox
                for t in tasks[1:]:
                    t.drained.wait(timeout=5.0)
            else:  # drop
                for t in tasks[1:]:
                    t.chained = True
                    while True:
                        try:
                            t.inbox.get_nowait()
                        except queue.Empty:
                            break
                    t.drained.wait(timeout=5.0)
            # 4. flip the senders to direct invocation; flush any stragglers
            #    that raced in while draining (delivered synchronously via the
            #    chained-destination path in deliver()).
            for cid in chain_channel_ids:
                self.senders[cid].chained = True
            for cid in chain_channel_ids:
                self.senders[cid].flush()
            self._chained_groups.append(tuple(v.id for v in req.tasks))
        finally:
            head.paused.set()

    # -- run --------------------------------------------------------------------------------
    def run(self, duration_ms: float) -> EngineResult:
        threads: list[threading.Thread] = []
        for v, ex in self.executors.items():
            if v.job_vertex in self.sources:
                th = threading.Thread(
                    target=self._source_body,
                    args=(v, self.sources[v.job_vertex]),
                    daemon=True,
                    name=f"src-{v.id}",
                )
            else:
                th = threading.Thread(target=ex.run, daemon=True, name=f"task-{v.id}")
                ex.thread = th
            threads.append(th)
        ctrl = threading.Thread(target=self._control_body, daemon=True, name="qos-ctrl")
        t0 = self.clock.now()
        for th in threads:
            th.start()
        ctrl.start()
        time.sleep(duration_ms / 1e3)
        self._stop.set()
        for ex in self.executors.values():
            ex.stop_flag = True
            ex.inbox.put(None)
        for th in threads:
            th.join(timeout=2.0)
        ctrl.join(timeout=2.0)
        dur = self.clock.now() - t0
        history = []
        for mgr in self.managers.values():
            history.extend(mgr.history)
        return EngineResult(
            duration_ms=dur,
            sink_latencies_ms=list(self._sink_lat),
            items_at_sinks=len(self._sink_lat),
            bytes_shipped=self._bytes,
            buffers_shipped=self._buffers,
            final_buffer_sizes={
                cid: s.buffer.capacity_bytes for cid, s in self.senders.items()
            },
            manager_history=history,
            give_ups=self._give_ups,
            chained_groups=self._chained_groups,
        )
