"""Event schedulers for the discrete-event core (the ordering authority).

``StreamSimulator.run()`` dispatches slotted event records — tuples of
``(time_ms, seq, kind, a, b, c)`` where ``seq`` is a simulator-owned
monotonically increasing tie-breaker — in strictly non-decreasing
``(time_ms, seq)`` order.  This module owns that ordering: lint rule
NS-L007 forbids ``heapq`` everywhere else under ``src/repro``, so any
code that needs a priority queue imports the re-exported
:func:`heappush`/:func:`heappop` from here (e.g. the simulator's pending
``schedule()`` call-time ledger) or uses an event queue class.

Two interchangeable implementations behind one duck interface
(``push(rec)``, ``pop() -> rec | None``, ``__len__``):

* :class:`HeapEventQueue` — the reference binary heap (CPython's C
  ``heapq``).  O(log n) per op with an extremely small constant; the
  baseline every ordering claim is verified against.

* :class:`CalendarEventQueue` — a calendar queue (Brown 1988): a ring of
  fixed-width time buckets, each an insertion-ordered flat list sorted
  lazily when the serving window first reaches it.  Pops from the
  current bucket are O(1) list indexing; pushes are O(1) appends for
  anything within the ring's time horizon.  Far-future (and non-finite)
  events park in a spill heap and are re-bucketed as the window advances
  past their bucket.  The bucket width retunes itself from the observed
  pop rate toward a target mean occupancy, so the queue stays in its
  O(1) regime as the event rate drifts over a run.

Both produce the *exact* total order on ``(time_ms, seq)`` — the golden
decision traces in ``tests/golden/`` pass bit-unmodified on either, and
``tests/test_eventq.py`` pins the equivalence with a hypothesis property
over adversarial push streams (ties, spills, epoch rollovers).
"""
from __future__ import annotations

from bisect import insort
from heapq import heappop, heappush

__all__ = [
    "SCHEDULERS",
    "HeapEventQueue",
    "CalendarEventQueue",
    "make_event_queue",
    "heappush",
    "heappop",
]

#: the scheduler names ``make_event_queue`` accepts
SCHEDULERS = ("calendar", "heap")

#: times at or above this bypass bucket-index arithmetic and go straight
#: to the spill heap: ``int(t * inv_w)`` of +inf raises OverflowError,
#: and astronomically large finite times would never enter the serving
#: window anyway.  Any record this far out is served from the spill heap
#: directly (heap order == total order once the ring is empty).
_MAX_T = 1e17

#: target mean events per ring bucket.  Measured on this machine's
#: CPython: per-op cost is flat for occupancies ~8-64 and the calendar
#: overtakes the C heapq decisively (>2x at 100k outstanding events)
#: around the middle of that basin.
TARGET_OCCUPANCY = 32

#: pops between bucket-width retune checks
_RETUNE_POPS = 8192


class HeapEventQueue:
    """Reference scheduler: a plain binary heap over whole records.

    ``data`` is public on purpose — the simulator's reference dispatch
    loop pops it directly (and pushes via ``heappush(eq.data, rec)``
    bound as a partial) so the heap arm keeps C-speed ops with zero
    method-call overhead.
    """

    __slots__ = ("data",)

    def __init__(self) -> None:
        self.data: list[tuple] = []

    def push(self, rec: tuple) -> None:
        heappush(self.data, rec)

    def pop(self) -> tuple | None:
        d = self.data
        return heappop(d) if d else None

    def peek(self) -> tuple | None:
        d = self.data
        return d[0] if d else None

    def __len__(self) -> int:
        return len(self.data)


class CalendarEventQueue:
    """Calendar queue with lazy-sorted buckets and a far-future spill heap.

    Layout: ``ring`` holds ``nb`` (power of two) buckets of width ``w``
    ms; a record at time ``t`` belongs to absolute bucket
    ``b = int(t * inv_w)`` and lives at ``ring[b & mask]`` while ``b``
    falls inside the serving window ``[cur_b, cur_b + nb)``.  Records
    beyond the window (or past ``_MAX_T``) wait in ``spill``, a binary
    heap, and migrate into the ring as the window advances over their
    bucket.  ``cur`` aliases the bucket currently being served;
    ``cur[ci:]`` is its sorted, not-yet-popped tail (buckets are
    insertion-ordered until the window reaches them, then sorted once).

    Ordering invariant (why this reproduces the heap's total order):

    * every outstanding record in a bucket ``> cur_b`` or in ``spill``
      has time ``>= cur_b * w``, i.e. sorts after everything left in
      ``cur[ci:]``;
    * a push whose bucket ``<= cur_b`` (same bucket, or a time that
      floors below the window — only possible for ``t`` >= the last
      popped time, since the simulator never schedules into the past)
      is insorted into ``cur`` at position ``>= ci``, preserving the
      sorted tail;
    * ``_advance`` serves buckets strictly left to right and sorts each
      exactly once before serving it.

    Bucket width self-tunes: every ``_RETUNE_POPS`` pops the observed
    event rate is compared against ``TARGET_OCCUPANCY`` events per
    bucket, and the whole queue is re-bucketed onto a new width when the
    current one is off by more than 2x either way (hysteresis keeps the
    steady state free of rebucket churn).
    """

    __slots__ = ("w", "inv_w", "nb", "mask", "ring", "ring_count", "spill",
                 "cur", "ci", "cur_b", "pops", "mark_pops", "mark_t")

    def __init__(self, width_ms: float = 1.0, nbuckets: int = 512) -> None:
        if nbuckets <= 0 or nbuckets & (nbuckets - 1):
            raise ValueError("nbuckets must be a power of two")
        w = float(width_ms)
        if not w > 0.0:
            raise ValueError("width_ms must be > 0")
        self.w = w
        self.inv_w = 1.0 / w
        self.nb = nbuckets
        self.mask = nbuckets - 1
        self.ring: list[list[tuple]] = [[] for _ in range(nbuckets)]
        self.ring_count = 0
        self.spill: list[tuple] = []
        self.cur_b = 0
        self.cur = self.ring[0]
        self.ci = 0
        # retune bookkeeping: pops/sim-time marks of the last check
        self.pops = 0
        self.mark_pops = 0
        self.mark_t = 0.0

    def __len__(self) -> int:
        return self.ring_count + len(self.spill)

    def push(self, rec: tuple) -> None:
        t = rec[0]
        if t < _MAX_T:
            b = int(t * self.inv_w)
            d = b - self.cur_b
            if 0 < d < self.nb:
                self.ring[b & self.mask].append(rec)
                self.ring_count += 1
                return
            if d <= 0:
                # same bucket as the serving position (or floored below
                # it): keep the sorted unserved tail cur[ci:] sorted
                insort(self.cur, rec, self.ci)
                self.ring_count += 1
                return
        heappush(self.spill, rec)

    def pop(self) -> tuple | None:
        ci = self.ci
        cur = self.cur
        if ci < len(cur):
            self.ci = ci + 1
            self.ring_count -= 1
            self.pops += 1
            return cur[ci]
        return self._advance()

    def peek(self) -> tuple | None:
        rec = self.pop()
        if rec is not None:
            # re-insert: push preserves the total order for any record at
            # or after the serving position, which a just-popped one is
            self.push(rec)
            self.pops -= 1
        return rec

    # -- window advance (rare path: once per served bucket) ------------------

    def _advance(self) -> tuple | None:
        if self.pops - self.mark_pops >= _RETUNE_POPS:
            self._maybe_retune()
            # a rebucket re-anchors the window at the earliest
            # outstanding record — retry the fast path before advancing
            ci = self.ci
            cur = self.cur
            if ci < len(cur):
                self.ci = ci + 1
                self.ring_count -= 1
                self.pops += 1
                return cur[ci]
        cur = self.cur
        if cur:
            cur.clear()  # fully served; recycle the bucket list
        self.ci = 0
        ring = self.ring
        mask = self.mask
        nb = self.nb
        inv_w = self.inv_w
        spill = self.spill
        cur_b = self.cur_b
        count = self.ring_count
        while True:
            cur_b += 1
            # the window gained a bucket on the right edge: migrate every
            # spill record whose bucket now falls inside it (after an
            # empty-ring jump this drains a whole window's worth at once)
            if spill:
                edge = cur_b + nb
                while spill:
                    t0 = spill[0][0]
                    if t0 >= _MAX_T:
                        break
                    b0 = int(t0 * inv_w)
                    if b0 >= edge:
                        break
                    ring[b0 & mask].append(heappop(spill))
                    count += 1
            if count == 0:
                if not spill:
                    # truly empty
                    self.cur_b = cur_b
                    self.cur = ring[cur_b & mask]
                    self.ring_count = 0
                    return None
                t0 = spill[0][0]
                if t0 >= _MAX_T:
                    # only astronomically-far records remain: the spill
                    # heap alone is the queue; heap order is total order
                    self.cur_b = cur_b
                    self.cur = ring[cur_b & mask]
                    self.ring_count = 0
                    self.pops += 1
                    return heappop(spill)
                # empty-ring jump: warp the window to the spill minimum's
                # bucket instead of stepping one bucket at a time
                nxt = int(t0 * inv_w)
                if nxt > cur_b:
                    cur_b = nxt - 1  # the loop head re-increments
                continue
            bucket = ring[cur_b & mask]
            if bucket:
                if len(bucket) > 1:
                    bucket.sort()
                self.cur = bucket
                self.cur_b = cur_b
                self.ci = 1
                self.ring_count = count - 1
                self.pops += 1
                return bucket[0]

    # -- adaptive bucket width ----------------------------------------------

    def _maybe_retune(self) -> None:
        """Compare the observed pop rate against the target occupancy and
        re-bucket onto a better width when off by more than 2x."""
        now_t = self.cur_b * self.w
        dp = self.pops - self.mark_pops
        dt = now_t - self.mark_t
        self.mark_pops = self.pops
        self.mark_t = now_t
        if dp <= 0 or dt <= 0.0:
            return
        ideal = TARGET_OCCUPANCY * dt / dp  # ms per bucket at target occ
        ideal = min(max(ideal, 1e-6), 1e6)
        ratio = ideal / self.w
        if 0.5 <= ratio <= 2.0:
            return
        self._rebucket(ideal)

    def _rebucket(self, new_w: float) -> None:
        """Re-anchor every outstanding record onto a new bucket width.
        Only called at a bucket boundary (``cur`` fully served), so the
        serving bucket holds no live records."""
        recs: list[tuple] = []
        cur = self.cur
        for bucket in self.ring:
            if bucket and bucket is not cur:
                recs.extend(bucket)
                bucket.clear()
        cur.clear()
        recs.extend(self.spill)
        self.spill = []
        self.w = new_w
        self.inv_w = 1.0 / new_w
        # anchor the window at the earliest outstanding record (falling
        # back to the retune timestamp when the queue is empty)
        anchor = self.mark_t
        if recs:
            tmin = min(r[0] for r in recs)
            if tmin < _MAX_T:
                anchor = tmin
        self.cur_b = cb = int(anchor * self.inv_w)
        self.cur = self.ring[cb & self.mask]
        self.ci = 0
        self.ring_count = 0
        for rec in recs:
            self.push(rec)


def make_event_queue(scheduler: str,
                     rate_hint_events_per_ms: float | None = None):
    """Build a scheduler by name.

    ``rate_hint_events_per_ms`` seeds the calendar queue's initial bucket
    width at ``TARGET_OCCUPANCY / rate`` (the adaptive retune corrects any
    estimation error within the first few thousand pops); the heap takes
    no parameters.
    """
    if scheduler == "heap":
        return HeapEventQueue()
    if scheduler == "calendar":
        r = rate_hint_events_per_ms
        width = TARGET_OCCUPANCY / r if r is not None and r > 0.0 else 1.0
        return CalendarEventQueue(min(max(width, 1e-4), 1e3))
    raise ValueError(
        f"unknown scheduler {scheduler!r}: expected one of {SCHEDULERS}")
