"""Distributed QoS management setup (paper §3.4.2, Algorithms 1-3).

The master splits the runtime graph into m subgraphs ``G_i``, one per QoS
Manager, maximizing m (objective 1) while keeping subgraph overlap small
(objective 2), subject to the side conditions:

* every runtime constraint is attended by exactly one manager
  (``union constr(G_i) = C``, pairwise disjoint),
* subgraphs are minimal (no vertices irrelevant to their constraints).

Algorithm 1  ComputeQoSSetup(JG, JC)  — enumerate constrained job-graph paths,
             compute managers per path, merge allocations per worker.
Algorithm 2  GetQoSManagers(path)     — pick the anchor job vertex, partition
             its runtime vertices by worker, GraphExpand each partition
             forwards+backwards into a manager subgraph.
Algorithm 3  GetAnchorVertex(path)    — among vertices with the highest worker
             count, pick the one whose in/out job edge (within the path) has
             the fewest runtime edges.

Ownership rule (disjointness guarantee): a runtime sequence S of a constraint
on ``path`` is owned by the manager on ``worker(anchor instance of S)`` —
every S crosses the anchor job vertex exactly once, so ownership is unique.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from .constraints import JobConstraint
from .graphs import JobGraph, RuntimeGraph, RuntimeSubgraph, RuntimeVertex


@dataclass
class ConstraintScope:
    """One constrained path as seen by one manager: the manager owns the
    sequences passing through ``anchor_tasks`` (all on this manager's worker)."""

    constraint: JobConstraint
    path: tuple[str, ...]
    anchor_vertex: str
    anchor_tasks: tuple[RuntimeVertex, ...]


@dataclass
class ManagerAllocation:
    """``(w_i, G_i)`` plus constraint-ownership metadata."""

    worker: int
    subgraph: RuntimeSubgraph
    scopes: list[ConstraintScope] = field(default_factory=list)

    def merge(self, other: "ManagerAllocation") -> None:
        assert self.worker == other.worker
        self.subgraph.merge(other.subgraph)
        self.scopes.extend(other.scopes)


# ---------------------------------------------------------------------------
# Algorithm 3 — GetAnchorVertex
# ---------------------------------------------------------------------------


def cnt_workers(jv: str, rg: RuntimeGraph) -> int:
    return len({rg.worker(v) for v in rg.tasks_of(jv)})


def cnt_chan(jv: str, path: tuple[str, ...], rg: RuntimeGraph) -> int:
    """Fewest runtime edges among jv's in/out job edges *within the path*."""
    i = path.index(jv)
    counts = []
    if i > 0:
        counts.append(rg.num_runtime_edges(path[i - 1], jv))
    if i < len(path) - 1:
        counts.append(rg.num_runtime_edges(jv, path[i + 1]))
    return min(counts) if counts else 0


def get_anchor_vertex(path: tuple[str, ...], rg: RuntimeGraph) -> str:
    ret = list(path)
    max_work = max(cnt_workers(jv, rg) for jv in ret)
    ret = [jv for jv in ret if cnt_workers(jv, rg) == max_work]
    min_edge = min(cnt_chan(jv, path, rg) for jv in ret)
    ret = [jv for jv in ret if cnt_chan(jv, path, rg) == min_edge]
    return ret[0]


# ---------------------------------------------------------------------------
# Algorithm 2 — GetQoSManagers
# ---------------------------------------------------------------------------


def partition_by_worker(
    tasks: list[RuntimeVertex], rg: RuntimeGraph
) -> dict[int, list[RuntimeVertex]]:
    parts: dict[int, list[RuntimeVertex]] = {}
    for v in tasks:
        parts.setdefault(rg.worker(v), []).append(v)
    return parts


def graph_expand(
    seeds: list[RuntimeVertex], rg: RuntimeGraph, path: tuple[str, ...]
) -> RuntimeSubgraph:
    """Expand a set of runtime vertices to a runtime subgraph by traversing
    the runtime graph forwards and backwards, restricted to the job vertices
    of ``path`` (keeps subgraphs minimal — side condition 2)."""
    on_path = set(path)
    succ = {path[i]: path[i + 1] for i in range(len(path) - 1)}
    pred = {path[i + 1]: path[i] for i in range(len(path) - 1)}
    sub = RuntimeSubgraph()
    sub.job_paths.append(path)
    sub.vertices.update(seeds)
    # forward
    frontier = list(seeds)
    while frontier:
        nxt: list[RuntimeVertex] = []
        for v in frontier:
            jv_next = succ.get(v.job_vertex)
            if jv_next is None:
                continue
            for c in rg.out_channels(v):
                if c.dst.job_vertex != jv_next or c.dst.job_vertex not in on_path:
                    continue
                sub.channels.add(c)
                if c.dst not in sub.vertices:
                    sub.vertices.add(c.dst)
                    nxt.append(c.dst)
        frontier = nxt
    # backward
    frontier = list(seeds)
    while frontier:
        nxt = []
        for v in frontier:
            jv_prev = pred.get(v.job_vertex)
            if jv_prev is None:
                continue
            for c in rg.in_channels(v):
                if c.src.job_vertex != jv_prev or c.src.job_vertex not in on_path:
                    continue
                sub.channels.add(c)
                if c.src not in sub.vertices:
                    sub.vertices.add(c.src)
                    nxt.append(c.src)
        frontier = nxt
    return sub


def get_qos_managers(
    path: tuple[str, ...], rg: RuntimeGraph, constraint: JobConstraint
) -> list[ManagerAllocation]:
    anchor = get_anchor_vertex(path, rg)
    ret: list[ManagerAllocation] = []
    for worker, tasks in sorted(partition_by_worker(rg.tasks_of(anchor), rg).items()):
        sub = graph_expand(tasks, rg, path)
        scope = ConstraintScope(constraint, path, anchor, tuple(tasks))
        ret.append(ManagerAllocation(worker, sub, [scope]))
    return ret


# ---------------------------------------------------------------------------
# Algorithm 1 — ComputeQoSSetup
# ---------------------------------------------------------------------------


def get_constrained_paths(
    jg: JobGraph, constraints: list[JobConstraint]
) -> list[tuple[tuple[str, ...], JobConstraint]]:
    """Paths (tuples of job vertices) covered by a job constraint.  Each
    constraint's sequence spans exactly one path (depth-first traversal of the
    job graph is only needed when a constraint is given as endpoints; our
    JobSequence already encodes the path)."""
    return [(jc.sequence.covered_path(), jc) for jc in constraints]


def compute_qos_setup(
    jg: JobGraph, constraints: list[JobConstraint], rg: RuntimeGraph
) -> dict[int, ManagerAllocation]:
    """Algorithm 1: returns worker -> merged ManagerAllocation."""
    managers: dict[int, ManagerAllocation] = {}
    for path, jc in get_constrained_paths(jg, constraints):
        for alloc in get_qos_managers(path, rg, jc):
            if alloc.worker in managers:
                managers[alloc.worker].merge(alloc)
            else:
                managers[alloc.worker] = alloc
    return managers


# ---------------------------------------------------------------------------
# QoS Reporter setup (§3.4.2): which reporter sends what to which manager
# ---------------------------------------------------------------------------


@dataclass
class ReporterAssignment:
    """Per worker: element ids whose measurements go to each manager.

    Channel latency is measured on the *receiving* worker (the tag is
    evaluated there); output-buffer lifetime on the *sending* worker; task
    latency on the task's own worker.
    """

    # worker -> manager -> element ids
    task_routes: dict[int, dict[int, set[str]]] = field(default_factory=dict)
    channel_routes: dict[int, dict[int, set[str]]] = field(default_factory=dict)

    def _add(self, table: dict, worker: int, mgr: int, elem: str) -> None:
        table.setdefault(worker, {}).setdefault(mgr, set()).add(elem)

    def managers_for_channel(self, worker: int, channel_id: str) -> list[int]:
        return [m for m, els in self.channel_routes.get(worker, {}).items()
                if channel_id in els]


def compute_reporter_setup(
    managers: dict[int, ManagerAllocation], rg: RuntimeGraph
) -> ReporterAssignment:
    ra = ReporterAssignment()
    for mgr_worker, alloc in managers.items():
        for v in alloc.subgraph.vertices:
            ra._add(ra.task_routes, rg.worker(v), mgr_worker, v.id)
        for c in alloc.subgraph.channels:
            # receiver-side: tag evaluation -> channel latency
            ra._add(ra.channel_routes, rg.worker(c.dst), mgr_worker, c.id)
            # sender-side: output buffer lifetime + current buffer size
            ra._add(ra.channel_routes, rg.worker(c.src), mgr_worker, c.id)
    return ra


# ---------------------------------------------------------------------------
# Side-condition checks (used by tests; paper §3.4.2 objectives)
# ---------------------------------------------------------------------------


def check_side_conditions(
    managers: dict[int, ManagerAllocation],
    constraints: list[JobConstraint],
    rg: RuntimeGraph,
) -> None:
    """Raise AssertionError if the setup violates the paper's side conditions."""
    # 1. every constraint attended: each anchor task of each constraint is
    #    owned by exactly one manager, and the anchor tasks across managers
    #    cover the anchor job vertex's full task set.
    for jc in constraints:
        path = jc.sequence.covered_path()
        owned: list[RuntimeVertex] = []
        for alloc in managers.values():
            for scope in alloc.scopes:
                if scope.constraint is jc:
                    owned.extend(scope.anchor_tasks)
        anchor = get_anchor_vertex(path, rg)
        assert sorted(v.id for v in owned) == sorted(
            v.id for v in rg.tasks_of(anchor)
        ), f"constraint {jc.name} not fully covered / double covered"
    # 2. minimality: every vertex in a subgraph lies on a constrained path.
    for alloc in managers.values():
        on_paths = set()
        for p in alloc.subgraph.job_paths:
            on_paths |= set(p)
        for v in alloc.subgraph.vertices:
            assert v.job_vertex in on_paths, f"irrelevant vertex {v} in subgraph"
