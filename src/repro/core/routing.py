"""Key-range routing + per-key task state (elastic state migration).

The paper's elastic re-parallelization (§6) re-spreads key routing when a
stage's parallelism changes.  A bare ``key % group_size`` re-homes *every*
key on *every* rescale, which silently detaches keys from any per-key state
their old owner held.  The standard fix (key-range repartitioning with state
handoff — Röger & Mayer's elasticity survey, Fragkoulis et al.'s stream
systems survey) is implemented here:

* ``KeyRouter`` — every consumer group owns a fixed number of *virtual key
  ranges* (``NUM_KEY_RANGES``, default 128).  A key hashes to a range, a
  range maps to one owning subtask index.  On rescale the table is
  **remapped, not rehashed**: a minimal, balanced set of ranges moves to the
  new/surviving owners and every other range keeps its owner — unmoved keys
  never change subtask.  ``plan()`` computes the remap without mutating the
  table; the execution layer migrates the moved ranges' state and then
  ``commit()``s the new table in one atomic swap.

O(1) emit-path contract: the dense range->owner table is public as
``KeyRouter.table`` (an immutable tuple of ``num_ranges`` owner indices),
and when ``num_ranges`` is a power of two ``KeyRouter.mask`` is
``num_ranges - 1`` — so for integer keys the per-item routing decision on
both backends' emit hot paths is the single masked array index
``router.table[key & router.mask]``, equivalent to ``owner(key)`` (Python's
``&`` on negative ints follows two's complement, matching ``%``).
``commit()`` swaps ``table`` atomically together with the owner view, so a
reader sees either the pre- or post-migration table, never a partial remap.
* ``StateStore`` — optional per-task keyed state with a
  ``snapshot(key_ranges)`` / ``restore(entries)`` API sliced along the same
  virtual ranges, so a migration moves exactly the re-homed keys.

Both execution backends (core/engine.py, core/simulator.py) route keyed
emissions through the group's ``KeyRouter`` — the single replacement for the
former ad-hoc modulo sites — and ``RuntimeRewirer`` (core/elastic.py) drives
the pause-drain-snapshot-install-swap migration protocol around it.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any, Iterable

from ..analysis import race as _race

#: fixed virtual-partition count shared by routers and state stores; a power
#: of two well above any realistic parallelism so ranges stay divisible.
NUM_KEY_RANGES = 128

#: widest routing table the stock policy will configure (power of two;
#: covers the paper's m=800 grid).
WIDE_KEY_RANGES = 1024


def key_ranges_for(group_size: int) -> int | None:
    """Routing-table width for a stage of ``group_size`` subtasks.

    None (the default ``NUM_KEY_RANGES`` table) while it can address the
    group; ``WIDE_KEY_RANGES`` for paper-scale groups.  A group beyond the
    widest table fails fast with a clear message — silently mis-routing
    would starve every subtask past the addressable-owner count
    (``KeyRouter`` refuses such tables outright; this names the knob)."""
    if group_size <= NUM_KEY_RANGES:
        return None
    if group_size > WIDE_KEY_RANGES:
        raise ValueError(
            f"group_size {group_size} exceeds the {WIDE_KEY_RANGES} "
            f"addressable key-range owners of the widest stock table; "
            f"raise WIDE_KEY_RANGES (a power of two >= group_size) in "
            f"core/routing.py to run such a grid")
    return WIDE_KEY_RANGES


def range_of_key(key: Any, num_ranges: int = NUM_KEY_RANGES) -> int:
    """Key -> virtual range.  Integer keys map directly: dense integer key
    populations (stream-group ids, request ids — what every scenario here
    uses) then spread perfectly evenly over the range space, matching the
    historical ``key % group_size`` balance exactly when nothing has been
    rescaled.  Because a correlated key set may still leave some ranges
    cold, ``KeyRouter.plan`` spreads every donation evenly across the
    donor's owned ranges instead of carving off a contiguous block.
    Non-integer keys go through ``hash``."""
    k = key if isinstance(key, int) else hash(key)
    return k % num_ranges


@dataclass(frozen=True, slots=True)
class MigrationPlan:
    """A computed (not yet applied) routing-table remap.

    ``moves`` holds only the ranges that change owner:
    ``range -> (old_owner, new_owner)``.  Everything else keeps its owner.
    """

    new_size: int
    new_owners: tuple[int, ...]
    moves: dict[int, tuple[int, int]] = field(default_factory=dict)

    @property
    def sources(self) -> list[int]:
        """Old owners that lose at least one range (migration sources)."""
        return sorted({old for old, _ in self.moves.values()})

    @property
    def targets(self) -> list[int]:
        """Owners that gain at least one range (migration targets)."""
        return sorted({new for _, new in self.moves.values()})

    def ranges_from(self, owner: int) -> list[int]:
        """Ranges this plan takes away from ``owner``."""
        return sorted(r for r, (old, _) in self.moves.items() if old == owner)


class KeyRouter:
    """Key-range -> subtask assignment table for one consumer group.

    The owner table is an immutable tuple; readers on the emit hot path see
    either the old or the new table, never a partial remap.
    """

    __slots__ = ("num_ranges", "group_size", "mask", "table")

    def __init__(self, group_size: int,
                 num_ranges: int = NUM_KEY_RANGES) -> None:
        if group_size < 1:
            raise ValueError("group_size must be >= 1")
        if group_size > num_ranges:
            # a router can address at most num_ranges distinct owners: with
            # more subtasks than ranges, owners >= num_ranges would simply
            # never receive a key.  Fail fast instead of silently
            # mis-routing (paper-scale m=800 needs num_ranges >= m).
            raise ValueError(
                f"group_size {group_size} exceeds num_ranges {num_ranges}: "
                f"owners >= {num_ranges} would never be addressed — "
                f"construct the router with num_ranges >= group_size "
                f"(a power of two keeps the masked fast path)")
        self.num_ranges = num_ranges
        self.group_size = group_size
        #: ``num_ranges - 1`` when the range count is a power of two (the
        #: default): integer keys route as ``table[key & mask]`` — one masked
        #: array index on the emit hot path.  None otherwise (fall back to
        #: ``owner()``).
        self.mask: int | None = (
            num_ranges - 1 if num_ranges & (num_ranges - 1) == 0 else None)
        #: dense range -> owner lookup table (public emit-path view).  An
        #: immutable tuple swapped atomically by ``commit()``; readers see
        #: either the old or the new table, never a partial remap.
        self.table: tuple[int, ...] = tuple(
            r % group_size for r in range(num_ranges))

    # back-compat internal alias (pre-O(1)-table name)
    @property
    def _owners(self) -> tuple[int, ...]:
        return self.table

    # -- routing (hot path) --------------------------------------------------
    def range_of(self, key: Any) -> int:
        return range_of_key(key, self.num_ranges)

    def owner(self, key: Any) -> int:
        """Subtask index that owns ``key``.  Equivalent to the inlined
        ``table[key & mask]`` fast path both backends use for int keys."""
        return self.table[range_of_key(key, self.num_ranges)]

    def owner_of_range(self, r: int) -> int:
        return self.table[r]

    def ranges_of(self, owner: int) -> list[int]:
        return [r for r, o in enumerate(self.table) if o == owner]

    # -- rescale -------------------------------------------------------------
    def plan(self, new_size: int) -> MigrationPlan:
        """Compute the minimal balanced remap for ``new_size`` owners.

        Invariants: every owner ends with ``num_ranges/new_size`` ranges
        (+/-1); only over-target or orphaned (owner >= new_size) ranges
        move; the choice is deterministic.  Donations are spread EVENLY
        across each donor's owned ranges (Bresenham selection) and handed to
        the gaining owners round-robin — so even when a key population only
        heats part of the range space (e.g. dense ids narrower than
        ``num_ranges``), every rescale still sheds a proportional share of
        the hot ranges to every gaining owner."""
        if new_size < 1:
            raise ValueError("new_size must be >= 1")
        if new_size > self.num_ranges:
            raise ValueError(
                f"new_size {new_size} exceeds num_ranges {self.num_ranges}: "
                f"owners >= {self.num_ranges} would never be addressed")
        old = self.table
        base, rem = divmod(self.num_ranges, new_size)
        targets = [base + (1 if i < rem else 0) for i in range(new_size)]
        owned: dict[int, list[int]] = {}
        for r, o in enumerate(old):
            owned.setdefault(o, []).append(r)
        kept = [0] * new_size
        orphans: list[int] = []
        for o in sorted(owned):
            rs = owned[o]
            if o >= new_size:
                orphans.extend(rs)  # retiring owner: everything moves
                continue
            excess = len(rs) - targets[o]
            if excess <= 0:
                kept[o] = len(rs)
                continue
            n = len(rs)
            # Bresenham spread: donate `excess` of the n owned ranges at
            # even intervals, keep the rest in place
            donated = [rs[i] for i in range(n)
                       if (i + 1) * excess // n > i * excess // n]
            orphans.extend(donated)
            kept[o] = n - excess
        gaining = [o for o in range(new_size) if kept[o] < targets[o]]
        slots = {o: targets[o] - kept[o] for o in gaining}
        new_owners = list(old)
        moves: dict[int, tuple[int, int]] = {}
        gi = 0
        for r in orphans:
            while slots[gaining[gi % len(gaining)]] == 0:
                gi += 1
            o = gaining[gi % len(gaining)]
            slots[o] -= 1
            gi += 1
            new_owners[r] = o
            if old[r] != o:
                moves[r] = (old[r], o)
        return MigrationPlan(new_size, tuple(new_owners), moves)

    def commit(self, plan: MigrationPlan) -> None:
        """Atomically swap in the planned table (after state migration).
        A single tuple rebind: emit-path readers of ``table`` see either the
        old or the new mapping in full."""
        self.table = plan.new_owners
        self.group_size = plan.new_size


class _NullLock:
    """No-op context manager for single-threaded stores (the discrete-event
    simulator): migration runs within one event, so there is nothing to
    exclude and the per-item ``bump`` on stateful stages skips the real
    lock's acquire/release cost."""

    __slots__ = ()

    def __enter__(self) -> "_NullLock":
        return self

    def __exit__(self, *exc) -> None:
        return None


_NULL_LOCK = _NullLock()


class StateStore:
    """Per-task keyed state, sliced along the router's virtual key ranges.

    User code (threaded engine: ``ctx.state`` inside the task fn) reads and
    writes per-key entries; the migration protocol moves whole ranges with
    ``snapshot(key_ranges, evict=True)`` on the old owner and
    ``restore(entries)`` on the new one.  All operations take the store lock
    so a snapshot never observes a half-applied update from the task thread.
    Single-threaded executors pass ``locked=False`` to skip the real lock
    (the discrete-event simulator bumps stateful stages once per item).
    """

    __slots__ = ("num_ranges", "_data", "_lock")

    def __init__(self, num_ranges: int = NUM_KEY_RANGES,
                 locked: bool = True) -> None:
        self.num_ranges = num_ranges
        self._data: dict[Any, Any] = {}
        # make_lock IS threading.Lock when the race detector is off
        # (NS-L006: race-instrumented modules never construct raw locks)
        self._lock = _race.make_lock() if locked else _NULL_LOCK

    # -- per-key access ------------------------------------------------------
    def get(self, key: Any, default: Any = None) -> Any:
        with self._lock:
            return self._data.get(key, default)

    def put(self, key: Any, value: Any) -> None:
        with self._lock:
            self._data[key] = value

    def bump(self, key: Any, amount: int = 1) -> int:
        """Increment-and-get — the common keyed-aggregate primitive."""
        lock = self._lock
        if lock is _NULL_LOCK:  # single-threaded fast path (simulator)
            data = self._data
            v = data.get(key, 0) + amount
            data[key] = v
            return v
        with lock:
            v = self._data.get(key, 0) + amount
            self._data[key] = v
            return v

    def pop(self, key: Any, default: Any = None) -> Any:
        with self._lock:
            return self._data.pop(key, default)

    def keys(self) -> list[Any]:
        with self._lock:
            return list(self._data.keys())

    def items(self) -> list[tuple[Any, Any]]:
        with self._lock:
            return list(self._data.items())

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)

    def __contains__(self, key: Any) -> bool:
        with self._lock:
            return key in self._data

    # -- migration API -------------------------------------------------------
    def snapshot(self, key_ranges: Iterable[int],
                 evict: bool = True) -> dict[Any, Any]:
        """Extract every entry whose key falls in ``key_ranges``.  With
        ``evict`` (the migration default) the entries leave this store so no
        key is ever served by two owners."""
        ranges = set(key_ranges)
        with self._lock:
            hit = {k: v for k, v in self._data.items()
                   if range_of_key(k, self.num_ranges) in ranges}
            if evict:
                for k in hit:
                    del self._data[k]
        return hit

    def restore(self, entries: dict[Any, Any]) -> None:
        """Install migrated entries (new-owner side of a handoff)."""
        with self._lock:
            self._data.update(entries)


# -- lockset race detector hook (analysis/race.py) ---------------------------
# Selected ONCE at import: with REPRO_RACE_CHECK unset the classes above are
# untouched and the hot paths run the exact same bytecode as before this
# hook existed.  With the flag set, keyed-state accesses and rescale-side
# router writes feed the per-thread lockset checker.
if _race.RACE_CHECK:  # pragma: no cover - exercised via subprocess tests
    _race.instrument_state_store(StateStore)
    _race.instrument_key_router(KeyRouter)
