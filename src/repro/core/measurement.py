"""Workflow latency measurement (paper §3.3).

Per worker node a *QoS Reporter* collects, once per *measurement interval*:

1. channel latencies, estimated via **tagged data items** — a tag is a small
   record (creation timestamp + channel id) attached when an item exits the
   sender's user code and evaluated just before it enters the receiver's user
   code; one tagged item per channel per interval,
2. the **output buffer lifetime** ``oblt(e)`` per locally outgoing channel —
   the average time output buffers took to fill,
3. task latencies, sampled (no tags needed): once per interval, the time
   between an item entering the user code and the next item leaving it.

Reports are pre-aggregated locally and flushed to each interested QoS Manager
once per interval, at a per-manager random offset to avoid report bursts.
"""
from __future__ import annotations

import math
import random
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Iterable

from .clock import Clock
from .graphs import Channel, RuntimeVertex


def latency_percentile(latencies_ms: Iterable[float], q: float) -> float:
    """Nearest-rank percentile: the ceil(q*n)-th smallest value (NaN when
    empty).  The ONE percentile definition shared by both backends' result
    types, so cross-backend comparisons compare the same order statistic.
    The epsilon guards float artifacts like ``0.95 * 20 == 19.000000000004``
    rounding the rank up a step."""
    xs = sorted(latencies_ms)
    if not xs:
        return float("nan")
    n = len(xs)
    rank = max(1, min(n, math.ceil(q * n - 1e-9)))
    return xs[rank - 1]

# ---------------------------------------------------------------------------
# Tags & running averages
# ---------------------------------------------------------------------------


@dataclass
class Tag:
    """Timestamp tag piggy-backed on a data item (one per channel/interval)."""

    channel_id: str
    created_at_ms: float


class RunningAverage:
    """Windowed running average: values fresher than ``window_ms`` (Eq. 1's
    time span t); older measurements are discarded (§3.3).

    Eviction runs on ``add()`` as well as on reads: a window that keeps
    receiving samples but is rarely read (e.g. a channel whose manager
    moved away, or an idle stretch between manager reads) stays bounded
    instead of accumulating every sample until the next ``value()`` call.
    Results are unchanged — an entry evicted at add time could never have
    contributed to a later read (timestamps are monotonic).
    """

    __slots__ = ("window_ms", "_items",)

    def __init__(self, window_ms: float) -> None:
        self.window_ms = window_ms
        self._items: deque[tuple[float, float]] = deque()  # (ts, value)

    def add(self, ts_ms: float, value: float) -> None:
        self._evict(ts_ms)
        self._items.append((ts_ms, value))

    def _evict(self, now_ms: float) -> None:
        fresh_after = now_ms - self.window_ms
        items = self._items
        while items and items[0][0] < fresh_after:
            items.popleft()

    def value(self, now_ms: float) -> float | None:
        self._evict(now_ms)
        if not self._items:
            return None
        return sum(v for _, v in self._items) / len(self._items)

    def count(self, now_ms: float) -> int:
        self._evict(now_ms)
        return len(self._items)


class RateMeter:
    """Cumulative counter -> instantaneous rate samples (items/s).

    The predictive-QoS estimators (core/estimation.py) want periodic rate
    samples; both backends only expose monotonically growing cumulative
    counts (source sequence numbers, per-stage emitted counters).  A
    ``RateMeter`` holds the last (timestamp, count) pair and turns the next
    observation into a rate over the elapsed span.  Counts may reset
    downward across a rescale (a retired replica's counter disappears from
    the sum) — a negative delta yields a zero-rate sample rather than a
    negative one.
    """

    __slots__ = ("_last_ms", "_last_count")

    def __init__(self) -> None:
        self._last_ms: float | None = None
        self._last_count = 0.0

    def sample(self, now_ms: float, count: float) -> float | None:
        """Fold in a cumulative observation; return the rate (items/s) since
        the previous observation, or ``None`` on the first call / zero
        elapsed time (no span to rate over)."""
        last_ms, last_count = self._last_ms, self._last_count
        self._last_ms, self._last_count = now_ms, count
        if last_ms is None or now_ms <= last_ms:
            return None
        return max(count - last_count, 0.0) / ((now_ms - last_ms) / 1e3)


# ---------------------------------------------------------------------------
# Reports
# ---------------------------------------------------------------------------


@dataclass
class ChannelStats:
    channel_id: str
    mean_latency_ms: float | None = None     # from tag round-trips (receiver side)
    mean_oblt_ms: float | None = None        # output buffer lifetime (sender side)
    buffer_size_bytes: int | None = None     # current obs(e) (sender side)
    buffer_size_version: int = 0             # §3.5.1 update-race bookkeeping
    n_samples: int = 0


@dataclass
class TaskStats:
    vertex_id: str
    mean_latency_ms: float | None = None
    cpu_utilization: float = 0.0             # busy fraction of one core (§3.5.2)
    chained: bool = False
    n_samples: int = 0


@dataclass
class QoSReport:
    """One reporter -> one manager, once per measurement interval, as-needed
    (empty reports are not sent)."""

    worker: int
    sent_at_ms: float
    channel_stats: list[ChannelStats] = field(default_factory=list)
    task_stats: list[TaskStats] = field(default_factory=list)

    def empty(self) -> bool:
        return not self.channel_stats and not self.task_stats


# ---------------------------------------------------------------------------
# QoS Reporter (worker-node role)
# ---------------------------------------------------------------------------


class QoSReporter:
    """Worker-node background role (§3.4.1): pre-aggregates local measurement
    data and prepares one report per interested QoS Manager.

    The execution layer (engine or simulator) feeds raw measurements in via
    ``record_*``; ``maybe_flush`` returns the due (manager, report) pairs.
    """

    def __init__(
        self,
        worker: int,
        clock: Clock,
        interval_ms: float,
        rng: random.Random | None = None,
    ) -> None:
        self.worker = worker
        self.clock = clock
        self.interval_ms = interval_ms
        self.rng = rng or random.Random(worker)
        # manager id -> elements it is interested in
        self._mgr_channels: dict[int, set[str]] = {}
        self._mgr_tasks: dict[int, set[str]] = {}
        # per-manager random report offset (§3.3 "random offset")
        self._mgr_offset: dict[int, float] = {}
        self._last_flush: dict[int, float] = {}
        # interval aggregation buffers: id -> (sum, count)
        self._chan_lat: dict[str, tuple[float, int]] = {}
        self._chan_oblt: dict[str, tuple[float, int]] = {}
        self._chan_buf: dict[str, tuple[int, int]] = {}  # id -> (bytes, version)
        self._task_lat: dict[str, tuple[float, int]] = {}
        self._task_cpu: dict[str, float] = {}
        self._task_chained: dict[str, bool] = {}
        # tagging bookkeeping: channel id -> timestamp of last tag sent
        self._last_tagged: dict[str, float] = {}
        self._last_task_sample: dict[str, float] = {}

    # -- setup (master-driven, §3.4.2) ---------------------------------------
    def assign_manager(
        self, manager_id: int, channels: Iterable[str], tasks: Iterable[str]
    ) -> None:
        self._mgr_channels.setdefault(manager_id, set()).update(channels)
        self._mgr_tasks.setdefault(manager_id, set()).update(tasks)
        if manager_id not in self._mgr_offset:
            self._mgr_offset[manager_id] = self.rng.uniform(0, self.interval_ms)
            self._last_flush[manager_id] = -float("inf")

    def reset_assignments(self) -> None:
        """Drop manager routes ahead of a QoS-setup refresh (elastic
        re-wiring): per-manager flush offsets/cadence survive, so managers
        that persist across the refresh keep their report rhythm."""
        self._mgr_channels.clear()
        self._mgr_tasks.clear()

    def interested_channels(self) -> set[str]:
        out: set[str] = set()
        for s in self._mgr_channels.values():
            out |= s
        return out

    def interested_tasks(self) -> set[str]:
        out: set[str] = set()
        for s in self._mgr_tasks.values():
            out |= s
        return out

    # -- sampling decisions ----------------------------------------------------
    def should_tag(self, channel_id: str, now: float | None = None) -> bool:
        """One tagged item per channel per measurement interval (§3.3).
        Hot-path callers that already know the current time pass ``now``."""
        if now is None:
            now = self.clock.now()
        last = self._last_tagged.get(channel_id)
        if last is None or now - last >= self.interval_ms:
            self._last_tagged[channel_id] = now
            return True
        return False

    def should_sample_task(self, vertex_id: str,
                           now: float | None = None) -> bool:
        if now is None:
            now = self.clock.now()
        last = self._last_task_sample.get(vertex_id)
        if last is None or now - last >= self.interval_ms:
            self._last_task_sample[vertex_id] = now
            return True
        return False

    # -- raw measurement ingestion ---------------------------------------------
    def record_channel_latency(self, channel_id: str, latency_ms: float) -> None:
        s, c = self._chan_lat.get(channel_id, (0.0, 0))
        self._chan_lat[channel_id] = (s + latency_ms, c + 1)

    def record_channel_latency_batch(self, channel_id: str,
                                     latencies_ms: Iterable[float]) -> None:
        """Array ingestion for batched executors: one call folds a run's
        samples into the interval aggregate.  Equivalent to calling
        ``record_channel_latency`` per element in order (the aggregate is a
        left-folded (sum, count) pair, so the float arithmetic matches)."""
        s, c = self._chan_lat.get(channel_id, (0.0, 0))
        n = 0
        for lat in latencies_ms:
            s += lat
            n += 1
        self._chan_lat[channel_id] = (s, c + n)

    def record_output_buffer_lifetime(self, channel_id: str, lifetime_ms: float,
                                      buffer_size: int, version: int) -> None:
        s, c = self._chan_oblt.get(channel_id, (0.0, 0))
        self._chan_oblt[channel_id] = (s + lifetime_ms, c + 1)
        self._chan_buf[channel_id] = (buffer_size, version)

    def record_task_latency(self, vertex_id: str, latency_ms: float) -> None:
        s, c = self._task_lat.get(vertex_id, (0.0, 0))
        self._task_lat[vertex_id] = (s + latency_ms, c + 1)

    def record_task_cpu(self, vertex_id: str, utilization: float,
                        chained: bool = False) -> None:
        self._task_cpu[vertex_id] = utilization
        self._task_chained[vertex_id] = chained

    # -- flushing ---------------------------------------------------------------
    def maybe_flush(self) -> list[tuple[int, QoSReport]]:
        """Return (manager_id, report) pairs that are due now."""
        now = self.clock.now()
        out: list[tuple[int, QoSReport]] = []
        for mgr in self._mgr_channels.keys() | self._mgr_tasks.keys():
            due = self._last_flush[mgr] + self.interval_ms
            if self._last_flush[mgr] == -float("inf"):
                due = self._mgr_offset[mgr]
            if now < due:
                continue
            report = self._build_report(mgr, now)
            self._last_flush[mgr] = now
            if not report.empty():  # as-needed: no empty reports (§3.4.1)
                out.append((mgr, report))
        if out:
            self._clear_flushed(out)
        return out

    def _build_report(self, mgr: int, now: float) -> QoSReport:
        rep = QoSReport(worker=self.worker, sent_at_ms=now)
        for ch in self._mgr_channels.get(mgr, ()):
            lat = self._chan_lat.get(ch)
            ob = self._chan_oblt.get(ch)
            buf = self._chan_buf.get(ch)
            if lat is None and ob is None:
                continue
            rep.channel_stats.append(
                ChannelStats(
                    channel_id=ch,
                    mean_latency_ms=None if lat is None else lat[0] / lat[1],
                    mean_oblt_ms=None if ob is None else ob[0] / ob[1],
                    buffer_size_bytes=None if buf is None else buf[0],
                    buffer_size_version=0 if buf is None else buf[1],
                    n_samples=(lat[1] if lat else 0) + (ob[1] if ob else 0),
                )
            )
        for tk in self._mgr_tasks.get(mgr, ()):
            lat = self._task_lat.get(tk)
            if lat is None and tk not in self._task_cpu:
                continue
            rep.task_stats.append(
                TaskStats(
                    vertex_id=tk,
                    mean_latency_ms=None if lat is None else lat[0] / lat[1],
                    cpu_utilization=self._task_cpu.get(tk, 0.0),
                    chained=self._task_chained.get(tk, False),
                    n_samples=0 if lat is None else lat[1],
                )
            )
        return rep

    def _clear_flushed(self, flushed: list[tuple[int, QoSReport]]) -> None:
        # Aggregation buffers are per-interval; once any report went out we
        # reset the buffers for the elements included in it.
        for _, rep in flushed:
            for cs in rep.channel_stats:
                self._chan_lat.pop(cs.channel_id, None)
                self._chan_oblt.pop(cs.channel_id, None)
            for ts in rep.task_stats:
                self._task_lat.pop(ts.vertex_id, None)
