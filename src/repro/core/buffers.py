"""Output buffers and adaptive sizing (paper §2.2.1, §3.5.1).

Data items produced by a task are collected in an output buffer; the buffer
ships once its byte capacity ``obs(e)`` is reached (Fig. 1).  Buffer size is
the primary latency<->throughput knob (Fig. 2).

Adaptive sizing (§3.5.1), run by QoS managers on violated sequences:

* estimated output-buffer latency ``obl(e,t) = oblt(e,t) / 2``
* shrink when ``obl`` exceeds both a minimum threshold (default 5 ms) and the
  task latency of the channel's source task:

      obs*(e) = max(eps, obs(e) * r ** obl(e,t))        (Eq. 2)

  with defaults r = 0.98 (per ms), eps = 200 bytes.
* grow when ``obl ~ 0`` (buffers filling faster than the threshold):

      obs*(e) = min(omega, s * obs(e))                  (Eq. 3)

  with defaults s = 1.1 and omega an upper bound (32 KB in the evaluation).

Update races (§3.5.1): several managers can share a channel; the worker
applies the *first* update computed against the current version and discards
updates computed against stale versions, then advertises the new
(size, version) through the next reports.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

# Paper defaults.
DEFAULT_R = 0.98
DEFAULT_EPS_BYTES = 200
DEFAULT_S = 1.1
DEFAULT_OMEGA_BYTES = 64 * 1024
DEFAULT_MIN_OBL_MS = 5.0
#: below this, ``obl`` counts as ~0 and the buffer may grow (Eq. 3).
DEFAULT_ZERO_OBL_MS = 1.0


@dataclass(slots=True)
class BufferSizingPolicy:
    r: float = DEFAULT_R
    eps_bytes: int = DEFAULT_EPS_BYTES
    s: float = DEFAULT_S
    omega_bytes: int = DEFAULT_OMEGA_BYTES
    min_obl_ms: float = DEFAULT_MIN_OBL_MS
    zero_obl_ms: float = DEFAULT_ZERO_OBL_MS

    def propose(
        self,
        obs_bytes: int,
        obl_ms: float,
        src_task_latency_ms: float | None,
    ) -> int | None:
        """Return the new buffer size, or None if no change is warranted."""
        if obl_ms > self.min_obl_ms and (
            src_task_latency_ms is None or obl_ms > src_task_latency_ms
        ):
            new = max(self.eps_bytes, int(obs_bytes * (self.r ** obl_ms)))
            return new if new != obs_bytes else None
        if obl_ms < self.zero_obl_ms:
            new = min(self.omega_bytes, int(self.s * obs_bytes) + 1)
            return new if new != obs_bytes else None
        return None


@dataclass(slots=True)
class OutputBuffer:
    """A byte-capacity output buffer on one channel (sender side).

    The execution layer appends serialized items; ``append`` returns True when
    the buffer must be shipped.  Lifetime (fill time) feeds ``oblt(e,t)``.
    ``version`` implements the §3.5.1 first-writer-wins update rule.
    Slotted: both backends touch it once per item on their emit hot paths.
    """

    channel_id: str
    capacity_bytes: int
    version: int = 0
    items: list[Any] = field(default_factory=list)
    used_bytes: int = 0
    opened_at_ms: float | None = None

    def append(self, item: Any, size_bytes: int, now_ms: float) -> bool:
        if self.opened_at_ms is None:
            self.opened_at_ms = now_ms
        self.items.append(item)
        self.used_bytes += size_bytes
        return self.used_bytes >= self.capacity_bytes

    def room_for(self, size_bytes: int) -> int:
        """How many more items of ``size_bytes`` this buffer takes before it
        crosses capacity and must ship (>= 1: ``append`` only reports *after*
        the crossing item lands).  Batch-aware fill accounting: a batched
        sender splits a same-size run at these arithmetic fill points
        instead of checking capacity item by item."""
        if size_bytes <= 0:
            return 1 << 30
        remaining = self.capacity_bytes - self.used_bytes
        if remaining <= size_bytes:
            return 1
        return -(-remaining // size_bytes)  # ceil div

    def append_run(self, items: list[Any], size_bytes_each: int,
                   opened_at_ms: float) -> bool:
        """Append a whole same-size run in one call — byte accounting and
        open-timestamp semantics identical to per-item ``append`` at the
        run's first-item time.  The caller guarantees (via ``room_for``)
        that at most the final item crosses capacity; returns True when it
        did (the buffer must ship at that item's emission instant)."""
        if self.opened_at_ms is None:
            self.opened_at_ms = opened_at_ms
        self.items.extend(items)
        self.used_bytes += size_bytes_each * len(items)
        return self.used_bytes >= self.capacity_bytes

    def take(self, now_ms: float) -> tuple[list[Any], int, float]:
        """Ship the buffer: returns (items, bytes, lifetime_ms) and resets."""
        lifetime = 0.0 if self.opened_at_ms is None else now_ms - self.opened_at_ms
        out, nbytes = self.items, self.used_bytes
        self.items, self.used_bytes, self.opened_at_ms = [], 0, None
        return out, nbytes, lifetime

    @property
    def empty(self) -> bool:
        return not self.items

    def try_update_size(self, new_size: int, base_version: int) -> bool:
        """First-writer-wins (§3.5.1): apply only if the requester computed the
        update against the current version."""
        if base_version != self.version:
            return False
        self.capacity_bytes = max(1, int(new_size))
        self.version += 1
        return True


class BufferArena:
    """Struct-of-arrays twin of :class:`OutputBuffer` for the simulator.

    One arena holds the fill state of *every* simulated channel in five
    parallel columns indexed by a dense channel id (``chi`` from
    :meth:`alloc`): pending items, used bytes, open timestamp, byte
    capacity, and the §3.5.1 update version.  The semantics of each
    operation mirror ``OutputBuffer`` field for field — same capacity
    crossing rule, same lifetime accounting, same first-writer-wins
    version check — so the simulator's decision traces are bit-identical
    whichever representation backs a channel.  The simulator's inlined
    dispatch loop reads the columns directly; under instrumentation
    (``REPRO_SANITIZE=1`` / ``REPRO_RACE_CHECK=1``) the simulator keeps
    per-channel ``OutputBuffer`` objects instead, because the checkers
    wrap those methods.
    """

    __slots__ = ("items", "used", "opened", "cap", "ver")

    def __init__(self) -> None:
        self.items: list[list[Any]] = []
        self.used: list[int] = []
        self.opened: list[float | None] = []
        self.cap: list[int] = []
        self.ver: list[int] = []

    def alloc(self, capacity_bytes: int) -> int:
        """Add one channel; returns its dense column index."""
        chi = len(self.cap)
        self.items.append([])
        self.used.append(0)
        self.opened.append(None)
        self.cap.append(capacity_bytes)
        self.ver.append(0)
        return chi

    def append(self, chi: int, item: Any, size_bytes: int,
               now_ms: float) -> bool:
        if self.opened[chi] is None:
            self.opened[chi] = now_ms
        self.items[chi].append(item)
        used = self.used[chi] + size_bytes
        self.used[chi] = used
        return used >= self.cap[chi]

    def room_for(self, chi: int, size_bytes: int) -> int:
        if size_bytes <= 0:
            return 1 << 30
        remaining = self.cap[chi] - self.used[chi]
        if remaining <= size_bytes:
            return 1
        return -(-remaining // size_bytes)  # ceil div

    def append_run(self, chi: int, items: list[Any], size_bytes_each: int,
                   opened_at_ms: float) -> bool:
        if self.opened[chi] is None:
            self.opened[chi] = opened_at_ms
        self.items[chi].extend(items)
        used = self.used[chi] + size_bytes_each * len(items)
        self.used[chi] = used
        return used >= self.cap[chi]

    def take(self, chi: int, now_ms: float) -> tuple[list[Any], int, float]:
        opened = self.opened[chi]
        lifetime = 0.0 if opened is None else now_ms - opened
        out, nbytes = self.items[chi], self.used[chi]
        self.items[chi] = []
        self.used[chi] = 0
        self.opened[chi] = None
        return out, nbytes, lifetime

    def try_update_size(self, chi: int, new_size: int,
                        base_version: int) -> bool:
        if base_version != self.ver[chi]:
            return False
        self.cap[chi] = max(1, int(new_size))
        self.ver[chi] += 1
        return True


# -- lockset race detector hook (analysis/race.py) ---------------------------
# Zero-cost when disabled: the class above is untouched unless the process
# was started with REPRO_RACE_CHECK=1 (the engine guards each buffer with
# its ChannelSender lock — a tracked lock under the flag — so the checker
# can prove every buffer access happens under it).
from ..analysis import race as _race  # noqa: E402

if _race.RACE_CHECK:  # pragma: no cover - exercised via subprocess tests
    _race.instrument_output_buffer(OutputBuffer)

# -- runtime invariant sanitizer hook (analysis/sanitize.py) -----------------
# Same zero-cost contract: under REPRO_SANITIZE=1 every buffer keeps an
# append/take ledger and fill accounting is verified after each operation
# (NS-S004); the ledgers also feed the channel-conservation sweeps (NS-S001).
from ..analysis import sanitize as _sanitize  # noqa: E402

if _sanitize.SANITIZE:  # pragma: no cover - exercised via subprocess tests
    _sanitize.instrument_output_buffer(OutputBuffer)
