"""Deterministic fault injection for both execution backends (§3.6).

The paper argues the QoS machinery must coexist with log-based
rollback-recovery; this module supplies the *unplanned* half of that story:
a declarative, seedable schedule of faults that either backend replays
exactly.

* ``FaultPlan`` — a builder for a time-ordered fault schedule.  The plan is
  pure data plus one private ``random.Random(seed)``; it never touches the
  executor's RNG, so a run WITHOUT a plan is bit-identical to a run of the
  same job before this module existed, and a run WITH a plan is
  reproducible from ``(job, seed, schedule)`` alone.
* fault kinds (one frozen dataclass each):
    - ``KillWorker``       — the worker vanishes at ``at_ms``: queued and
      in-service items are dropped, buffered output is lost, its sources
      stop emitting.  Exactly what a machine loss looks like from the
      master.
    - ``KillOwnerOf``      — kill whichever worker owns subtask
      ``(job_vertex, index)`` *at fire time* — the owner is resolved late,
      so a plan can target "the worker holding the migrating state" without
      knowing placement in advance.
    - ``ChannelBlackhole`` — every runtime channel of a job edge stops
      delivering for ``duration_ms`` (a network partition that heals);
      held items deliver when the partition lifts, not before.
    - ``DelaySpike``       — a stage's service time is multiplied by
      ``factor`` for ``duration_ms`` (GC pause / noisy neighbour).
* ``RecoveryEvent`` — one completed crash -> detect -> respawn -> restore ->
  replay cycle, appended to the re-wiring layer's ``recovery_log`` and
  surfaced on ``SimResult``/``EngineResult``.

Injection seams (see docs/robustness.md):

* ``StreamSimulator(fault_plan=...)`` schedules each fault as an ordinary
  simulator event; a plan forces the reference event loop so every drop is
  an explicit, accounted branch (the inlined fast loop stays fault-free and
  keeps its perf-canary bytecode).
* ``StreamEngine(fault_plan=...)`` runs an injector thread that aborts the
  victim task threads: the flag flip makes the thread exit WITHOUT its
  drain-on-exit sweep, pending inbox items are discarded, and in-flight
  emissions are swallowed — the observable footprint of a real crash.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field


@dataclass(frozen=True)
class KillWorker:
    at_ms: float
    worker: int | None = None  # None: the plan RNG picks a live worker


@dataclass(frozen=True)
class KillOwnerOf:
    """Kill the worker that owns subtask ``(job_vertex, index)`` when the
    fault fires (late-bound, so it composes with migrations in flight)."""

    at_ms: float
    job_vertex: str
    index: int = 0


@dataclass(frozen=True)
class ChannelBlackhole:
    at_ms: float
    src_vertex: str
    dst_vertex: str
    duration_ms: float


@dataclass(frozen=True)
class DelaySpike:
    at_ms: float
    job_vertex: str
    duration_ms: float
    factor: float = 8.0


Fault = KillWorker | KillOwnerOf | ChannelBlackhole | DelaySpike


@dataclass(frozen=True)
class FaultRecord:
    """One fault as it actually fired (late-bound targets resolved)."""

    at_ms: float
    kind: str
    detail: str


@dataclass(frozen=True)
class RecoveryEvent:
    """One completed recovery cycle (core/elastic.py ``recover_worker``)."""

    dead_worker: int
    replacement: int
    crash_at_ms: float
    detected_at_ms: float
    recovered_at_ms: float
    lost_vertices: tuple = ()
    restored_keys: int = 0
    replayed_items: int = 0

    @property
    def time_to_detect_ms(self) -> float:
        return self.detected_at_ms - self.crash_at_ms

    @property
    def time_to_recover_ms(self) -> float:
        return self.recovered_at_ms - self.crash_at_ms


@dataclass
class FaultPlan:
    """Seedable, deterministic fault schedule for one run.

    Builder methods return ``self`` so schedules read as one chain::

        plan = (FaultPlan(seed=7)
                .kill_worker(5_000.0, worker=1)
                .blackhole(8_000.0, "Src", "Agg", duration_ms=400.0))

    ``log`` records every fault as fired with its late-bound target — the
    run's ground truth for tests and BENCH rows.
    """

    seed: int = 0
    faults: list[Fault] = field(default_factory=list)
    log: list[FaultRecord] = field(default_factory=list)

    def __post_init__(self) -> None:
        self.rng = random.Random(self.seed ^ 0x5EEDFA17)

    # -- builders ------------------------------------------------------------
    def kill_worker(self, at_ms: float,
                    worker: int | None = None) -> "FaultPlan":
        self.faults.append(KillWorker(at_ms, worker))
        return self

    def kill_owner_of(self, at_ms: float, job_vertex: str,
                      index: int = 0) -> "FaultPlan":
        self.faults.append(KillOwnerOf(at_ms, job_vertex, index))
        return self

    def blackhole(self, at_ms: float, src_vertex: str, dst_vertex: str,
                  duration_ms: float) -> "FaultPlan":
        self.faults.append(
            ChannelBlackhole(at_ms, src_vertex, dst_vertex, duration_ms))
        return self

    def delay_spike(self, at_ms: float, job_vertex: str, duration_ms: float,
                    factor: float = 8.0) -> "FaultPlan":
        self.faults.append(DelaySpike(at_ms, job_vertex, duration_ms, factor))
        return self

    # -- firing support ------------------------------------------------------
    def ordered(self) -> list[Fault]:
        """Schedule in firing order (stable for equal timestamps)."""
        return sorted(self.faults, key=lambda f: f.at_ms)

    def pick_worker(self, live: list[int]) -> int:
        """Resolve a ``KillWorker(worker=None)`` target from the plan's own
        RNG (never the executor's — fault-free determinism)."""
        if not live:
            raise ValueError("no live worker to kill")
        return self.rng.choice(sorted(live))

    def record(self, at_ms: float, kind: str, detail: str) -> None:
        self.log.append(FaultRecord(at_ms, kind, detail))
