"""Nephele Streaming core: QoS-constrained stream processing (paper §2-§3).

The paper's primary contribution as a composable library:

* graphs        — job graph / runtime graph formalism (§3.1)
* constraints   — task/channel/sequence latency + constraints, Eq. (1) (§3.2)
* measurement   — tagged-item sampling, reporters, reports (§3.3)
* setup         — distributed QoS manager placement, Algorithms 1-3 (§3.4)
* buffers       — output buffers + adaptive sizing, Eq. (2)/(3) (§3.5.1)
* chaining      — dynamic task chaining + §3.6 fault-tolerance veto (§3.5.2)
* manager       — violation detection (max-plus DP) + countermeasures (§3.5)
* routing       — key-range routing + keyed task state (elastic migration)
* placement     — first-class workers: WorkerPool with elastic
                  acquire/release + packed/spread/affinity policies (§3.1.2
                  worker(v), §6 cloud elasticity)
* engine        — threaded executor (real time, laptop scale)
* simulator     — discrete-event executor (paper scale: n=200, m=800)

KeyRouter / StateStore contract (core/routing.py; elastic §6 + the
elasticity surveys' key-range repartitioning):

* Every consumer group (job vertex) owns ONE ``KeyRouter`` at
  ``RuntimeGraph.routers[name]`` — a fixed table of ``NUM_KEY_RANGES``
  virtual key ranges, each mapped to one subtask index.  Both backends
  route every keyed emission through it; there is no other key routing.
* Rescaling never rehashes: ``plan(new_size)`` computes the minimal
  balanced set of ranges that must change owner, ``RuntimeRewirer``
  migrates exactly those ranges' state (snapshot -> serialized handoff ->
  restore, via checkpoint/checkpointer.py), then ``commit()`` swaps the
  table atomically.  Keys in unmoved ranges keep their owner across any
  number of rescales.
* A task marked ``JobVertex(stateful=True)`` holds a per-key ``StateStore``
  (``ctx.state`` in engine task fns; an automatic per-key processed-item
  count in the simulator).  ``snapshot(key_ranges, evict=True)`` /
  ``restore(entries)`` move whole ranges; eviction plus processing-time
  ownership enforcement guarantee no key is ever served by two owners and
  no per-key state is lost or duplicated across grow/shrink round trips.
"""

from .buffers import BufferSizingPolicy, OutputBuffer
from .chaining import ChainRequest, TaskRuntimeInfo, chainable_series, find_chain
from .clock import Clock, RealClock, SimClock
from .constraints import (
    JobConstraint,
    JobSequence,
    RuntimeConstraint,
    RuntimeSequence,
    constraint_elements,
    enumerate_runtime_sequences,
    sequence_latency,
)
from .engine import EngineResult, SourceSpec, StreamEngine, StreamItem
from .estimation import (
    EwmaEstimator,
    HoltEstimator,
    ProactiveConfig,
    RateEstimator,
    SlidingWindowTrendEstimator,
    make_estimator,
)
from .faults import (
    ChannelBlackhole,
    DelaySpike,
    FaultPlan,
    FaultRecord,
    KillOwnerOf,
    KillWorker,
    RecoveryEvent,
)
from .liveness import HeartbeatMonitor
from .graphs import (
    ALL_TO_ALL,
    POINTWISE,
    Channel,
    JobEdge,
    JobGraph,
    JobVertex,
    RuntimeGraph,
    RuntimeSubgraph,
    RuntimeVertex,
)
from .manager import BufferSizeUpdate, GiveUp, QoSManager
from .measurement import QoSReport, QoSReporter, RateMeter, RunningAverage, Tag
from .placement import (
    MODULO,
    PACKED,
    SPREAD,
    PoolEvent,
    PoolSaturated,
    Worker,
    WorkerPool,
)
from .routing import (
    NUM_KEY_RANGES,
    WIDE_KEY_RANGES,
    KeyRouter,
    MigrationPlan,
    StateStore,
    key_ranges_for,
    range_of_key,
)
from .setup import (
    ManagerAllocation,
    check_side_conditions,
    compute_qos_setup,
    compute_reporter_setup,
    get_anchor_vertex,
)
from .simulator import (
    SimNetConfig,
    SimResult,
    SimSourceSpec,
    StreamSimulator,
    analytic_emission_times,
)

__all__ = [k for k in dir() if not k.startswith("_")]

from .elastic import (  # noqa: F401,E402
    DrainTimeout,
    ElasticController,
    RuntimeRewirer,
    ScaleDecision,
    ScaleRequest,
    ThroughputConstraint,
    split_constraints,
)
