"""Elastic scale-out for throughput QoS goals — the paper's §6 future work
("strategies for other QoS goals such as ... throughput that exploit the
capability of a cloud to elastically scale on demand").

A ``ThroughputConstraint`` demands a minimum delivered rate at a job
vertex's tasks.  The ``ElasticController`` watches per-task throughput and
utilization (from the same QoS reporter telemetry) and, when a stage is
saturated (utilization near 1 and throughput below target), requests a
scale-out: the stage's parallelism grows, new tasks are wired with the same
job-edge patterns, and upstream key-routing spreads over the larger group.
Scale-in happens when utilization stays below a low-water mark.

The simulator executes the re-wiring live (StreamSimulator.apply_scale_out)
— the scheme the paper sketches for cloud deployments.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class ThroughputConstraint:
    """Minimum items/s that ``job_vertex``'s tasks must deliver in
    aggregate, evaluated over a sliding window of ``window_ms``."""

    job_vertex: str
    min_items_per_s: float
    window_ms: float = 5_000.0
    name: str = "throughput"


@dataclass
class ScaleDecision:
    job_vertex: str
    from_parallelism: int
    to_parallelism: int
    reason: str
    at_ms: float


class ElasticController:
    """Scale-out/in policy on reporter telemetry.

    saturated: mean task utilization > hi_water AND delivered < target.
    idle:      mean utilization < lo_water for ``cooldown_ms``.
    """

    def __init__(self, constraint: ThroughputConstraint, *,
                 hi_water: float = 0.85, lo_water: float = 0.25,
                 max_parallelism: int = 64, step: int = 2,
                 cooldown_ms: float = 10_000.0) -> None:
        self.c = constraint
        self.hi_water = hi_water
        self.lo_water = lo_water
        self.max_parallelism = max_parallelism
        self.step = step
        self.cooldown_ms = cooldown_ms
        self._last_action_ms = -float("inf")
        self.decisions: list[ScaleDecision] = []

    def check(self, now_ms: float, parallelism: int,
              delivered_items_per_s: float,
              mean_utilization: float) -> ScaleDecision | None:
        if now_ms - self._last_action_ms < self.cooldown_ms:
            return None
        d = None
        if (delivered_items_per_s < self.c.min_items_per_s
                and mean_utilization > self.hi_water
                and parallelism < self.max_parallelism):
            d = ScaleDecision(
                self.c.job_vertex, parallelism,
                min(parallelism + self.step, self.max_parallelism),
                f"saturated: {delivered_items_per_s:.1f}/s < "
                f"{self.c.min_items_per_s:.1f}/s at util "
                f"{mean_utilization:.2f}", now_ms)
        elif (mean_utilization < self.lo_water
              and delivered_items_per_s > 1.2 * self.c.min_items_per_s
              and parallelism > self.step):
            d = ScaleDecision(
                self.c.job_vertex, parallelism, parallelism - self.step,
                f"idle: util {mean_utilization:.2f}", now_ms)
        if d is not None:
            self._last_action_ms = now_ms
            self.decisions.append(d)
        return d
