"""Elastic scale-out/in for throughput QoS goals — the paper's §6 future
work ("strategies for other QoS goals such as ... throughput that exploit
the capability of a cloud to elastically scale on demand").

A ``ThroughputConstraint`` demands a minimum delivered rate at a job
vertex's tasks.  The ``ElasticController`` watches per-task throughput and
utilization (from the same QoS reporter telemetry) and, when a stage is
saturated (utilization near 1 and throughput below target), requests a
scale-out: the stage's parallelism grows, new tasks are wired with the same
job-edge patterns, and upstream key-routing spreads over the larger group.
Scale-in happens when utilization stays below a low-water mark.

Both execution backends apply decisions through the SAME runtime re-wiring
layer, ``RuntimeRewirer`` — a mixin the threaded ``StreamEngine`` and the
discrete-event ``StreamSimulator`` inherit.  It owns the backend-neutral
mutation protocol:

1. ``scale_out``: grow the runtime graph (``RuntimeGraph.grow_vertex``),
   spawn tasks, open + wire channels (upstream key-routing re-spreads over
   the larger group), refresh QoS manager/reporter scopes,
2. ``scale_in``: shrink the runtime graph, un-route channels into retiring
   tasks, *drain* them (no in-flight item is lost), retire them, flush
   their outgoing buffers, refresh QoS scopes,
3. ``attach_elastic`` + ``elastic_check``: shared telemetry sampling
   (delivered rate + mean utilization per stage) driving an
   ``ElasticController``.

Backends supply only small hooks (``_spawn_task``, ``_open_channel``,
``_unroute_channel``, ``_drain_tasks``, ``_retire_task``,
``_flush_task_outputs``, ``_task_emitted``, ``_task_busy_ms``,
``_schedule_elastic``); the policy, graph surgery, and QoS-scope refresh
live here once.  The QoS manager can also emit a ``ScaleRequest`` as its
third countermeasure (after buffer sizing and chaining, before GiveUp)
when a throughput-constrained stage on a violated path is saturated.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class ThroughputConstraint:
    """Minimum items/s that ``job_vertex``'s tasks must deliver in
    aggregate, evaluated over a sliding window of ``window_ms``.

    ``max_parallelism`` caps how far ANY scaling authority (attached
    ElasticController or the QoS manager's ScaleRequest countermeasure)
    may grow the stage — the resource budget travels with the constraint.
    """

    job_vertex: str
    min_items_per_s: float
    window_ms: float = 5_000.0
    name: str = "throughput"
    max_parallelism: int = 64


@dataclass
class ScaleDecision:
    job_vertex: str
    from_parallelism: int
    to_parallelism: int
    reason: str
    at_ms: float


class ElasticController:
    """Scale-out/in policy on reporter telemetry.

    saturated: mean task utilization > hi_water AND delivered < target.
    idle:      mean utilization < lo_water for ``cooldown_ms``.
    """

    def __init__(self, constraint: ThroughputConstraint, *,
                 hi_water: float = 0.85, lo_water: float = 0.25,
                 max_parallelism: int = 64, step: int = 2,
                 cooldown_ms: float = 10_000.0) -> None:
        self.c = constraint
        self.hi_water = hi_water
        self.lo_water = lo_water
        self.max_parallelism = max_parallelism
        self.step = step
        self.cooldown_ms = cooldown_ms
        self._last_action_ms = -float("inf")
        self.decisions: list[ScaleDecision] = []

    def check(self, now_ms: float, parallelism: int,
              delivered_items_per_s: float,
              mean_utilization: float) -> ScaleDecision | None:
        if now_ms - self._last_action_ms < self.cooldown_ms:
            return None
        cap = min(self.max_parallelism, self.c.max_parallelism)
        d = None
        if (delivered_items_per_s < self.c.min_items_per_s
                and mean_utilization > self.hi_water
                and parallelism < cap):
            d = ScaleDecision(
                self.c.job_vertex, parallelism,
                min(parallelism + self.step, cap),
                f"saturated: {delivered_items_per_s:.1f}/s < "
                f"{self.c.min_items_per_s:.1f}/s at util "
                f"{mean_utilization:.2f}", now_ms)
        elif (mean_utilization < self.lo_water
              and parallelism > self.step
              # only shrink if the survivors can absorb the current load
              # without saturating (projected post-shrink utilization)
              and (mean_utilization * parallelism)
              / max(parallelism - self.step, 1) < self.hi_water):
            d = ScaleDecision(
                self.c.job_vertex, parallelism, parallelism - self.step,
                f"idle: util {mean_utilization:.2f}", now_ms)
        if d is not None:
            self._last_action_ms = now_ms
            self.decisions.append(d)
        return d


@dataclass(frozen=True)
class ScaleRequest:
    """Manager-initiated scale-out (third countermeasure, §3.5 extended):
    emitted when a latency constraint stays violated after buffer sizing and
    chaining are exhausted AND a throughput-constrained stage on the path is
    saturated — routed by the execution layer to ``RuntimeRewirer``."""

    job_vertex: str
    from_parallelism: int
    to_parallelism: int
    reason: str


# ---------------------------------------------------------------------------
# Runtime re-wiring layer shared by both execution backends
# ---------------------------------------------------------------------------


class RuntimeRewirer:
    """Backend-neutral live re-parallelization (mixin).

    Host requirements (provided by StreamEngine / StreamSimulator):
    attributes ``jg``, ``rg``, ``clock``, ``sources``, ``reporters``,
    ``managers``, ``policy``, ``constraints`` (latency),
    ``throughput_constraints``, plus the ``_spawn_task``-family hooks listed
    in the module docstring.
    """

    def _init_rewirer(self) -> None:
        self.scale_log: list[ScaleDecision] = []
        self._elastic: list[dict] = []
        self._manager_history_archive: list = []

    # -- public mutation API -------------------------------------------------
    def apply_scale_decision(self, d: ScaleDecision) -> bool:
        if d.to_parallelism > d.from_parallelism:
            return self.scale_out(d.job_vertex, d.to_parallelism,
                                  reason=d.reason)
        return self.scale_in(d.job_vertex, d.to_parallelism, reason=d.reason)

    def scale_out(self, job_vertex: str, new_parallelism: int,
                  reason: str = "manual") -> bool:
        """Grow ``job_vertex`` to ``new_parallelism`` live.  Source vertices
        are not scalable (their pacing is external input, not capacity)."""
        if job_vertex in self.sources:
            raise ValueError(f"cannot scale source vertex {job_vertex!r}")
        old_n = len(self.rg.tasks_of(job_vertex))
        new_vs, new_cs = self.rg.grow_vertex(job_vertex, new_parallelism)
        if not new_vs:
            return False
        for v in new_vs:
            self._spawn_task(v)
        # wire channels only after every new task exists, so no channel ever
        # points at a missing endpoint
        for c in new_cs:
            self._open_channel(c)
        self._refresh_qos_scopes()
        self.scale_log.append(ScaleDecision(
            job_vertex, old_n, len(self.rg.tasks_of(job_vertex)),
            reason, self.clock.now()))
        return True

    def scale_in(self, job_vertex: str, new_parallelism: int,
                 reason: str = "manual") -> bool:
        """Shrink ``job_vertex`` live: stop routing into the retiring tasks,
        drain them (in-flight items are preserved), retire, flush their
        outgoing buffers downstream, and refresh QoS scopes.  Chained tasks
        are never retired (their thread is fused into another's)."""
        if job_vertex in self.sources:
            raise ValueError(f"cannot scale source vertex {job_vertex!r}")
        old_n = len(self.rg.tasks_of(job_vertex))
        candidates = self.rg.tasks_of(job_vertex)[new_parallelism:]
        if any(self._task_is_chained(v) for v in candidates):
            return False
        retired_vs, removed_cs = self.rg.shrink_vertex(
            job_vertex, new_parallelism)
        if not retired_vs:
            return False
        retired = set(retired_vs)
        # 1. stop routing new items into the retiring tasks; flush what the
        #    closed channels still buffer so it reaches them before the drain
        for c in removed_cs:
            if c.dst in retired:
                self._unroute_channel(c)
        # 2. drain: every already-delivered item gets processed
        self._drain_tasks(retired_vs)
        # 3. retire the tasks, then push their last outputs downstream
        for v in retired_vs:
            self._retire_task(v)
        for v in retired_vs:
            self._flush_task_outputs(v)
        self._refresh_qos_scopes()
        self.scale_log.append(ScaleDecision(
            job_vertex, old_n, len(self.rg.tasks_of(job_vertex)),
            reason, self.clock.now()))
        return True

    # -- QoS scope refresh ---------------------------------------------------
    def _refresh_qos_scopes(self) -> None:
        """Re-run the master's QoS setup (Algorithms 1-3) against the mutated
        runtime graph and swap in fresh manager/reporter scopes.  Managers
        restart their measurement windows (§4.3.2-style warmup) — their past
        history is archived for the final result."""
        from .manager import QoSManager
        from .setup import compute_qos_setup, compute_reporter_setup

        for mgr in self.managers.values():
            self._manager_history_archive.extend(mgr.history)
        self.allocations = compute_qos_setup(
            self.jg, self.constraints, self.rg)
        self.reporter_setup = compute_reporter_setup(self.allocations, self.rg)
        for rep in self.reporters.values():
            rep.reset_assignments()
        for w, routes in self.reporter_setup.task_routes.items():
            for mgr, tasks in routes.items():
                self.reporters[w].assign_manager(mgr, (), tasks)
        for w, routes in self.reporter_setup.channel_routes.items():
            for mgr, chans in routes.items():
                self.reporters[w].assign_manager(mgr, chans, ())
        self.managers = {
            w: QoSManager(alloc, self.rg, self.clock, policy=self.policy,
                          throughput_constraints=self.throughput_constraints)
            for w, alloc in self.allocations.items()
        }
        # §3.5 discipline carries across the rebuild: after a re-wiring the
        # fresh managers wait one constraint window before acting, so stale
        # pre-scale measurements (and queue backlog) flush out first —
        # without this, a ScaleRequest-triggered refresh would let the new
        # manager fire another ScaleRequest every check cycle.
        now = self.clock.now()
        for mgr in self.managers.values():
            horizon = max((s.constraint.window_ms
                           for s in mgr.allocation.scopes), default=0.0)
            mgr.defer_until(now + horizon)
        measured_channels: set[str] = set()
        measured_tasks: set[str] = set()
        for r in self.reporters.values():
            measured_channels |= r.interested_channels()
            measured_tasks |= r.interested_tasks()
        self.measured_channels = measured_channels
        self.measured_tasks = measured_tasks

    # -- controller attachment + shared telemetry ---------------------------
    def attach_elastic(self, controller: ElasticController) -> None:
        """Attach an ElasticController; its constraint's vertex is watched
        (delivered rate + mean utilization) and scaled live, both out and
        in."""
        st = {"ctl": controller, "last_t": self.clock.now(),
              "last_emitted": 0, "last_busy": 0.0}
        self._elastic.append(st)
        self._schedule_elastic(st, controller.c.window_ms / 2.0)

    def elastic_check(self, st: dict) -> ScaleDecision | None:
        """One telemetry sample + policy check for an attached controller;
        applies the decision (if any) through the shared re-wiring path."""
        ctl: ElasticController = st["ctl"]
        now = self.clock.now()
        tasks = self.rg.tasks_of(ctl.c.job_vertex)
        emitted = sum(self._task_emitted(v) for v in tasks)
        busy = sum(self._task_busy_ms(v) for v in tasks)
        dt = max(now - st["last_t"], 1e-9)
        rate = max(emitted - st["last_emitted"], 0) / (dt / 1e3)
        util = max(busy - st["last_busy"], 0.0) / dt / max(len(tasks), 1)
        st["last_t"], st["last_emitted"], st["last_busy"] = now, emitted, busy
        d = ctl.check(now, len(tasks), rate, min(util, 1.0))
        if d is not None and self.apply_scale_decision(d):
            # re-baseline the counters over the re-wired task group so the
            # next sample is not skewed by spawned/retired tasks
            tasks = self.rg.tasks_of(ctl.c.job_vertex)
            st["last_emitted"] = sum(self._task_emitted(v) for v in tasks)
            st["last_busy"] = sum(self._task_busy_ms(v) for v in tasks)
            st["last_t"] = self.clock.now()
        return d

    # -- hooks backends must provide ----------------------------------------
    def _spawn_task(self, v) -> None:
        raise NotImplementedError

    def _open_channel(self, c) -> None:
        raise NotImplementedError

    def _unroute_channel(self, c) -> None:
        raise NotImplementedError

    def _drain_tasks(self, vs) -> None:
        raise NotImplementedError

    def _retire_task(self, v) -> None:
        raise NotImplementedError

    def _flush_task_outputs(self, v) -> None:
        raise NotImplementedError

    def _task_is_chained(self, v) -> bool:
        raise NotImplementedError

    def _task_emitted(self, v) -> int:
        raise NotImplementedError

    def _task_busy_ms(self, v) -> float:
        raise NotImplementedError

    def _schedule_elastic(self, st: dict, period_ms: float) -> None:
        raise NotImplementedError


def split_constraints(constraints) -> tuple[list, list[ThroughputConstraint]]:
    """Partition a mixed constraint list into (latency, throughput) — both
    backends accept ThroughputConstraints alongside JobConstraints."""
    latency, throughput = [], []
    for c in constraints:
        if isinstance(c, ThroughputConstraint):
            throughput.append(c)
        else:
            latency.append(c)
    return latency, throughput
