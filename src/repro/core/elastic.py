"""Elastic scale-out/in for throughput QoS goals — the paper's §6 future
work ("strategies for other QoS goals such as ... throughput that exploit
the capability of a cloud to elastically scale on demand").

A ``ThroughputConstraint`` demands a minimum delivered rate at a job
vertex's tasks.  The ``ElasticController`` watches per-task throughput and
utilization (from the same QoS reporter telemetry) and, when a stage is
saturated (utilization near 1 and throughput below target), requests a
scale-out: the stage's parallelism grows, new tasks are wired with the same
job-edge patterns, and upstream key-routing spreads over the larger group.
Scale-in happens when utilization stays below a low-water mark.

Both execution backends apply decisions through the SAME runtime re-wiring
layer, ``RuntimeRewirer`` — a mixin the threaded ``StreamEngine`` and the
discrete-event ``StreamSimulator`` inherit.  It owns the backend-neutral
mutation protocol:

1. ``scale_out``: grow the runtime graph (``RuntimeGraph.grow_vertex``),
   spawn tasks, open + wire channels (upstream key-routing re-spreads over
   the larger group), refresh QoS manager/reporter scopes,
2. ``scale_in``: shrink the runtime graph, un-route channels into retiring
   tasks, *drain* them (no in-flight item is lost), retire them, flush
   their outgoing buffers, refresh QoS scopes,
3. ``attach_elastic`` + ``elastic_check``: shared telemetry sampling
   (delivered rate + mean utilization per stage) driving an
   ``ElasticController``.

Backends supply only small hooks (``_spawn_task``, ``_open_channel``,
``_unroute_channel``, ``_drain_tasks``, ``_retire_task``,
``_flush_task_outputs``, ``_task_emitted``, ``_task_busy_ms``,
``_schedule_elastic``, ``_dissolve_chain``, ``_add_worker``, plus the
keyed-state quartet ``_quiesce_tasks`` / ``_resume_tasks`` /
``_task_state`` / ``_reroute_queued``); the policy, graph surgery, and
QoS-scope refresh live here once.  The QoS manager can also emit a
``ScaleRequest`` as its third countermeasure (after buffer sizing and
chaining, before GiveUp) when a throughput-constrained stage on a violated
path is saturated.

Worker placement (core/placement.py): the runtime graph's ``WorkerPool``
decides where spawned subtasks land.  ``scale_out`` therefore doubles as
the cloud-acquisition path — when the pool's placement policy saturates,
the pool acquires a worker and ``_sync_new_workers`` gives the backend its
per-worker plumbing before any task/channel references it.  ``scale_in``
is the give-back path: retiring tasks free their pool slots and every
non-initial worker the retirement emptied is released.  Chains are
registered in ``active_chains`` when applied; scale_in **unchains before
retiring** (reverse of §3.5.2) so a fused series no longer vetoes
elasticity — the two countermeasures compose.

Keyed-state migration: every rescale of a group goes through its
``KeyRouter`` (core/routing.py).  ``plan()`` computes which virtual key
ranges change owner; the protocol then (1) quiesces the old owners of the
moved ranges, (2) snapshots exactly those ranges out of their
``StateStore``s, (3) ships them through the checkpoint plane's serialized
handoff (checkpoint/state_codec.py pack/unpack — stdlib-only, so the FIRST
live rescale never stalls on the accelerator stack's numpy import), (4)
installs them on the new owners, (5) atomically commits the routing table
(one tuple swap of the dense lookup table the emit hot paths index),
re-homes any queued items of moved ranges, and resumes.  Unmoved ranges
never change owner, so a rescale is invisible to every key that did not
migrate.
"""
from __future__ import annotations

from dataclasses import dataclass, field


class DrainTimeout(RuntimeError):
    """A task failed to drain its inbox within the drain timeout.  Raised by
    ``scale_in`` instead of silently retiring an undrained task (which would
    lose in-flight items); policy-driven callers (``apply_scale_decision``)
    catch it, record it in ``drain_failures``, and abort the rescale."""


@dataclass(frozen=True)
class ThroughputConstraint:
    """Minimum items/s that ``job_vertex``'s tasks must deliver in
    aggregate, evaluated over a sliding window of ``window_ms``.

    ``max_parallelism`` caps how far ANY scaling authority (attached
    ElasticController or the QoS manager's ScaleRequest countermeasure)
    may grow the stage — the resource budget travels with the constraint.
    """

    job_vertex: str
    min_items_per_s: float
    window_ms: float = 5_000.0
    name: str = "throughput"
    max_parallelism: int = 64


@dataclass
class ScaleDecision:
    job_vertex: str
    from_parallelism: int
    to_parallelism: int
    reason: str
    at_ms: float


class ElasticController:
    """Scale-out/in policy on reporter telemetry.

    saturated: mean task utilization > hi_water AND delivered < target.
    idle:      mean utilization < lo_water for ``cooldown_ms``.
    """

    def __init__(self, constraint: ThroughputConstraint, *,
                 hi_water: float = 0.85, lo_water: float = 0.25,
                 max_parallelism: int = 64, step: int = 2,
                 cooldown_ms: float = 10_000.0) -> None:
        self.c = constraint
        self.hi_water = hi_water
        self.lo_water = lo_water
        self.max_parallelism = max_parallelism
        self.step = step
        self.cooldown_ms = cooldown_ms
        self._last_action_ms = -float("inf")
        self.decisions: list[ScaleDecision] = []

    def check(self, now_ms: float, parallelism: int,
              delivered_items_per_s: float,
              mean_utilization: float) -> ScaleDecision | None:
        if now_ms - self._last_action_ms < self.cooldown_ms:
            return None
        cap = min(self.max_parallelism, self.c.max_parallelism)
        d = None
        if (delivered_items_per_s < self.c.min_items_per_s
                and mean_utilization > self.hi_water
                and parallelism < cap):
            d = ScaleDecision(
                self.c.job_vertex, parallelism,
                min(parallelism + self.step, cap),
                f"saturated: {delivered_items_per_s:.1f}/s < "
                f"{self.c.min_items_per_s:.1f}/s at util "
                f"{mean_utilization:.2f}", now_ms)
        elif (mean_utilization < self.lo_water
              and parallelism > self.step
              # only shrink if the survivors can absorb the current load
              # without saturating (projected post-shrink utilization)
              and (mean_utilization * parallelism)
              / max(parallelism - self.step, 1) < self.hi_water):
            d = ScaleDecision(
                self.c.job_vertex, parallelism, parallelism - self.step,
                f"idle: util {mean_utilization:.2f}", now_ms)
        if d is not None:
            self._last_action_ms = now_ms
            self.decisions.append(d)
        return d


@dataclass(frozen=True)
class ScaleRequest:
    """Manager-initiated scale-out (third countermeasure, §3.5 extended):
    emitted when a latency constraint stays violated after buffer sizing and
    chaining are exhausted AND a throughput-constrained stage on the path is
    saturated — routed by the execution layer to ``RuntimeRewirer``."""

    job_vertex: str
    from_parallelism: int
    to_parallelism: int
    reason: str


# ---------------------------------------------------------------------------
# Runtime re-wiring layer shared by both execution backends
# ---------------------------------------------------------------------------


class RuntimeRewirer:
    """Backend-neutral live re-parallelization (mixin).

    Host requirements (provided by StreamEngine / StreamSimulator):
    attributes ``jg``, ``rg``, ``clock``, ``sources``, ``reporters``,
    ``managers``, ``policy``, ``constraints`` (latency),
    ``throughput_constraints``, plus the ``_spawn_task``-family hooks listed
    in the module docstring.
    """

    def _init_rewirer(self) -> None:
        self.scale_log: list[ScaleDecision] = []
        self._elastic: list[dict] = []
        # -- predictive QoS (core/estimation.py) -----------------------------
        #: ProactiveConfig or None; backends set it from their constructor
        #: argument before/after _init_rewirer — getattr keeps bare-mixin
        #: hosts (tests) working
        self.proactive = getattr(self, "proactive", None)
        #: shared estimator registry ("src:<jv>" / "stage:<jv>" ->
        #: RateEstimator) — owned HERE, not by the managers, so estimator
        #: state survives every _refresh_qos_scopes manager rebuild.  A
        #: backend that built its managers before calling _init_rewirer has
        #: already created (and shared) the dict — preserve that identity.
        if not hasattr(self, "_rate_estimators"):
            self._rate_estimators: dict = {}
        #: cumulative-count -> rate meters feeding the estimators
        self._rate_meters: dict = {}
        self._next_estimator_ms = 0.0
        self._manager_history_archive: list = []
        #: drain/chain failures surfaced instead of silently proceeding
        self.drain_failures: list[str] = []
        #: how long drains (scale-in, chaining, quiesce) may take
        self.drain_timeout_s: float = 5.0
        #: live chains (tuples of RuntimeVertex, dataflow order): appended by
        #: the backends' chain application, removed by ``_unchain`` — the
        #: registry scale_in consults to unchain before retiring
        self.active_chains: list[tuple] = []
        #: dissolved chains, for results/tests: (task ids, reason)
        self.unchain_log: list[tuple[tuple[str, ...], str]] = []
        #: workers released back to the pool by scale_in, in order
        self.released_workers: list[int] = []
        # -- crash recovery (core/faults.py + core/liveness.py) --------------
        #: streaming Checkpointer driving periodic snapshots + restore
        self._checkpointer = None
        #: HeartbeatMonitor declaring workers dead; None = detection off
        self._monitor = None
        #: completed recovery cycles (RecoveryEvent), in order
        self.recovery_log: list = []
        #: workers known crashed (stop being beaten) -> injection timestamp
        self._crash_time_ms: dict[int, float] = {}
        self._crashed_workers: set[int] = set()
        #: first-crash recovery metrics, surfaced on SimResult/EngineResult
        self.time_to_detect_ms: float | None = None
        self.time_to_recover_ms: float | None = None
        self.time_to_slo_recovery_ms: float | None = None
        #: crash time of the oldest crash whose SLOs have not re-converged
        self._slo_pending_since: float | None = None

    # -- public mutation API -------------------------------------------------
    def apply_scale_decision(self, d: ScaleDecision) -> bool:
        try:
            if d.to_parallelism > d.from_parallelism:
                return self.scale_out(d.job_vertex, d.to_parallelism,
                                      reason=d.reason)
            return self.scale_in(d.job_vertex, d.to_parallelism,
                                 reason=d.reason)
        except DrainTimeout:
            # policy-driven rescale against a hung task: the failure is
            # already recorded in drain_failures by scale_in; report the
            # decision as failed and keep the control loop alive
            return False

    def scale_out(self, job_vertex: str, new_parallelism: int,
                  reason: str = "manual") -> bool:
        """Grow ``job_vertex`` to ``new_parallelism`` live.  Source vertices
        are not scalable (their pacing is external input, not capacity)."""
        if job_vertex in self.sources:
            raise ValueError(f"cannot scale source vertex {job_vertex!r}")
        old_n = len(self.rg.tasks_of(job_vertex))
        if new_parallelism <= old_n:
            return False
        # plan the key-range remap against the OLD table; nothing routes to
        # the new subtasks until the moved ranges' state has been installed
        plan = self.rg.routers[job_vertex].plan(new_parallelism)
        new_vs, new_cs = self.rg.grow_vertex(job_vertex, new_parallelism)
        if not new_vs:
            return False
        # placement may have acquired fresh workers (pool saturated): give
        # the backend its per-worker plumbing (QoS reporter, CPU model)
        # before any task or channel can reference them
        self._sync_new_workers()
        for v in new_vs:
            self._spawn_task(v)
        # wire channels only after every new task exists, so no channel ever
        # points at a missing endpoint
        for c in new_cs:
            self._open_channel(c)
        # migrate moved ranges' state, then atomically swap the routing table
        self._migrate_keyed_state(job_vertex, plan)
        self._refresh_qos_scopes()
        self.scale_log.append(ScaleDecision(
            job_vertex, old_n, len(self.rg.tasks_of(job_vertex)),
            reason, self.clock.now()))
        return True

    def scale_in(self, job_vertex: str, new_parallelism: int,
                 reason: str = "manual") -> bool:
        """Shrink ``job_vertex`` live: migrate the retiring tasks' key-range
        state to the survivors, stop routing into the retiring tasks, drain
        them (in-flight items are preserved), retire, flush their outgoing
        buffers downstream, and refresh QoS scopes.  A retiring task that was
        pulled into a chain is first **unchained** (reverse of §3.5.2: its
        thread/queues are re-established and the fused channels revert to
        buffered hand-over), so chaining never vetoes elasticity.  Workers
        emptied by the retirement are released back to the pool.  Raises
        ``DrainTimeout`` if a retiring task cannot be drained — silently
        retiring it would lose its in-flight items."""
        if job_vertex in self.sources:
            raise ValueError(f"cannot scale source vertex {job_vertex!r}")
        old_n = len(self.rg.tasks_of(job_vertex))
        if not 1 <= new_parallelism < old_n:
            return False
        candidates = self.rg.tasks_of(job_vertex)[new_parallelism:]
        # validate shrinkability FIRST: an inapplicable rescale must not
        # dissolve chains (a manager countermeasure) or half-swap routing
        self.rg._check_elastic_edges(job_vertex, "shrink")
        # unchain-before-retire: dissolve every chain that contains a
        # retiring task (the whole chain, head included — a fused series
        # only functions as a unit)
        chains: list[tuple] = []
        for v in candidates:
            ch = self._chain_of(v)
            if ch is not None and ch not in chains:
                chains.append(ch)
        for ch in chains:
            if not self._unchain(ch, reason=f"scale_in {job_vertex}"):
                self.drain_failures.append(
                    f"scale_in({job_vertex!r}): could not unchain "
                    f"{[v.id for v in ch]}; rescale aborted")
                return False
        if any(self._task_is_chained(v) for v in candidates):
            # chained flag without a registered chain (inconsistent state,
            # e.g. a test-injected flag): retiring would orphan the fused
            # thread, so refuse rather than guess
            return False
        # hand the retiring owners' key ranges (with their state) to the
        # survivors and swap the routing table BEFORE unrouting: from the
        # swap on, every keyed emission targets a survivor, and leftover
        # items in retiring inboxes are re-homed by ownership enforcement
        plan = self.rg.routers[job_vertex].plan(new_parallelism)
        self._migrate_keyed_state(job_vertex, plan)
        retired_vs, removed_cs = self.rg.shrink_vertex(
            job_vertex, new_parallelism)
        if not retired_vs:
            return False
        retired = set(retired_vs)
        # 1. stop routing new items into the retiring tasks; flush what the
        #    closed channels still buffer so it reaches them before the drain
        for c in removed_cs:
            if c.dst in retired:
                self._unroute_channel(c)
        # 2. drain: every already-delivered item gets processed (or re-homed
        #    to its new owner).  A hung task is surfaced as DrainTimeout —
        #    but only AFTER the retirement completes structurally below, so
        #    the graph, routing table, and executor set stay consistent: the
        #    hung task is marked retired (deliver() reroutes stragglers to
        #    survivors) and its thread, once unstuck, drains its leftover
        #    inbox into the surviving group before exiting.
        drained = self._drain_tasks(retired_vs)
        # 3. retire the tasks, then push their last outputs downstream
        for v in retired_vs:
            self._retire_task(v)
        for v in retired_vs:
            self._flush_task_outputs(v)
        # 4. release workers the retirement emptied (never the initial
        #    fleet): the pool models cloud give-back; per-worker backend
        #    plumbing (reporters) stays for straggler telemetry
        for w in sorted({self.rg.worker(v) for v in retired_vs}):
            if self.rg.pool.release_if_empty(
                    w, reason=f"scale_in {job_vertex}"):
                self.released_workers.append(w)
        self._refresh_qos_scopes()
        self.scale_log.append(ScaleDecision(
            job_vertex, old_n, len(self.rg.tasks_of(job_vertex)),
            reason, self.clock.now()))
        if not drained:
            msg = (f"scale_in({job_vertex!r}): tasks "
                   f"{[v.id for v in retired_vs]} failed to drain within "
                   f"{self.drain_timeout_s}s; retired undrained (leftover "
                   f"items re-home to survivors when the task unblocks)")
            self.drain_failures.append(msg)
            raise DrainTimeout(msg)
        return True

    # -- chain registry + unchain (reverse of §3.5.2) ------------------------
    def _chain_of(self, v):
        """The live chain (tuple of RuntimeVertex) containing ``v`` — head
        included — or None.  Backends register chains in ``active_chains``
        when they apply a ChainRequest."""
        for chain in self.active_chains:
            if v in chain:
                return chain
        return None

    def _unchain(self, chain, reason: str = "manual") -> bool:
        """Dissolve ``chain``: re-establish the member tasks' own execution
        (thread / queue) and revert the fused channels to buffered
        hand-over.  The backend does the mechanics (``_dissolve_chain``);
        bookkeeping and the audit log live here."""
        if chain not in self.active_chains:
            return False
        if not self._dissolve_chain(chain):
            return False
        self.active_chains.remove(chain)
        self.unchain_log.append((tuple(v.id for v in chain), reason))
        return True

    def unchain_all(self, reason: str = "manual") -> int:
        """Dissolve every live chain (e.g. before a topology change that
        invalidates co-location); returns how many were dissolved."""
        n = 0
        for chain in list(self.active_chains):
            if self._unchain(chain, reason=reason):
                n += 1
        return n

    # -- worker-pool sync ----------------------------------------------------
    def _sync_new_workers(self) -> None:
        """Give the backend per-worker plumbing for workers the pool
        acquired since the last sync (reporters are keyed by worker id on
        both backends)."""
        for w in self.rg.pool.worker_ids():
            if w not in self.reporters:
                self._add_worker(w)

    # -- crash detection + recovery (unplanned elasticity, §3.6) -------------
    def attach_recovery(self, checkpointer=None,
                        heartbeat_timeout_ms: float = 1_500.0) -> None:
        """Arm failure detection (and, with a ``Checkpointer``, periodic
        snapshots + checkpoint-based restore).  The monitor runs on the
        backend's OWN clock — simulated milliseconds in the simulator, so
        detection latency is deterministic there."""
        from .liveness import HeartbeatMonitor

        self._checkpointer = checkpointer
        self._monitor = HeartbeatMonitor(
            self.rg.pool.worker_ids(), timeout_ms=heartbeat_timeout_ms,
            clock=self.clock.now)

    def note_crash(self, worker: int, at_ms: float) -> None:
        """Record an injected crash: the worker stops being beaten (the
        monitor will time it out) and the injection instant anchors the
        time-to-detect metric."""
        self._crashed_workers.add(worker)
        self._crash_time_ms.setdefault(worker, at_ms)

    def _maybe_checkpoint(self, now: float) -> None:
        """Take the periodic streaming snapshot when the cadence says so
        (called from both backends' control ticks; no-op without an armed
        ``Checkpointer`` or with ``checkpoint_interval_ms=None``)."""
        ck = self._checkpointer
        if ck is not None and ck.due(now):
            ck.save_stream(now, self._stream_checkpoint_payload())

    def _stream_checkpoint_payload(self) -> dict:
        """One consistent streaming snapshot: per-source replay offsets plus
        per-stage packed keyed state (merged across subtasks — ownership is
        exclusive, so the merge is collision-free and restore can re-slice
        by whatever routing table rules at recovery time)."""
        from ..checkpoint.state_codec import pack_keyed_state

        state: dict[str, bytes] = {}
        for name, jv in self.jg.vertices.items():
            if not getattr(jv, "stateful", False):
                continue
            merged: dict = {}
            router = self.rg.routers.get(name)
            for v in self.rg.tasks_of(name):
                store = self._task_state(v)
                if store is None:
                    continue
                if router is not None:
                    merged.update(store.snapshot(
                        router.ranges_of(v.index), evict=False))
                else:
                    merged.update(store.snapshot(None, evict=False))
            state[name] = pack_keyed_state(
                merged, meta={"job_vertex": name})
        return {"offsets": self._source_offsets(), "state": state}

    def _liveness_tick(self, now: float) -> list:
        """One detection cycle: beat every live worker, declare the silent
        ones dead, and run the full recovery protocol for each.  Returns the
        completed ``RecoveryEvent``s (empty without an armed monitor)."""
        mon = self._monitor
        if mon is None:
            return []
        for w in self.rg.pool.worker_ids():
            if w not in self._crashed_workers:
                mon.beat(w)
        events = []
        for w in mon.dead_workers():
            if self.time_to_detect_ms is None:
                self.time_to_detect_ms = now - self._crash_time_ms.get(
                    w, now - mon.timeout_ms)
            ev = self.recover_worker(w, now)
            events.append(ev)
            if self.time_to_recover_ms is None:
                self.time_to_recover_ms = ev.recovered_at_ms - ev.crash_at_ms
            if self._slo_pending_since is None:
                self._slo_pending_since = ev.crash_at_ms
        return events

    def _slo_recovery_check(self, now: float) -> None:
        """Post-crash SLO watch: the first control tick at which every
        latency constraint's scope analysis is satisfied again (estimate
        within its limit, with at least one scope evaluable) stamps
        ``time_to_slo_recovery_ms`` (measured from the crash instant)."""
        if self._slo_pending_since is None or not self.managers:
            return
        evaluable = False
        for mgr in self.managers.values():
            for scope in mgr.allocation.scopes:
                res = mgr.analyze(scope)
                if res is None:
                    continue
                evaluable = True
                if res.worst_estimate_ms > scope.constraint.latency_limit_ms:
                    return
        if evaluable:
            self.time_to_slo_recovery_ms = now - self._slo_pending_since
            self._slo_pending_since = None

    def recover_worker(self, dead: int, now: float):
        """The full unplanned-elasticity protocol for one dead worker:

        1. every chain containing a dead member dissolves (bookkeeping +
           backend mechanics — the members share the worker, so the whole
           fused series died with it),
        2. the pool quarantines the dead id (``mark_dead``; NS-G008 makes
           any later placement onto it an error) and hands out a
           replacement (``acquire_replacement`` restores fleet size, it
           does not grow it),
        3. the lost subtasks respawn on the replacement — same
           ``RuntimeVertex`` identities, so the routing table, constraints
           and channel structure survive unchanged,
        4. their key ranges are restored from the last periodic streaming
           checkpoint, re-sliced by the CURRENT routing table (correct even
           if ranges migrated between snapshot and crash),
        5. every source rolls back to its recorded offset (log-based
           replay: at-least-once within the replay window, exactly-once
           outside it),
        6. ``_refresh_qos_scopes`` makes the QoS plane re-cover the rebuilt
           subgraph immediately.

        Returns the ``RecoveryEvent`` (also appended to ``recovery_log``).
        """
        from .faults import RecoveryEvent

        rg = self.rg
        lost = sorted(rg.vertices_on_worker(dead),
                      key=lambda v: (v.job_vertex, v.index))
        lost_set = set(lost)
        self._crashed_workers.add(dead)
        # 1. chains with a dead member dissolve before recovery
        for chain in [c for c in list(self.active_chains)
                      if lost_set.intersection(c)]:
            self._crash_dissolve_chain(chain)
            self.active_chains.remove(chain)
            self.unchain_log.append(
                (tuple(v.id for v in chain), f"crash of worker {dead}"))
        # 2. quarantine + replacement
        rg.pool.mark_dead(dead, reason="crash")
        if self._monitor is not None:
            self._monitor.remove(dead)
        self._drop_worker_plumbing(dead)
        new_w = rg.pool.acquire_replacement(
            dead, reason=f"recover worker {dead}").id
        self._sync_new_workers()
        if self._monitor is not None:
            self._monitor.add(new_w)
        # 3. respawn the lost subtasks on the replacement (NS-G008 is
        #    enforced inside pool.assign: a dead target raises)
        for v in lost:
            rg.pool.assign(v, new_w)
            rg._worker[v] = new_w
            self._respawn_task(v)
            for c in rg.out_channels(v):
                self._open_channel(c)
            self._repoint_in_channels(v)
        # 4. restore lost key ranges from the last periodic checkpoint
        snap = (self._checkpointer.latest_stream()
                if self._checkpointer is not None else None)
        restored = 0
        if snap is not None:
            from ..checkpoint.state_codec import unpack_keyed_state

            unpacked = {jv: unpack_keyed_state(blob)
                        for jv, blob in snap.get("state", {}).items()}
            for v in lost:
                store = self._task_state(v)
                entries = unpacked.get(v.job_vertex)
                if store is None or not entries:
                    continue
                router = rg.routers.get(v.job_vertex)
                mine = (dict(entries) if router is None else
                        {k: val for k, val in entries.items()
                         if router.owner(k) == v.index})
                if mine:
                    store.restore(mine)
                    restored += len(mine)
        # 5. replay from recorded source offsets
        replayed = self._replay_sources(
            snap.get("offsets") if snap is not None else None, now)
        # 6. the QoS plane re-covers the rebuilt subgraph
        self._refresh_qos_scopes()
        crash_at = self._crash_time_ms.get(
            dead, now - (self._monitor.timeout_ms
                         if self._monitor is not None else 0.0))
        ev = RecoveryEvent(dead, new_w, crash_at, now, self.clock.now(),
                           tuple(lost), restored, replayed)
        self.recovery_log.append(ev)
        return ev

    # -- keyed-state migration (core/routing.py + checkpoint handoff) --------
    def _migrate_keyed_state(self, job_vertex: str, plan) -> None:
        """Pause-drain-snapshot-install-swap for one ``MigrationPlan``:
        quiesce the old owners of the moved ranges, snapshot exactly those
        ranges, ship them through the checkpoint plane's serialized handoff,
        install on the new owners, commit the routing table atomically, and
        only then evict the moved entries from the old owners — a failure in
        any fallible step (e.g. unpicklable user state) therefore aborts
        with the old table and all state intact, never half-migrated.
        Stateless groups skip the machinery: their rescale is just the
        table swap."""
        from .graphs import RuntimeVertex

        router = self.rg.routers[job_vertex]
        if not plan.moves or not self.jg.vertices[job_vertex].stateful:
            router.commit(plan)
            return
        from ..checkpoint.state_codec import (
            pack_keyed_state,
            unpack_keyed_state,
        )

        old_owners = [RuntimeVertex(job_vertex, i) for i in plan.sources]
        if not self._quiesce_tasks(old_owners):
            # a source task would not pause between items in time: the
            # snapshot below may race its in-flight per-key update (that one
            # item's state change can strand on the old owner).  Proceed —
            # the table swap must not block on a hung task — but loudly.
            self.drain_failures.append(
                f"migrate({job_vertex!r}): old owners "
                f"{[v.id for v in old_owners]} not quiesced within "
                f"{self.drain_timeout_s}s; snapshot may race one in-flight "
                f"item per unparked task")
        try:
            # 1. snapshot WITHOUT evicting + pack (the fallible step)
            blobs: list[bytes] = []
            for v in old_owners:
                store = self._task_state(v)
                if store is None:
                    continue
                entries = store.snapshot(plan.ranges_from(v.index),
                                         evict=False)
                if entries:
                    blobs.append(pack_keyed_state(
                        entries,
                        meta={"job_vertex": job_vertex, "from": v.index,
                              "ranges": plan.ranges_from(v.index)}))
            # 2. install, batched per gaining owner
            for blob in blobs:
                by_target: dict[int, dict] = {}
                for key, value in unpack_keyed_state(blob).items():
                    _, new_owner = plan.moves[router.range_of(key)]
                    by_target.setdefault(new_owner, {})[key] = value
                for new_owner, batch in by_target.items():
                    dst = self._task_state(
                        RuntimeVertex(job_vertex, new_owner))
                    if dst is not None:
                        dst.restore(batch)
            # 3. swap the table, then evict the moved entries from their old
            #    owners — from here on exactly one store serves each key
            router.commit(plan)
            for v in old_owners:
                store = self._task_state(v)
                if store is not None:
                    store.snapshot(plan.ranges_from(v.index), evict=True)
            # items of moved ranges already queued at old owners are re-homed
            # now that the table points at the state's new location
            self._reroute_queued(old_owners)
        finally:
            self._resume_tasks(old_owners)

    # -- QoS scope refresh ---------------------------------------------------
    def _refresh_qos_scopes(self) -> None:
        """Re-run the master's QoS setup (Algorithms 1-3) against the mutated
        runtime graph and swap in fresh manager/reporter scopes.

        Warm start: the fresh managers adopt the element stores (measurement
        windows) and per-constraint cooldowns of the managers they replace
        for every vertex/channel that survived the re-wiring, so only NEW
        group members start cold — a violated path stays detectable within
        one reporting interval instead of paying a full §4.3.2-style warmup
        after every rescale.  The carried cooldowns also preserve the §3.5
        post-adjustment discipline: a scope that just fired a countermeasure
        (e.g. the ScaleRequest that triggered this very refresh) keeps
        waiting out its constraint window instead of re-firing every cycle.
        Past manager history is archived for the final result."""
        from .manager import QoSManager
        from .setup import compute_qos_setup, compute_reporter_setup

        old_managers = dict(self.managers)
        for mgr in old_managers.values():
            self._manager_history_archive.extend(mgr.history)
        self.allocations = compute_qos_setup(
            self.jg, self.constraints, self.rg)
        self.reporter_setup = compute_reporter_setup(self.allocations, self.rg)
        for rep in self.reporters.values():
            rep.reset_assignments()
        # a crashed worker may still hold placements until the heartbeat
        # monitor declares it and recovery re-homes them — its reporter
        # plumbing is already gone, so skip it; recovery triggers another
        # refresh once the subgraph is rebuilt
        for w, routes in self.reporter_setup.task_routes.items():
            rep = self.reporters.get(w)
            if rep is None:
                continue
            for mgr, tasks in routes.items():
                rep.assign_manager(mgr, (), tasks)
        for w, routes in self.reporter_setup.channel_routes.items():
            rep = self.reporters.get(w)
            if rep is None:
                continue
            for mgr, chans in routes.items():
                rep.assign_manager(mgr, chans, ())
        self.managers = {
            w: QoSManager(alloc, self.rg, self.clock, policy=self.policy,
                          throughput_constraints=self.throughput_constraints,
                          proactive=getattr(self, "proactive", None),
                          estimators=getattr(self, "_rate_estimators", None))
            for w, alloc in self.allocations.items()
        }
        # warm start: adopt surviving element stores from EVERY old manager
        # (manager placement may move workers across a refresh); adopt_state
        # filters to the new subgraph, so retired elements are dropped
        for mgr in self.managers.values():
            for old in old_managers.values():
                mgr.adopt_state(old)
        measured_channels: set[str] = set()
        measured_tasks: set[str] = set()
        for r in self.reporters.values():
            measured_channels |= r.interested_channels()
            measured_tasks |= r.interested_tasks()
        self.measured_channels = measured_channels
        self.measured_tasks = measured_tasks

    # -- predictive QoS: estimator feed (core/estimation.py) -----------------
    def _estimator_tick(self, now: float) -> None:
        """Feed the rate estimators from counters both backends already
        maintain: per-source replay offsets (emitted sequence numbers) and
        per-stage emitted counts for every throughput-constrained stage.
        Pure bookkeeping — no events, no RNG, no new threads — so with
        ``proactive=None`` this never runs and the golden decision traces
        are untouched; with a config set, the estimators observe but only
        the manager's proactive path (``ProactiveConfig.enabled``) acts."""
        cfg = self.proactive
        if cfg is None:
            return
        period = cfg.update_period_ms
        if period is not None:
            if now < self._next_estimator_ms:
                return
            self._next_estimator_ms = now + period
        from .estimation import make_estimator
        from .measurement import RateMeter

        counts: dict[str, float] = {}
        for (jv, _idx), seq in self._source_offsets().items():
            key = f"src:{jv}"
            counts[key] = counts.get(key, 0.0) + seq
        for tc in self.throughput_constraints:
            counts[f"stage:{tc.job_vertex}"] = float(sum(
                self._task_emitted(v)
                for v in self.rg.tasks_of(tc.job_vertex)))
        for key, count in counts.items():
            meter = self._rate_meters.get(key)
            if meter is None:
                meter = self._rate_meters[key] = RateMeter()
            rate = meter.sample(now, count)
            if rate is None:
                continue  # first observation: no span to rate over yet
            est = self._rate_estimators.get(key)
            if est is None:
                est = self._rate_estimators[key] = make_estimator(
                    cfg.estimator, **cfg.estimator_args)
            est.update(now, rate)

    # -- controller attachment + shared telemetry ---------------------------
    def attach_elastic(self, controller: ElasticController,
                       sample=None) -> None:
        """Attach an ElasticController; its constraint's vertex is watched
        (delivered rate + mean utilization) and scaled live, both out and
        in.

        ``sample`` optionally replaces the default emitted/busy telemetry:
        a callable ``(now_ms) -> (rate, utilization)`` owning its own
        deltas — the token-aware Decode autoscaler feeds token throughput
        and KV-cache occupancy through this seam."""
        st = {"ctl": controller, "last_t": self.clock.now(),
              "last_emitted": 0, "last_busy": 0.0, "sample": sample}
        self._elastic.append(st)
        self._schedule_elastic(st, controller.c.window_ms / 2.0)

    def elastic_check(self, st: dict) -> ScaleDecision | None:
        """One telemetry sample + policy check for an attached controller;
        applies the decision (if any) through the shared re-wiring path."""
        ctl: ElasticController = st["ctl"]
        now = self.clock.now()
        tasks = self.rg.tasks_of(ctl.c.job_vertex)
        sample = st.get("sample")
        if sample is not None:
            rate, util = sample(now)
        else:
            emitted = sum(self._task_emitted(v) for v in tasks)
            busy = sum(self._task_busy_ms(v) for v in tasks)
            dt = max(now - st["last_t"], 1e-9)
            rate = max(emitted - st["last_emitted"], 0) / (dt / 1e3)
            util = max(busy - st["last_busy"], 0.0) / dt / max(len(tasks), 1)
            st["last_t"], st["last_emitted"], st["last_busy"] = (
                now, emitted, busy)
        d = ctl.check(now, len(tasks), rate, min(util, 1.0))
        if d is not None and self.apply_scale_decision(d):
            # re-baseline the counters over the re-wired task group so the
            # next sample is not skewed by spawned/retired tasks
            if sample is not None:
                sample(self.clock.now())
            else:
                tasks = self.rg.tasks_of(ctl.c.job_vertex)
                st["last_emitted"] = sum(
                    self._task_emitted(v) for v in tasks)
                st["last_busy"] = sum(self._task_busy_ms(v) for v in tasks)
                st["last_t"] = self.clock.now()
        return d

    # -- hooks backends must provide ----------------------------------------
    def _spawn_task(self, v) -> None:
        raise NotImplementedError

    def _open_channel(self, c) -> None:
        raise NotImplementedError

    def _unroute_channel(self, c) -> None:
        raise NotImplementedError

    def _drain_tasks(self, vs) -> bool:
        """Drain the given tasks' pending input; return False on timeout
        (never silently proceed on an undrained inbox)."""
        raise NotImplementedError

    def _retire_task(self, v) -> None:
        raise NotImplementedError

    def _flush_task_outputs(self, v) -> None:
        raise NotImplementedError

    def _task_is_chained(self, v) -> bool:
        raise NotImplementedError

    def _dissolve_chain(self, chain) -> bool:
        """Backend mechanics of unchaining: restore each fused member's own
        execution and flip the chain channels back to buffered hand-over.
        Returns False if the chain could not be dissolved (the caller then
        aborts the rescale instead of orphaning a fused task)."""
        return False

    def _add_worker(self, w: int) -> None:
        """Create per-worker plumbing (QoS reporter, CPU model) for a
        freshly acquired pool worker."""
        raise NotImplementedError

    def _task_emitted(self, v) -> int:
        raise NotImplementedError

    def _task_busy_ms(self, v) -> float:
        raise NotImplementedError

    def _schedule_elastic(self, st: dict, period_ms: float) -> None:
        raise NotImplementedError

    # -- keyed-state hooks (defaults: stateless backend) ---------------------
    def _quiesce_tasks(self, vs) -> bool:
        """Pause the given tasks and wait until they are between items, so a
        state snapshot never races an in-flight update (no-op for the
        discrete-event backend, where migration runs within one event).
        Returns False if some task could not be parked in time."""
        return True

    def _resume_tasks(self, vs) -> None:
        """Undo ``_quiesce_tasks``."""

    def _task_state(self, v):
        """Return the task's ``StateStore`` (or None for stateless tasks)."""
        return None

    def _reroute_queued(self, vs) -> None:
        """After a routing-table commit: re-home items of moved key ranges
        still queued at their old owners (backends that enforce ownership at
        processing time may leave this a no-op)."""

    # -- crash-recovery hooks (defaults keep fault-free backends inert) ------
    def _respawn_task(self, v) -> None:
        """Re-create the execution of a crashed subtask on its (already
        re-assigned) replacement worker.  Unlike ``_spawn_task`` for a
        grown vertex, the RuntimeVertex identity is *reused* — routing
        table, constraints and channel structure survive unchanged."""
        self._spawn_task(v)

    def _repoint_in_channels(self, v) -> None:
        """Re-aim the existing inbound channels of a respawned subtask at
        its new execution (backends whose delivery indirects through the
        RuntimeVertex may leave this a no-op)."""

    def _replay_sources(self, offsets, now: float) -> int:
        """Roll every source back to its checkpointed offset (``offsets``:
        ``(job_vertex, index) -> seq`` or None when no snapshot exists) and
        make crashed sources emit again.  Returns the number of items that
        will be re-emitted (the replay window)."""
        return 0

    def _source_offsets(self) -> dict:
        """Current per-source replay offsets, ``(job_vertex, index) -> seq``
        (recorded into every periodic checkpoint)."""
        return {}

    def _crash_dissolve_chain(self, chain) -> None:
        """Tear down a chain one of whose members died.  Unlike
        ``_dissolve_chain`` this must not touch the dead member's execution
        (it is gone) and must never fail — the chain *is* dissolved, the
        only question is cleaning up the survivors' wiring."""

    def _drop_worker_plumbing(self, w: int) -> None:
        """Discard per-worker plumbing (QoS reporter, CPU model) of a dead
        worker so no stale handle outlives the crash."""
        if w in self.reporters:
            # rebind-without-w: readers holding the old dict see a
            # consistent snapshot (same idiom as _add_worker's insert)
            self.reporters = {k: r for k, r in self.reporters.items()
                              if k != w}


def split_constraints(constraints) -> tuple[list, list[ThroughputConstraint]]:
    """Partition a mixed constraint list into (latency, throughput) — both
    backends accept ThroughputConstraints alongside JobConstraints."""
    latency, throughput = [], []
    for c in constraints:
        if isinstance(c, ThroughputConstraint):
            throughput.append(c)
        else:
            latency.append(c)
    return latency, throughput


# -- runtime invariant sanitizer hook (analysis/sanitize.py) -----------------
# Under REPRO_SANITIZE=1 every keyed-state migration is followed by an
# ownership scan: each key of the stage must reside in exactly the store of
# its routed owner (NS-S003).
from ..analysis import sanitize as _sanitize  # noqa: E402

if _sanitize.SANITIZE:  # pragma: no cover - exercised via subprocess tests
    _sanitize.instrument_rewirer(RuntimeRewirer)
