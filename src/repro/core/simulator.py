"""Discrete-event simulator: the paper's control plane at paper scale.

Runs the *identical* QoS code (setup.py, measurement.py, manager.py,
buffers.py, chaining.py) on a simulated 200-node cluster — tasks are
single-server queues with configured per-item CPU cost, channels have
output buffers, serialization/transport overhead and bandwidth, exactly the
Fig. 1 processing pattern.  Used by benchmarks to reproduce Fig. 2 and the
Fig. 7/8/9 scenario suite at n=200, and by tests for deterministic QoS
behaviour checks.

Batched event core (the PR-4 hot-path overhaul).  The event heap stores
slotted records ``(time, seq, kind, a, b, c)`` — plain tuples dispatched on
an int ``kind`` — instead of per-item allocated closures:

* a shipped output buffer is ONE event carrying its whole item batch
  (``_EV_SHIP``: the batch is enqueued and served without further wakeups),
* one service completion is ONE event (``_EV_COMPLETE``) whose dispatch
  also starts the task's next queued item and drains the worker CPU's ready
  queue — there are no intermediate "wakeup" events between completions,
* sources advance through a mutable per-source record (``_EV_SOURCE`` /
  ``_EV_SRC_EMIT``) instead of a closure per emitted item,
* ``schedule(at_ms, fn)`` still accepts arbitrary callables (``_EV_CALL``)
  for tests/benchmarks that inject actions mid-run.

Per-item routing is the O(1) dense-table lookup of core/routing.py
(``router.table[key & router.mask]``), and every task/channel caches its
worker id, CPU model, and QoS reporter (all fixed for the object's
lifetime — elastic re-wiring only ever ADDS workers and swaps manager
scopes, never rebinds these).

Determinism contract: under a fixed ``seed`` the event core is bit-exact —
event count, event order (heap ties broken by a global sequence number),
all measurement timestamps, and therefore every QoS decision
(BufferSizeUpdate / ChainRequest / ScaleRequest / GiveUp) are a pure
function of the scenario.  The slotted core preserves the pre-overhaul
per-item-closure semantics exactly (same events at the same times in the
same order, same float arithmetic); tests/test_sim_determinism.py pins
golden decision traces recorded before the rewrite.

Batched-completion mode (``event_mode="batched"``, opt-in).  The exact core
spends one heap event per service completion, which tops out around ~200k
events/s — not enough for the paper's full Fig. 8 grid (n=200, m=800).  The
batched mode coalesces a task's queued run of items into ONE completion
event (``_EV_BATCH``): the run is retired with per-item emission timestamps
computed analytically (cumulative service times — the exact core's own
float accumulation, so per-item instants agree bit-for-bit), and a second
event (``_EV_BDONE``) releases the task and its core at the run's analytic
end.  Sources coalesce the same way: one ``_EV_SOURCE`` event emits a chunk
of items at their exact analytic pacing instants (``rate_fn`` is sampled at
every per-item emission time, so bursty pacing matches item for item).  QoS
measurement (tags, task samples, buffer lifetimes), buffer fill/flush,
routing, and manager decision points all run at the same logical instants
as the exact core — they are just *recorded* from inside the batch event.
Runs are capped at ``batch_horizon_ms`` (default: one control-tick period)
so no observer ever sees effects further than one control tick ahead, and
run splits are timestamp-invariant (tests/test_sim_modes.py).
See ``StreamSimulator.event_mode`` for the two modes' determinism contract:
``"exact"`` is pinned bit-exactly by tests/golden/sim_decisions.json;
``"batched"`` is pinned bit-exactly by tests/golden/sim_decisions_batched.json
and *decision-equivalent* to exact (same QoS decision multisets, latency
stats within 1%) on the golden scenarios.

Simplifications vs. the threaded engine (recorded here on purpose):
* CPython thread-scheduling noise is absent — latencies are deterministic,
* per-worker CPU contention is modeled per task only (a worker is assumed to
  have enough cores for its unchained tasks, like the paper's 8-core nodes).

Elastic re-parallelization (paper §6) goes through the SAME shared runtime
re-wiring layer as the threaded engine (core/elastic.py RuntimeRewirer):
``scale_out``/``scale_in`` mutate the running simulation — tasks join or
retire, channels re-wire per job-edge pattern, retiring tasks hand their
queues to surviving siblings (no item loss), and QoS manager/reporter
scopes are refreshed.  Attached ``ElasticController``s and the manager's
``ScaleRequest`` countermeasure drive the identical ``ScaleDecision`` path
on both backends.
"""
from __future__ import annotations

import random
from bisect import insort
from collections import deque
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable

from .buffers import BufferArena, BufferSizingPolicy, OutputBuffer
from .chaining import ChainRequest
from .clock import SimClock
from .constraints import JobConstraint
from .elastic import (
    DrainTimeout, RuntimeRewirer, ScaleRequest, split_constraints)
from .estimation import ProactiveConfig
from .eventq import (
    _MAX_T,
    CalendarEventQueue,
    HeapEventQueue,
    heappop as _heappop,
    heappush as _heappush,
    make_event_queue,
)
from .graphs import JobGraph, RuntimeGraph, RuntimeVertex
from .manager import Action, BufferSizeUpdate, GiveUp, QoSManager
from .measurement import QoSReporter, Tag, latency_percentile
from .placement import WorkerPool
from .routing import StateStore
from .setup import compute_qos_setup, compute_reporter_setup

# Slotted event kinds (scheduler records are ``(time, seq, kind, a, b, c)``;
# ties break on ``seq``, so ``kind``/payload never reach a comparison).
_EV_CALL = 0      # a = callable                      (schedule() back-compat)
_EV_SHIP = 1      # a = dst _SimTask,  b = items, c = channel_id
_EV_COMPLETE = 2  # a = _SimTask,      b = item,  c = stages
_EV_SRC_EMIT = 3  # a = last _SimTask, b = source item
_EV_SOURCE = 4    # a = dense source index (StreamSimulator.src_* columns)
_EV_CONTROL = 5   # QoS control tick
_EV_FLUSH = 6     # stale-buffer sweep
_EV_BATCH = 7     # a = _SimTask, b = item, c = stages (batched first completion)
_EV_BDONE = 8     # a = _SimTask — analytic end of a batched run

#: empty latency-timeline cell (shared zero fold state)
_T0 = (0.0, 0)


def analytic_emission_times(start_ms: float, service_ms_seq) -> list[float]:
    """Per-item completion/emission instants of a queued run served
    back-to-back from ``start_ms`` — the batched core's analytic timestamps.

    Accumulated EXACTLY like the exact core (sequential float addition:
    item j completes at ``(...(start + s1) + s2 ...) + sj``), so the two
    modes' per-item instants agree bit-for-bit, and the sequence is
    invariant under run-boundary splits: serving ``s[:k]`` then ``s[k:]``
    from the first run's analytic end replays the identical float ops.
    Property-tested in tests/test_sim_modes.py.
    """
    out = []
    t = start_ms
    for s in service_ms_seq:
        t += s
        out.append(t)
    return out


@dataclass
class SimNetConfig:
    """1 GBit/s links, small fixed ship overhead per buffer (meta data, memory
    management, thread sync — §2.2.1), cheap same-worker hand-over."""

    bandwidth_bytes_per_ms: float = 125_000.0  # 1 Gbit/s
    per_buffer_overhead_ms: float = 0.10
    #: queue hand-over between threads on the same worker (wakeup, sync,
    #: scheduling under load) — what dynamic task chaining eliminates.
    same_worker_overhead_ms: float = 2.0
    propagation_ms: float = 0.15


@dataclass(slots=True)
class SimItem:
    created_at_ms: float
    size_bytes: int
    key: int
    tag: Tag | None = None
    emitted_at_ms: float = 0.0


@dataclass
class SimSourceSpec:
    rate_items_per_s: float
    item_bytes: int = 128
    #: global round-robin key space (stream-group ids); with
    #: ``keys_per_task`` set, source subtask p cycles only over its own keys
    #: [p*keys_per_task, (p+1)*keys_per_task) — the paper's Partitioner
    #: forwards each stream group to the one Decoder responsible for it.
    keys: int | None = None
    keys_per_task: int | None = None
    #: optional bursty pacing: elapsed_ms -> items/s (same contract as
    #: SourceSpec.rate_fn on the threaded engine)
    rate_fn: Callable[[float], float] | None = None

    def rate_at(self, elapsed_ms: float) -> float:
        if self.rate_fn is not None:
            return self.rate_fn(elapsed_ms)
        return self.rate_items_per_s


class _SimChannel:
    """Sender-side output buffer + transport for one channel.  Worker ids,
    the source-side QoS reporter, and the destination task are fixed for the
    channel's lifetime and cached at construction.

    Fill state lives in the simulator's shared :class:`BufferArena` (five
    flat columns indexed by the dense ``chi`` handed out here) on normal
    runs; under instrumentation (``sim.arena is None``) each channel keeps
    a real :class:`OutputBuffer` instead, because the sanitizer/race
    checkers wrap that class's methods.  Both layouts execute the same
    arithmetic in the same order, so decision traces are identical."""

    __slots__ = ("channel", "cid", "chi", "buffer", "sim", "cross_worker",
                 "src_reporter", "dst_task", "chained", "blackhole_until")

    def __init__(self, channel, sim: "StreamSimulator", capacity: int) -> None:
        self.channel = channel
        self.cid = channel.id
        self.sim = sim
        self.blackhole_until = 0.0  # ChannelBlackhole fault: ship no earlier
        arena = sim.arena
        if arena is None:
            self.chi = -1
            self.buffer = OutputBuffer(channel.id, capacity)
        else:
            self.chi = arena.alloc(capacity)
            self.buffer = None
        self.cross_worker = sim.rg.worker(channel.src) != sim.rg.worker(channel.dst)
        self.src_reporter = sim.reporters[sim.rg.worker(channel.src)]
        self.dst_task = sim.tasks[channel.dst]
        self.chained = False  # mirror of sim.chained_channels for this id

    def capacity_bytes(self) -> int:
        arena = self.sim.arena
        if arena is None:
            return self.buffer.capacity_bytes
        return arena.cap[self.chi]

    def try_update_size(self, new_size: int, base_version: int) -> bool:
        arena = self.sim.arena
        if arena is None:
            return self.buffer.try_update_size(new_size, base_version)
        return arena.try_update_size(self.chi, new_size, base_version)

    def send(self, item: SimItem, now: float) -> None:
        item.emitted_at_ms = now
        sim = self.sim
        cid = self.cid
        if cid in sim.measured_channels and self.src_reporter.should_tag(
                cid, now):
            item.tag = Tag(cid, now)
        arena = sim.arena
        if arena is None:
            if self.buffer.append(item, item.size_bytes, now):
                self.flush(now)
        elif arena.append(self.chi, item, item.size_bytes, now):
            self.flush(now)

    def send_run(self, items: list[SimItem], times: list[float]) -> None:
        """Send a same-size run of items with increasing (analytic) emission
        times — the batched source path.  Tag decisions are evaluated per
        item at its own instant (one per interval, like ``send``); buffer
        fill accounting is batch-aware: the run is split at the arithmetic
        capacity crossings (``OutputBuffer.room_for``/``append_run``) and
        each crossing group ships at its crossing item's instant, exactly
        where per-item ``send`` would have shipped it."""
        sim = self.sim
        cid = self.cid
        if cid in sim.measured_channels:
            rep = self.src_reporter
            for item, t in zip(items, times):
                item.emitted_at_ms = t
                if rep.should_tag(cid, t):
                    item.tag = Tag(cid, t)
        else:
            for item, t in zip(items, times):
                item.emitted_at_ms = t
        size = items[0].size_bytes
        start = 0
        n = len(items)
        arena = sim.arena
        if arena is None:
            buf = self.buffer
            while start < n:
                end = min(start + buf.room_for(size), n)
                if buf.append_run(items[start:end], size, times[start]):
                    self.flush(times[end - 1])
                start = end
        else:
            chi = self.chi
            while start < n:
                end = min(start + arena.room_for(chi, size), n)
                if arena.append_run(chi, items[start:end], size,
                                    times[start]):
                    self.flush(times[end - 1])
                start = end

    def flush(self, now: float | None = None) -> None:
        sim = self.sim
        arena = sim.arena
        if arena is None:
            buf = self.buffer
            if not buf.items:
                return
            if now is None:
                now = sim.clock.now()
            items, nbytes, lifetime = buf.take(now)
            cap, ver = buf.capacity_bytes, buf.version
        else:
            chi = self.chi
            if not arena.items[chi]:
                return
            if now is None:
                now = sim.clock.now()
            items, nbytes, lifetime = arena.take(chi, now)
            cap, ver = arena.cap[chi], arena.ver[chi]
        cid = self.cid
        if cid in sim.measured_channels:
            self.src_reporter.record_output_buffer_lifetime(
                cid, lifetime, cap, ver,
            )
        net = sim.net
        if self.cross_worker:
            delay = (
                net.per_buffer_overhead_ms
                + nbytes / net.bandwidth_bytes_per_ms
                + net.propagation_ms
            )
        else:
            delay = net.same_worker_overhead_ms
        sim.total_bytes += nbytes
        sim.total_buffers += 1
        # ChannelBlackhole fault: a partitioned link holds the shipment
        # until the partition heals (departure deferred, not dropped)
        depart = now if now >= self.blackhole_until else self.blackhole_until
        sim._seq += 1
        sim._push_rec((depart + delay, sim._seq, _EV_SHIP,
                       self.dst_task, items, cid))


class _SimTask:
    """Single-server queue; when head of a chain, service covers the whole
    chain (§3.5.2 — one thread runs all chained tasks)."""

    __slots__ = (
        "vertex", "vid", "sim", "svc_ms", "fan_in", "out_bytes", "stateful",
        "state", "is_sink", "queue", "halted", "retired", "crashed",
        "chained_into", "chain_next", "_fan_count", "_pending_task_sample",
        "emitted", "out_by_jv",
        "out_groups", "_inflight_since", "worker", "ti", "cpu_i",
        "index", "router", "reporter",
    )

    def __init__(self, vertex: RuntimeVertex, sim: "StreamSimulator") -> None:
        self.vertex = vertex
        self.vid = vertex.id
        self.sim = sim
        jv = sim.jg.vertices[vertex.job_vertex]
        self.svc_ms = jv.sim_cpu_ms
        self.fan_in = max(jv.sim_fan_in, 1)
        self.out_bytes = jv.sim_item_bytes
        self.stateful = jv.stateful
        #: per-key state; for stateful vertices the simulator maintains a
        #: per-key processed-item count (its tasks are cost models without
        #: user code) and migration moves it along key ranges (sliced with
        #: the group router's range width; lock-free: one event at a time)
        self.state = StateStore(
            sim.rg.routers[vertex.job_vertex].num_ranges, locked=False)
        self.is_sink = not sim.jg.out_edges(vertex.job_vertex)
        self.queue: deque[SimItem] = deque()
        self.halted = False
        self.retired = False           # elastically scaled in
        self.crashed = False           # worker died (implies retired)
        self.chained_into: RuntimeVertex | None = None  # member of a chain
        self.chain_next: RuntimeVertex | None = None    # next stage if chained
        self._fan_count = 0
        self._pending_task_sample: float | None = None
        self.emitted = 0          # lifetime emissions (elastic telemetry)
        # busy flag and busy-ms accounting live in the simulator's flat
        # per-task columns (t_busy / t_busy_w / t_busy_t) at this dense id
        self.ti = len(sim.t_busy)
        sim.t_busy.append(False)
        sim.t_busy_w.append(0.0)
        sim.t_busy_t.append(0.0)
        # emission routing: dst job vertex -> channels sorted by dst index;
        # out_groups is the hot-path projection [(router, channels), ...]
        # rebuilt by _rebuild_out() after every wiring mutation
        self.out_by_jv: dict[str, list] = {}
        self.out_groups: list[tuple[Any, list]] = []
        self._inflight_since: float | None = None
        # fixed for the task's lifetime (workers are only ever added; the
        # per-worker reporter objects and per-jv router survive QoS-scope
        # refreshes — routers mutate their tables in place, never swap)
        self.index = vertex.index
        self.router = sim.rg.routers[vertex.job_vertex]
        self.worker = sim.rg.worker(vertex)
        self.cpu_i = sim.cpus[self.worker]
        self.reporter = sim.reporters[self.worker]

    def _rebuild_out(self) -> None:
        """Refresh the hot-path routing projection after a wiring mutation
        (channel opened/closed).  Router objects are per job vertex and
        never replaced, so the pairs stay valid until the next mutation."""
        routers = self.sim.rg.routers
        self.out_groups = [
            (routers[jv_name], chans)
            for jv_name, chans in self.out_by_jv.items()
        ]

    def enqueue(self, items: list[SimItem], channel_id: str,
                now: float | None = None) -> None:
        if not (self.retired or self.stateful):
            # fast path: plain delivery (the overwhelming majority of ships)
            self.queue.extend(items)
            if not (self.sim.t_busy[self.ti] or self.halted):
                self._try_start(now)
            return
        jv = self.vertex.job_vertex
        if self.retired:
            if self.crashed:
                # delivery to a crashed task: the process is gone, the items
                # are lost with it — counted as dropped, recovered by replay
                sim = self.sim
                if sim._fault_acct:
                    for it in items:
                        sim._count_drop(it.key)
                return
            # straggler delivery after scale-in: hand each item to its key
            # range's surviving owner so nothing is lost and keyed state
            # stays with its one owner
            group = self.sim.rg.tasks_of(jv)
            if group:
                router = self.sim.rg.routers[jv]
                table, mask = router.table, router.mask
                last = len(group) - 1
                for it in items:
                    owner = (table[it.key & mask]
                             if mask is not None and isinstance(it.key, int)
                             else router.owner(it.key))
                    target = self.sim.tasks.get(
                        group[owner if owner < last else last])
                    if target is None or target.retired:
                        # routing table and group transiently disagree: pick
                        # any survivor directly (never recurse into another
                        # retired task)
                        target = next(
                            (t for g in group
                             if (t := self.sim.tasks.get(g)) is not None
                             and not t.retired), None)
                    if target is not None:
                        target.enqueue([it], channel_id, now)
                return
        if self.stateful:
            # key-ownership enforcement: items whose range migrated away (or
            # that were in flight across a routing-table swap) are re-homed
            # to the range's owner — its state lives there
            router = self.sim.rg.routers[jv]
            table, mask = router.table, router.mask
            index = self.vertex.index
            all_mine = mask is not None
            if all_mine:
                try:
                    for it in items:
                        if table[it.key & mask] != index:
                            all_mine = False
                            break
                except TypeError:  # non-int key: hash-routed slow path
                    all_mine = False
            if all_mine:
                pass  # every item is ours: skip the re-home machinery
            else:
                mine: list[SimItem] = []
                for it in items:
                    owner = (table[it.key & mask]
                             if mask is not None and isinstance(it.key, int)
                             else router.owner(it.key))
                    if owner != index:
                        target = self.sim.tasks.get(RuntimeVertex(jv, owner))
                        if target is not None and target is not self \
                                and not target.retired:
                            target.enqueue([it], channel_id, now)
                            continue
                    mine.append(it)
                items = mine
                if not items:
                    return
        self.queue.extend(items)
        if not (self.sim.t_busy[self.ti] or self.halted):
            self._try_start(now)

    def halt(self, halted: bool) -> None:
        self.halted = halted
        if not halted:
            self._try_start()

    def _try_start(self, now: float | None = None) -> None:
        sim = self.sim
        ti = self.ti
        if sim.t_busy[ti] or self.halted or not self.queue:
            return
        item = self.queue.popleft()
        if now is None:
            now = sim.clock.now()
        # tag evaluated just before user code (§3.3) — includes queue wait
        if item.tag is not None:
            self.reporter.record_channel_latency(
                item.tag.channel_id, now - item.tag.created_at_ms
            )
            item.tag = None
        vid = self.vid
        if (
            self._pending_task_sample is None
            and vid in sim.measured_tasks
            and self.reporter.should_sample_task(vid, now)
        ):
            self._pending_task_sample = now
        # total service time across the chain this item will traverse; the
        # whole chain runs on one core of this task's worker (§3.5.2).
        # Keyed aggregation happens at service START: a migration event
        # fired while this item is in service then snapshots a store that
        # already counts it (a completion-time bump would land in the old
        # owner's store AFTER its ranges were snapshotted away).
        if self.chain_next is None and self.fan_in == 1:
            # inlined _chain_service fast path (unchained, no fan-in gate)
            self._fan_count += 1
            svc = self.svc_ms
            stages = [self]
            if self.stateful:
                self.state.bump(item.key)
        else:
            svc, stages = self._chain_service(item)
            for t in stages:
                if t.stateful:
                    t.state.bump(item.key)
        sim.t_busy[ti] = True
        sim.t_busy_w[ti] += svc
        sim.t_busy_t[ti] += svc
        ci = self.cpu_i  # inlined multi-server CPU submit (per-item hot path)
        if sim.cpu_busy[ci] < sim.cpu_cores[ci]:
            sim.cpu_busy[ci] += 1
            sim._seq += 1
            sim._push_rec((now + svc, sim._seq, sim._complete_kind,
                           self, item, stages))
        else:
            sim.cpu_ready[ci].append((svc, self, item, stages))

    def _chain_service(self, item: SimItem) -> tuple[float, list["_SimTask"]]:
        """Walk the chain from this task; figure out which stages run for this
        item (fan-in gates) and the summed service time.  The overwhelmingly
        common unchained, fan-in-1 case short-circuits."""
        if self.chain_next is None and self.fan_in == 1:
            self._fan_count += 1
            return self.svc_ms, [self]
        stages: list[_SimTask] = []
        svc = 0.0
        t: _SimTask | None = self
        while t is not None:
            svc += t.svc_ms
            stages.append(t)
            t._fan_count += 1
            if t._fan_count % t.fan_in != 0:
                break  # item absorbed here (waiting for group completion)
            t = None if stages[-1].chain_next is None else self.sim.tasks[
                stages[-1].chain_next
            ]
        return svc, stages

    def _finish_item(self, item: SimItem, stages: list["_SimTask"],
                     now: float, sink_acc: tuple[list, list] | None = None,
                     ) -> None:
        """Completion effects of one serviced item at instant ``now``:
        task-latency samples, emission + routing (or sink recording).
        Shared by the exact per-event completion and the batched analytic
        drain — the instants and float arithmetic are identical in both
        modes.  ``sink_acc`` (batched drains) collects sink latencies into
        ``(lats, times)`` arrays for one batch-ingestion call instead of
        per-item recording."""
        sim = self.sim
        last = stages[-1]
        fan_in = last.fan_in
        if fan_in == 1 or last._fan_count % fan_in == 0:
            if self._pending_task_sample is not None:
                vid = self.vid
                if vid in sim.measured_tasks:
                    self.reporter.record_task_latency(
                        vid, now - self._pending_task_sample
                    )
                self._pending_task_sample = None
            # task-latency samples for interior chained stages: service only
            if len(stages) > 1:
                for t in stages[1:]:
                    vid = t.vid
                    if vid in sim.measured_tasks and t.reporter.\
                            should_sample_task(vid, now):
                        t.reporter.record_task_latency(vid, t.svc_ms)
            last.emitted += 1
            if last.is_sink:
                key = item.key
                counts = sim.sink_count_by_key
                counts[key] = counts.get(key, 0) + 1
                if sink_acc is not None:
                    sink_acc[0].append(now - item.created_at_ms)
                    sink_acc[1].append(now)
                else:
                    sim.record_sink_latency(now - item.created_at_ms, now)
            else:
                out = SimItem(item.created_at_ms, last.out_bytes, item.key)
                last.route(out, now)

    def _complete(self, item: SimItem, stages: list["_SimTask"],
                  now: float) -> None:
        if self.crashed:
            # in-service item at crash time: lost with the process (chains
            # are co-located, so one flag covers every stage of this item)
            sim = self.sim
            if sim._fault_acct:
                sim._count_drop(item.key)
            return
        self.sim.t_busy[self.ti] = False
        self._finish_item(item, stages, now)
        self._try_start(now)

    def _complete_batch(self, item: SimItem, stages: list["_SimTask"],
                        now: float) -> bool:
        """Dispatch of one ``_EV_BATCH`` event (batched mode): complete the
        item that was in service, then retire the task's queued run in this
        same event — per-item start/emission instants are the exact core's
        cumulative service times (``analytic_emission_times``), only the
        heap traffic is coalesced.  The run never computes effects past the
        batch boundary (next control tick / flush sweep / injected callback
        — ``StreamSimulator._batch_boundary``): an item whose completion
        would cross it goes back to a real heap event, so every observer
        samples state at the same logical instant as in the exact core; a
        longer queue continues in a fresh run after the boundary, which
        leaves every per-item instant unchanged (run-split invariance).
        Returns True when the task still owns its core (a continuation
        event — ``_EV_BDONE`` at the analytic end, or the crossing item's
        ``_EV_BATCH`` — was scheduled)."""
        sim = self.sim
        ti = self.ti
        sim.t_busy[ti] = False
        self._finish_item(item, stages, now)
        queue = self.queue
        if self.halted or not queue:
            return False
        # drain safety: a fan-in-gated stage's counter is SHARED state when
        # a chain traverses it from another task — its gate must then see
        # real-event interleaving (an analytic bump would race the other
        # bumpers).  Such tasks — a gated chain member, or the head of a
        # chain containing a gated interior stage — complete strictly per
        # event; a standalone gated task is safe (only its own queue, whose
        # order the drain preserves, ever bumps it).
        s: _SimTask | None = self
        while s is not None:
            if s.fan_in != 1 and (s is not self
                                  or self.chained_into is not None):
                self._try_start(now)
                return False
            s = None if s.chain_next is None else sim.tasks[s.chain_next]
        boundary = sim._batch_boundary(now)
        measured_tasks = sim.measured_tasks
        reporter = self.reporter
        push = sim._push_rec
        sink_acc: tuple[list, list] = ([], [])
        tag_lats: dict[str, list[float]] = {}
        hold = False
        t = now
        while queue and t < boundary:
            it = queue.popleft()
            # per-item service start at analytic instant t — the same
            # bookkeeping, at the same logical time, as the exact core's
            # _try_start (tag evaluation, task sampling, keyed-state bump
            # at service START)
            if it.tag is not None:
                tag_lats.setdefault(it.tag.channel_id, []).append(
                    t - it.tag.created_at_ms)
                it.tag = None
            vid = self.vid
            if (
                self._pending_task_sample is None
                and vid in measured_tasks
                and reporter.should_sample_task(vid, t)
            ):
                self._pending_task_sample = t
            if self.chain_next is None and self.fan_in == 1:
                self._fan_count += 1
                svc = self.svc_ms
                run_stages = [self]
                if self.stateful:
                    self.state.bump(it.key)
            else:
                svc, run_stages = self._chain_service(it)
                for s in run_stages:
                    if s.stateful:
                        s.state.bump(it.key)
            sim.t_busy_w[ti] += svc
            sim.t_busy_t[ti] += svc
            t_next = t + svc
            if t_next >= boundary:
                # crossing item: it is in service now (started at t, like
                # the exact core), but it completes on the far side of the
                # boundary — finish it through a real completion event so
                # its effects order correctly around the observer (a past-
                # the-cutoff completion is dropped there, also like exact)
                sim.t_busy[ti] = True
                sim._seq += 1
                push((t_next, sim._seq, _EV_BATCH, self, it, run_stages))
                hold = True
                break
            t = t_next
            self._finish_item(it, run_stages, t, sink_acc)
        else:
            if t > now:
                # drained to an idle queue: the run owns its core until its
                # analytic end
                sim.t_busy[ti] = True
                sim._seq += 1
                push((t, sim._seq, _EV_BDONE, self, None, None))
                hold = True
            elif queue:
                # boundary coincides with ``now`` (e.g. a zero-delay
                # injected callback): nothing can be drained analytically —
                # start the next item through the regular event path
                self._try_start(now)
        for cid, lats in tag_lats.items():
            reporter.record_channel_latency_batch(cid, lats)
        if sink_acc[0]:
            sim.record_sink_latency_batch(sink_acc[0], sink_acc[1])
        return hold

    def route(self, item: SimItem, now: float | None = None) -> None:
        if now is None:
            now = self.sim.clock.now()
        key = item.key
        for router, chans in self.out_groups:
            if len(chans) == 1:
                ch = chans[0]
            else:
                # O(1) key-range routing: one masked index into the consumer
                # group's dense lookup table (channels sorted by dst index;
                # clamped while a rescale is transiently re-wiring this
                # sender)
                mask = router.mask
                idx = (router.table[key & mask]
                       if mask is not None and isinstance(key, int)
                       else router.owner(key))
                if idx >= len(chans):
                    idx = len(chans) - 1
                ch = chans[idx]
            if ch.chained:
                # direct hand-over: zero-cost, record ~0 channel latency sample
                sim = self.sim
                cid = ch.cid
                if cid in sim.measured_channels and ch.src_reporter.\
                        should_tag(cid, now):
                    ch.dst_task.reporter.record_channel_latency(cid, 0.0)
                ch.dst_task.enqueue([item], cid, now)
            else:
                ch.send(item, now)
                if self.retired:
                    # the channel was unlinked from the runtime graph; no
                    # later buffer-full event will flush it, so ship now
                    ch.flush(now)


class StreamSimulator(RuntimeRewirer):
    def __init__(
        self,
        jg: JobGraph,
        constraints: list,
        num_workers: int | None = None,
        sources: dict[str, SimSourceSpec] | None = None,
        initial_buffer_bytes: int = 32 * 1024,
        measurement_interval_ms: float = 1_000.0,
        enable_qos: bool = True,
        enable_chaining: bool = True,
        policy: BufferSizingPolicy | None = None,
        net: SimNetConfig | None = None,
        seed: int = 0,
        latency_bucket_ms: float = 1_000.0,
        cores_per_worker: int = 8,
        max_buffer_lifetime_ms: float | None = 5_000.0,
        pool: WorkerPool | None = None,
        num_key_ranges: int | None = None,
        event_mode: str = "exact",
        batch_horizon_ms: float | None = None,
        scheduler: str = "calendar",
        preflight: bool = True,
        fault_plan=None,
        checkpointer=None,
        heartbeat_timeout_ms: float = 1_500.0,
        proactive: ProactiveConfig | None = None,
    ) -> None:
        self.jg = jg
        #: network model — resolved *before* pre-flight so the static
        #: feasibility pass prices transport with the exact parameters the
        #: run will use
        self.net = net or SimNetConfig()
        # pre-flight validation (analysis/graph_check.py): same contract as
        # StreamEngine — ERRORs raise before expansion, WARNs are stored in
        # preflight_diagnostics, preflight=False opts out.  The pass reads
        # no randomness and mutates nothing, so the bit-exact determinism
        # goldens are unaffected.  Imported lazily: graph_check imports
        # repro.core.
        if preflight:
            from ..analysis.graph_check import run_preflight
            self.preflight_diagnostics = run_preflight(
                jg, constraints, pool=pool, num_workers=num_workers,
                num_key_ranges=num_key_ranges,
                initial_buffer_bytes=initial_buffer_bytes,
                max_buffer_lifetime_ms=max_buffer_lifetime_ms,
                policy=policy, sources=sources, net=self.net,
                proactive=proactive,
                measurement_interval_ms=measurement_interval_ms)
        else:
            self.preflight_diagnostics = []
        #: event-core execution mode — the determinism contract:
        #:
        #: * ``"exact"`` (default): one heap event per service completion.
        #:   Bit-exact under a fixed seed — event count/order, every
        #:   measurement timestamp and QoS decision are pinned by the
        #:   goldens in tests/golden/sim_decisions.json; any change to this
        #:   mode's event semantics is a contract break.
        #: * ``"batched"`` (opt-in): a task's queued run retires in one
        #:   event with analytically computed per-item emission timestamps
        #:   (cumulative service times — the same float accumulation as the
        #:   exact core), and sources emit in analytic chunks.  Still fully
        #:   deterministic under a fixed seed (pinned by
        #:   tests/golden/sim_decisions_batched.json), but only
        #:   *decision-equivalent* to exact: identical item conservation,
        #:   per-stream counts and QoS decision multisets, latency stats
        #:   within 1% (tests/test_sim_modes.py) — not bit-exact event
        #:   traces, because observers (control ticks, flush sweeps) can
        #:   see a run's effects up to ``batch_horizon_ms`` early.
        if event_mode not in ("exact", "batched"):
            raise ValueError(
                f"event_mode must be 'exact' or 'batched', got {event_mode!r}")
        self.event_mode = event_mode
        self.batched = event_mode == "batched"
        #: injected failure schedule (core/faults.py) — None keeps the run
        #: bit-exact fault-free (no extra events, state, or RNG draws).
        #: Faults need per-object channel buffers and the reference loop
        #: (a crash must be able to wipe a specific channel's fill state),
        #: and the batched core's analytic lookahead cannot be torn at an
        #: arbitrary crash instant — so faulted runs run exact/reference.
        if fault_plan is not None and self.batched:
            raise ValueError(
                "fault injection requires event_mode='exact' (a batched "
                "run's analytic lookahead cannot be cut at a crash instant)")
        self.fault_plan = fault_plan
        #: fault accounting toggle: per-key emitted/dropped/replayed ledgers
        #: (the conservation-modulo-replay invariant) are maintained only
        #: when a fault plan is present
        self._fault_acct = fault_plan is not None
        self.emitted_by_key: dict = {}
        self.dropped_by_key: dict = {}
        self.replayed_by_key: dict = {}
        #: event-scheduler backend (core/eventq.py): ``"calendar"`` (default)
        #: or ``"heap"`` (the reference).  Both produce the exact total order
        #: on ``(time, seq)``, so this is a pure performance knob.
        if scheduler not in ("calendar", "heap"):
            raise ValueError(
                f"scheduler must be 'calendar' or 'heap', got {scheduler!r}")
        self.scheduler = scheduler
        #: max analytic lookahead of one batched run/chunk (caps how far a
        #: batch event's effects can precede the clock); defaults to one
        #: control-tick period so measurement skew stays under a tick
        self.batch_horizon_ms = (
            batch_horizon_ms if batch_horizon_ms is not None
            else measurement_interval_ms / 4.0)
        if self.batched and not self.batch_horizon_ms > 0.0:
            raise ValueError("batch_horizon_ms must be > 0")
        self._complete_kind = _EV_BATCH if self.batched else _EV_COMPLETE
        #: run-boundary cutoff (set by ``run``): the exact core drops heap
        #: events past the duration; batched drains/chunks mirror that by
        #: never completing or routing an item past it
        self._run_until = float("inf")
        #: max output-buffer lifetime (§3.5.1 companion; same contract as
        #: StreamEngine): an under-filled buffer ships once it has been open
        #: this long, so low rates cannot strand items forever.  None
        #: disables (pure Fig. 2 buffer-size sweeps).
        self.max_buffer_lifetime_ms = max_buffer_lifetime_ms
        self.constraints, self.throughput_constraints = split_constraints(
            constraints)
        # worker placement: an explicit WorkerPool (elastic policies,
        # acquire/release) or a fixed modulo fleet of ``num_workers``;
        # num_key_ranges widens the routers for m > 128 stages
        self.rg = RuntimeGraph(jg, num_workers, pool=pool,
                               num_key_ranges=num_key_ranges)
        self.clock = SimClock()
        self.enable_qos = enable_qos
        self.enable_chaining = enable_chaining
        self.interval_ms = measurement_interval_ms
        self.initial_buffer_bytes = initial_buffer_bytes
        self.policy = policy
        self.seed = seed
        self.rng = random.Random(seed)
        self.sources = sources or {}
        # predictive QoS (core/estimation.py): set BEFORE manager
        # construction so the estimator registry dict the managers hold is
        # the same object _estimator_tick feeds (_init_rewirer preserves it)
        self.proactive = proactive
        self._rate_estimators: dict = {}
        self.latency_bucket_ms = latency_bucket_ms
        self.cores_per_worker = cores_per_worker

        self.allocations = compute_qos_setup(jg, self.constraints, self.rg)
        self.reporter_setup = compute_reporter_setup(self.allocations, self.rg)
        self.reporters = {
            w: QoSReporter(w, self.clock, measurement_interval_ms,
                           rng=random.Random(seed * 7919 + w))
            for w in self.rg.worker_ids()
        }
        for w, routes in self.reporter_setup.task_routes.items():
            for mgr, tasks in routes.items():
                self.reporters[w].assign_manager(mgr, (), tasks)
        for w, routes in self.reporter_setup.channel_routes.items():
            for mgr, chans in routes.items():
                self.reporters[w].assign_manager(mgr, chans, ())
        self.managers = {
            w: QoSManager(alloc, self.rg, self.clock, policy=policy,
                          throughput_constraints=self.throughput_constraints,
                          proactive=proactive,
                          estimators=self._rate_estimators)
            for w, alloc in self.allocations.items()
        }
        self.measured_channels: set[str] = set()
        self.measured_tasks: set[str] = set()
        for r in self.reporters.values():
            self.measured_channels |= r.interested_channels()
            self.measured_tasks |= r.interested_tasks()

        # struct-of-arrays hot state: the dispatch loop indexes flat list
        # columns through dense ids instead of chasing per-entity objects.
        #   per task (dense id _SimTask.ti): busy flag, busy-ms window/total
        self.t_busy: list[bool] = []
        self.t_busy_w: list[float] = []
        self.t_busy_t: list[float] = []
        #   per worker CPU (dense id _SimTask.cpu_i; self.cpus maps worker
        #   id -> dense id): core count, busy cores, FIFO ready queue
        self.cpu_cores: list[int] = []
        self.cpu_busy: list[int] = []
        self.cpu_ready: list[deque] = []
        self.cpus: dict[int, int] = {}
        for w in self.rg.worker_ids():
            self._alloc_cpu(w)
        #   per channel (dense id _SimChannel.chi): output-buffer fill state,
        #   shared BufferArena columns.  Instrumented runs (REPRO_SANITIZE /
        #   REPRO_RACE_CHECK) keep per-channel OutputBuffer objects instead,
        #   because the checkers wrap that class's methods.
        self.arena: BufferArena | None = (
            None if (_INSTRUMENTED or fault_plan is not None)
            else BufferArena())
        #   per source subtask (dense id, the _EV_SOURCE payload): task,
        #   emission seq, subtask index, item bytes, key-space shape, pacing
        self.src_task: list[_SimTask] = []
        self.src_seq: list[int] = []
        self.src_index: list[int] = []
        self.src_bytes: list[int] = []
        self.src_keys: list[int | None] = []
        self.src_kpt: list[int | None] = []
        self.src_rate_fn: list[Callable[[float], float] | None] = []
        self.src_period: list[float] = []
        self.src_spec: list[SimSourceSpec] = []
        #: False once a source's pending _EV_SOURCE chain died with its
        #: crashed task (recovery then restarts the chain exactly once)
        self.src_live: list[bool] = []
        self.tasks: dict[RuntimeVertex, _SimTask] = {
            v: _SimTask(v, self) for v in self.rg.vertices
        }
        self.channels: dict[str, _SimChannel] = {}
        for c in self.rg.channels:
            sc = _SimChannel(c, self, initial_buffer_bytes)
            self.channels[c.id] = sc
            self.tasks[c.src].out_by_jv.setdefault(c.dst.job_vertex, []).append(sc)
        for t in self.tasks.values():  # deterministic routing order
            for jv_name in t.out_by_jv:
                t.out_by_jv[jv_name].sort(key=lambda sc: sc.channel.dst.index)
            t._rebuild_out()

        self.chained_channels: dict[str, bool] = {}
        self.chained_groups: list[tuple[str, ...]] = []
        self.give_ups: list[GiveUp] = []
        self._init_rewirer()
        self.sink_latencies: list[float] = []
        #: per-stream accounting: sink arrivals per item key (stream-group
        #: id) — what the cross-mode equivalence suite compares
        self.sink_count_by_key: dict = {}
        self.latency_timeline: dict[int, tuple[float, int]] = {}
        self.total_bytes = 0
        self.total_buffers = 0

        self._seq = 0
        # event queue: the calendar queue's initial bucket width comes from
        # the aggregate source rate (~4 events per item per ms: source fire,
        # emit, ship, complete); the adaptive retune corrects any error
        agg_rate = sum(
            spec.rate_items_per_s * len(self.rg.tasks_of(jv_name))
            for jv_name, spec in self.sources.items()
        )
        rate_hint = 4.0 * agg_rate / 1e3
        self._eq = make_event_queue(
            scheduler, rate_hint if rate_hint > 0.0 else None)
        #: push one record preserving total (time, seq) order — bound to the
        #: C heappush on the heap arm for zero call overhead
        if scheduler == "heap":
            self._push_rec: Callable[[tuple], None] = partial(
                _heappush, self._eq.data)
        else:
            self._push_rec = self._eq.push
        #: pending schedule() callback times (min-heap): batched runs treat
        #: the earliest one as an observer boundary, so injected actions
        #: (scale/chain probes, elastic controller ticks) see no analytic
        #: lookahead — they sample state at the same instant as exact mode
        self._call_times: list[float] = []
        #: the ACTUALLY scheduled next control-tick / flush-sweep instants
        #: (observer boundaries for batched runs; tracking the scheduled
        #: floats — not grid arithmetic — keeps the boundary exact even
        #: when repeated float addition drifts off the nominal period)
        self._next_control_ms = float("inf")
        self._next_flush_ms = float("inf")

        # failure detection / recovery plane: armed only when asked for —
        # a plain construction adds zero events and zero state changes
        if fault_plan is not None or checkpointer is not None:
            self.attach_recovery(checkpointer, heartbeat_timeout_ms)
        if fault_plan is not None:
            for f in fault_plan.ordered():
                self.schedule(f.at_ms, partial(self._inject_fault, f))

    # -- event machinery ---------------------------------------------------------
    def _push(self, at_ms: float, kind: int, a, b=None, c=None) -> None:
        """Push one slotted event record (hot path; no allocation beyond the
        record tuple itself).  The hottest sites inline this body — they all
        schedule at ``now + <nonnegative delta>``, so the backwards-time
        guard lives here, where ``schedule()``'s user callbacks enter (the
        run loop assigns event times to the clock directly and would
        otherwise rewind it silently)."""
        if at_ms < self.clock._now:
            raise ValueError(
                f"time went backwards: scheduling at {at_ms} < "
                f"{self.clock._now}")
        self._seq += 1
        self._push_rec((at_ms, self._seq, kind, a, b, c))

    def _alloc_cpu(self, w: int) -> int:
        """Register worker ``w``'s CPU columns (multi-server model: the
        paper's testbed ran eight tasks of four types per 8-core node —
        §4.2).  Unchained tasks each occupy a core for their service time; a
        chained series occupies ONE core for the summed service time (one
        thread, §3.5.2).  Ready work queues FIFO in ``cpu_ready`` when all
        cores are busy, which models the scheduling delay that task chaining
        removes.  Completions are slotted ``_EV_COMPLETE`` events; their
        dispatch frees the core, runs the completion, and drains the ready
        queue — no helper closures on the event queue."""
        ci = len(self.cpu_cores)
        self.cpus[w] = ci
        self.cpu_cores.append(self.cores_per_worker)
        self.cpu_busy.append(0)
        self.cpu_ready.append(deque())
        return ci

    def schedule(self, at_ms: float, fn: Callable[[], None]) -> None:
        """Back-compat generic event: run ``fn`` at ``at_ms`` (tests and
        benchmarks inject scale/chain actions this way)."""
        self._push(at_ms, _EV_CALL, fn)
        _heappush(self._call_times, at_ms)

    def _batch_boundary(self, now: float) -> float:
        """First instant after ``now`` at which an observer outside a batch
        can run: the next control tick, the next stale-flush sweep, or the
        earliest injected ``schedule()`` callback — capped by the batch
        horizon and the run cutoff.  The tick/sweep instants are the
        ACTUALLY scheduled event times (tracked when each reschedules
        itself), so the boundary stays exact even where repeated float
        addition drifts off the nominal period.  Batched runs and source
        chunks never compute effects past the boundary (a crossing
        item/emission falls back to a real heap event), so every
        control-plane decision point samples buffers, counters and
        measurement aggregates at the same logical instant as the exact
        core."""
        b = now + self.batch_horizon_ms
        if self._next_control_ms < b:
            b = self._next_control_ms
        if self._next_flush_ms < b:
            b = self._next_flush_ms
        calls = self._call_times
        if calls and calls[0] < b:
            b = calls[0]
        if self._run_until < b:
            b = self._run_until
        return b

    def record_sink_latency(self, lat_ms: float, now: float) -> None:
        self.sink_latencies.append(lat_ms)
        b = int(now // self.latency_bucket_ms)
        s, c = self.latency_timeline.get(b, (0.0, 0))
        self.latency_timeline[b] = (s + lat_ms, c + 1)

    def record_sink_latency_batch(self, lats_ms: list[float],
                                  times_ms: list[float]) -> None:
        """Timestamp-array ingestion for a batched run's sink arrivals —
        element-wise identical to ``record_sink_latency`` per item."""
        self.sink_latencies.extend(lats_ms)
        bucket = self.latency_bucket_ms
        timeline = self.latency_timeline
        for lat, now in zip(lats_ms, times_ms):
            b = int(now // bucket)
            s, c = timeline.get(b, (0.0, 0))
            timeline[b] = (s + lat, c + 1)

    # -- QoS control events ---------------------------------------------------------
    def _cpu_utilization(self, v: RuntimeVertex, window_ms: float) -> float:
        ti = self.tasks[v].ti
        util = self.t_busy_w[ti] / max(window_ms, 1e-9)
        self.t_busy_w[ti] = 0.0
        return min(util, 1.0)

    def _control_tick(self) -> None:
        tick = self.interval_ms / 4.0
        now = self.clock.now()
        self._next_control_ms = now + tick
        for v in list(self.rg.vertices):
            if v.id in self.measured_tasks:
                t = self.tasks[v]
                # .get: a crashed worker's reporter died with it, but its
                # tasks stay in rg until recovery re-homes them
                rep = self.reporters.get(self.rg.worker(v))
                if rep is not None:
                    rep.record_task_cpu(
                        v.id, self._cpu_utilization(v, tick),
                        t.chained_into is not None
                        or t.chain_next is not None,
                    )
        managers = self.managers
        for rep in self.reporters.values():
            for mgr_id, report in rep.maybe_flush():
                mgr = managers.get(mgr_id)
                if mgr is not None:
                    mgr.receive_report(report)
        # failure detection + recovery + periodic checkpoint run on the
        # control cadence (no-ops unless attach_recovery armed them)
        if self._monitor is not None:
            self._liveness_tick(now)
        self._maybe_checkpoint(now)
        # predictive QoS: feed the rate estimators on the control tick.
        # Strictly guarded by proactive: with None (the golden-pinned
        # default) the tick adds no bookkeeping, events, or RNG draws.
        if self.proactive is not None:
            self._estimator_tick(now)
        if self.enable_qos:
            # snapshot: a routed ScaleRequest rebuilds self.managers live
            for mgr in list(self.managers.values()):
                for action in mgr.check():
                    self._route_action(action)
        if self._slo_pending_since is not None:
            self._slo_recovery_check(now)
        self._push(self._next_control_ms, _EV_CONTROL, None)

    def _flush_stale_tick(self) -> None:
        """Max-buffer-lifetime sweep (§3.5.1 companion, same contract as the
        engine's control-loop sweep): ship under-filled buffers that have
        been open longer than ``max_buffer_lifetime_ms``."""
        now = self.clock.now()
        lifetime = self.max_buffer_lifetime_ms
        self._next_flush_ms = now + lifetime / 2.0
        arena = self.arena
        if arena is None:
            for ch in list(self.channels.values()):
                buf = ch.buffer
                if (buf.items and buf.opened_at_ms is not None
                        and now - buf.opened_at_ms >= lifetime):
                    ch.flush(now)
        else:
            items_col = arena.items
            opened_col = arena.opened
            for ch in list(self.channels.values()):
                chi = ch.chi
                opened = opened_col[chi]
                if (items_col[chi] and opened is not None
                        and now - opened >= lifetime):
                    ch.flush(now)
        self._push(self._next_flush_ms, _EV_FLUSH, None)

    # -- fault injection (core/faults.py) -------------------------------------
    def _count_drop(self, key, n: int = 1) -> None:
        d = self.dropped_by_key
        d[key] = d.get(key, 0) + n

    def _inject_fault(self, fault) -> None:
        """Dispatch one scheduled fault at its injection instant (an
        ``_EV_CALL`` event, so ordering against regular traffic is exact)."""
        from .faults import (
            ChannelBlackhole, DelaySpike, KillOwnerOf, KillWorker)

        now = self.clock.now()
        plan = self.fault_plan
        if isinstance(fault, KillWorker):
            w = fault.worker
            if w is None:
                live = [x for x in self.rg.pool.worker_ids()
                        if x not in self._crashed_workers]
                w = plan.pick_worker(live)
            if w is not None and w not in self._crashed_workers:
                self._crash_worker(w, now)
        elif isinstance(fault, KillOwnerOf):
            group = self.rg.tasks_of(fault.job_vertex)
            target = next((v for v in group if v.index == fault.index),
                          group[-1] if group else None)
            if target is not None:
                w = self.rg.worker(target)
                if w not in self._crashed_workers:
                    plan.record(now, "kill_owner_of",
                                f"{target.id} on worker {w}")
                    self._crash_worker(w, now)
        elif isinstance(fault, ChannelBlackhole):
            until = now + fault.duration_ms
            n = 0
            for sc in self.channels.values():
                c = sc.channel
                if (c.src.job_vertex == fault.src_vertex
                        and c.dst.job_vertex == fault.dst_vertex):
                    sc.blackhole_until = until
                    n += 1
            plan.record(now, "blackhole",
                        f"{fault.src_vertex}->{fault.dst_vertex} "
                        f"({n} channels, {fault.duration_ms:g}ms)")
        elif isinstance(fault, DelaySpike):
            factor = fault.factor
            spiked = [self.tasks[v]
                      for v in self.rg.tasks_of(fault.job_vertex)
                      if v in self.tasks]
            for t in spiked:
                t.svc_ms *= factor
            plan.record(now, "delay_spike",
                        f"{fault.job_vertex} x{factor:g} "
                        f"for {fault.duration_ms:g}ms")

            def _relax() -> None:
                for t in spiked:
                    if not t.crashed:
                        t.svc_ms /= factor

            self.schedule(now + fault.duration_ms, _relax)

    def _crash_worker(self, w: int, now: float) -> None:
        """Kill worker ``w`` the way a process crash would: every resident
        task stops mid-flight, its queue, in-service items and un-shipped
        output buffers are lost (counted per key in ``dropped_by_key``),
        and the worker stops heartbeating — detection and recovery follow
        through the control ticks (``_liveness_tick``)."""
        if self.fault_plan is not None:
            self.fault_plan.record(now, "kill_worker", f"worker {w}")
        self.note_crash(w, now)
        acct = self._fault_acct
        for v in list(self.rg.vertices_on_worker(w)):
            t = self.tasks.get(v)
            if t is None or t.crashed:
                continue
            t.crashed = True
            t.retired = True
            if acct:
                for it in t.queue:
                    self._count_drop(it.key)
            t.queue.clear()
            # un-shipped output buffers die with the process
            for chans in t.out_by_jv.values():
                for sc in chans:
                    buf = sc.buffer
                    if buf is not None and buf.items:
                        if _sanitize.SANITIZE:
                            _sanitize.CHECKER.note_crashed(buf)
                        lost, _, _ = buf.take(now)
                        if acct:
                            for it in lost:
                                self._count_drop(it.key)
        # ready-but-unstarted work queued on the dead worker's cores is
        # gone too (cpu_busy self-corrects: each pending completion event
        # still decrements it, then drops its item at the crashed guard)
        ci = self.cpus.get(w)
        if ci is not None:
            ready = self.cpu_ready[ci]
            if acct:
                for _svc, _t2, it2, _st in ready:
                    self._count_drop(it2.key)
            ready.clear()
        # the worker's QoS reporter dies with it: no more samples/reports
        self.reporters.pop(w, None)

    def _route_action(self, action: Action) -> None:
        if isinstance(action, BufferSizeUpdate):
            ch = self.channels.get(action.channel_id)
            if ch is not None:
                ch.try_update_size(
                    action.new_size_bytes, action.base_version
                )
        elif isinstance(action, ChainRequest):
            if self.enable_chaining:
                self._apply_chain(action)
        elif isinstance(action, ScaleRequest):
            try:
                if action.to_parallelism < action.from_parallelism:
                    # proactive give-back: the manager's forecast path may
                    # request a shrink; reactive requests only ever grow
                    self.scale_in(action.job_vertex, action.to_parallelism,
                                  reason=action.reason)
                else:
                    self.scale_out(action.job_vertex,
                                   action.to_parallelism,
                                   reason=action.reason)
            except (ValueError, DrainTimeout):
                # vertex not scalable or a retiring task failed to drain:
                # inapplicable countermeasure, never fatal to the simulation
                pass
        elif isinstance(action, GiveUp):
            self.give_ups.append(action)

    def _apply_chain(self, req: ChainRequest) -> None:
        tasks = [self.tasks[v] for v in req.tasks]
        if any(t.chained_into is not None or t.chain_next is not None for t in tasks):
            return
        # chaining is only legal for co-located tasks (§3.5.2 condition 1):
        # re-check against the live placement, mirroring the threaded engine
        workers = {self.rg.worker(v) for v in req.tasks}
        if len(workers) != 1:
            self.drain_failures.append(
                f"apply_chain({[v.id for v in req.tasks]}): tasks span "
                f"workers {sorted(workers)}; chain refused")
            return
        # §3.5.2 drain: in the event model queued items of downstream tasks are
        # simply processed before any new item reaches them via the chain (new
        # items enter at the head); re-wiring is atomic at this event time.
        for a, b in zip(req.tasks, req.tasks[1:]):
            for c in self.rg.out_channels(a):
                if c.dst == b:
                    sc = self.channels[c.id]
                    sc.flush()
                    sc.chained = True
                    self.chained_channels[c.id] = True
            self.tasks[a].chain_next = b
            self.tasks[b].chained_into = req.tasks[0]
        self.chained_groups.append(tuple(v.id for v in req.tasks))
        # live-chain registry: scale_in consults this to unchain a retiring
        # member (head included) before retiring it
        self.active_chains.append(tuple(req.tasks))

    def _dissolve_chain(self, chain) -> bool:
        """Reverse of _apply_chain (unchaining, for scale-in): clear the
        chain pointers and revert the fused channels to buffered transport.
        Atomic at this event time; items already in service finish under the
        chain's summed service time, new arrivals run per-task."""
        for a, b in zip(chain, chain[1:]):
            for c in self.rg.out_channels(a):
                if c.dst == b:
                    self.chained_channels.pop(c.id, None)
                    sc = self.channels.get(c.id)
                    if sc is not None:
                        sc.chained = False
            ta, tb = self.tasks.get(a), self.tasks.get(b)
            if ta is not None:
                ta.chain_next = None
            if tb is not None:
                tb.chained_into = None
        for v in chain:
            t = self.tasks.get(v)
            if t is not None:
                t._try_start()  # queued items resume under per-task service
        return True

    def _add_worker(self, w: int) -> None:
        # pool acquired a worker mid-run: per-worker CPU columns + reporter
        self._alloc_cpu(w)
        self.reporters[w] = QoSReporter(
            w, self.clock, self.interval_ms,
            rng=random.Random(self.seed * 7919 + w))

    # -- elastic re-wiring hooks (RuntimeRewirer; core/elastic.py, paper §6) ------
    def _spawn_task(self, v: RuntimeVertex) -> None:
        self.tasks[v] = _SimTask(v, self)

    def _open_channel(self, c) -> None:
        sc = _SimChannel(c, self, self.initial_buffer_bytes)
        self.channels[c.id] = sc
        src_task = self.tasks[c.src]
        lst = list(src_task.out_by_jv.get(c.dst.job_vertex, ()))
        lst.append(sc)
        lst.sort(key=lambda s2: s2.channel.dst.index)
        src_task.out_by_jv[c.dst.job_vertex] = lst
        src_task._rebuild_out()

    def _unroute_channel(self, c) -> None:
        src_task = self.tasks.get(c.src)
        sc = self.channels.get(c.id)
        if src_task is not None and sc is not None:
            src_task.out_by_jv[c.dst.job_vertex] = [
                x for x in src_task.out_by_jv.get(c.dst.job_vertex, ())
                if x is not sc
            ]
            src_task._rebuild_out()
        if sc is not None:
            sc.flush()  # ship what the closed channel still buffers
        self.channels.pop(c.id, None)

    def _drain_tasks(self, vs) -> bool:
        # event model: retiring tasks hand their queues to surviving
        # siblings at retire time; nothing to wait on
        return True

    def _task_state(self, v: RuntimeVertex) -> StateStore | None:
        t = self.tasks.get(v)
        return None if t is None else t.state

    def _reroute_queued(self, vs) -> None:
        # after a routing-table commit: items of moved key ranges still
        # queued at their old owners are re-homed in the same event (the
        # enqueue-side ownership check covers in-flight deliveries)
        for v in vs:
            t = self.tasks.get(v)
            if t is None or not t.stateful:
                continue
            router = self.rg.routers[v.job_vertex]
            pending = list(t.queue)
            t.queue.clear()
            keep: list[SimItem] = []
            for it in pending:
                owner = router.owner(it.key)
                if owner != v.index:
                    target = self.tasks.get(RuntimeVertex(v.job_vertex, owner))
                    if target is not None and not target.retired:
                        target.enqueue([it], "rebalance")
                        continue
                keep.append(it)
            t.queue.extend(keep)
            t._try_start()

    def _retire_task(self, v: RuntimeVertex) -> None:
        t = self.tasks.get(v)
        if t is None:
            return
        t.retired = True
        group = self.rg.tasks_of(v.job_vertex)
        if not group:
            return
        router = self.rg.routers[v.job_vertex]
        items = list(t.queue)
        t.queue.clear()
        for it in items:
            owner = min(router.owner(it.key), len(group) - 1)
            self.tasks[group[owner]].enqueue([it], "rebalance")

    def _flush_task_outputs(self, v: RuntimeVertex) -> None:
        t = self.tasks.get(v)
        if t is None:
            return
        for chans in list(t.out_by_jv.values()):
            for sc in list(chans):
                sc.flush()
                self.channels.pop(sc.cid, None)

    def _task_is_chained(self, v: RuntimeVertex) -> bool:
        t = self.tasks.get(v)
        return t is not None and (
            t.chained_into is not None or t.chain_next is not None)

    def _task_emitted(self, v: RuntimeVertex) -> int:
        t = self.tasks.get(v)
        return 0 if t is None else t.emitted

    def _task_busy_ms(self, v: RuntimeVertex) -> float:
        t = self.tasks.get(v)
        return 0.0 if t is None else self.t_busy_t[t.ti]

    def _schedule_elastic(self, st: dict, period_ms: float) -> None:
        def tick() -> None:
            self.elastic_check(st)
            self.schedule(self.clock.now() + period_ms, tick)

        self.schedule(self.clock.now() + period_ms, tick)

    def apply_scale_out(self, job_vertex: str, new_parallelism: int) -> None:
        """Back-compat alias for the shared re-wiring path."""
        self.scale_out(job_vertex, new_parallelism, reason="manual")

    # -- crash-recovery hooks (RuntimeRewirer.recover_worker) -----------------
    def _repoint_in_channels(self, v: RuntimeVertex) -> None:
        # senders keep their _SimChannel objects across a crash; only the
        # cached destination (and its co-location bit) must be re-aimed at
        # the respawned execution
        new_task = self.tasks[v]
        for c in self.rg.in_channels(v):
            sc = self.channels.get(c.id)
            if sc is not None:
                sc.dst_task = new_task
                sc.cross_worker = (
                    self.rg.worker(c.src) != self.rg.worker(c.dst))

    def _crash_dissolve_chain(self, chain) -> None:
        # the event-model dissolve is safe against dead members: it only
        # clears pointers/flags and (harmlessly) pokes empty queues
        self._dissolve_chain(chain)

    def _source_offsets(self) -> dict:
        return {(t.vertex.job_vertex, t.vertex.index): self.src_seq[si]
                for si, t in enumerate(self.src_task)}

    def _replay_sources(self, offsets, now: float) -> int:
        """Roll EVERY source back to its checkpointed offset (no snapshot →
        offset 0) and restart the emission chain of sources whose task died.
        Keys are a pure function of (source, seq), so the replay window
        [checkpoint_seq, crash_seq) re-produces the identical items."""
        replayed = 0
        acct = self._fault_acct
        for si in range(len(self.src_task)):
            task = self.src_task[si]
            v = task.vertex
            if task.crashed:
                nt = self.tasks.get(v)
                if nt is not None and not nt.crashed:
                    self.src_task[si] = nt
                    task = nt
            target = 0 if offsets is None else offsets.get(
                (v.job_vertex, v.index), 0)
            old = self.src_seq[si]
            if old > target:
                self.src_seq[si] = target
                replayed += old - target
                if acct:
                    kpt = self.src_kpt[si]
                    nk = self.src_keys[si]
                    idx = self.src_index[si]
                    r = self.replayed_by_key
                    for sq in range(target, old):
                        if kpt is not None:
                            key = idx * kpt + sq % kpt
                        else:
                            key = sq % nk if nk else sq
                        r[key] = r.get(key, 0) + 1
            if not self.src_live[si]:
                rf = self.src_rate_fn[si]
                period = (self.src_period[si] if rf is None
                          else 1e3 / max(rf(now), 1e-9))
                self._push(now + period, _EV_SOURCE, si)
                self.src_live[si] = True
        return replayed

    # -- sources ---------------------------------------------------------------------
    def _start_sources(self) -> None:
        for jv_name, spec in self.sources.items():
            for v in self.rg.tasks_of(jv_name):
                period = 1e3 / spec.rate_items_per_s
                offset = self.rng.uniform(0, period)
                si = len(self.src_task)
                self.src_task.append(self.tasks[v])
                self.src_seq.append(0)
                self.src_index.append(v.index)
                self.src_bytes.append(spec.item_bytes)
                self.src_keys.append(spec.keys)
                self.src_kpt.append(spec.keys_per_task)
                self.src_rate_fn.append(spec.rate_fn)
                # fixed-rate pacing precomputed (bit-identical to the
                # per-fire 1e3 / max(rate_at(now), 1e-9) when rate_fn is
                # None: rate_at then returns the constant rate)
                self.src_period.append(
                    1e3 / max(spec.rate_items_per_s, 1e-9))
                self.src_spec.append(spec)
                self.src_live.append(True)
                self._push(offset, _EV_SOURCE, si)

    def _fire_source(self, si: int, now: float) -> None:
        task = self.src_task[si]
        if task.crashed:
            # the pending emission chain dies with the task; recovery
            # re-points src_task and restarts the chain exactly once
            self.src_live[si] = False
            return
        seq = self.src_seq[si]
        kpt = self.src_kpt[si]
        if kpt is not None:
            key = self.src_index[si] * kpt + seq % kpt
        elif self.src_keys[si]:
            key = seq % self.src_keys[si]
        else:
            key = seq
        if self._fault_acct:
            e = self.emitted_by_key
            e[key] = e.get(key, 0) + 1
        item = SimItem(now, self.src_bytes[si], key)
        # a source "processes" the item (its cpu cost) then routes it
        svc, stages = task._chain_service(item)
        for t in stages:  # stateful chained stages count at start too
            if t.stateful:
                t.state.bump(item.key)
        self.t_busy_w[task.ti] += svc
        self._push(now + svc, _EV_SRC_EMIT, stages[-1], item)
        rf = self.src_rate_fn[si]
        period = (self.src_period[si] if rf is None
                  else 1e3 / max(rf(now), 1e-9))
        self.src_seq[si] = seq + 1
        self._push(now + period, _EV_SOURCE, si)

    def _fire_source_batched(self, si: int, now: float) -> None:
        """Batched sources: one ``_EV_SOURCE`` event emits a chunk of items
        at their exact analytic pacing instants (``rate_at`` is sampled at
        every per-item emission time, so bursty ``rate_fn`` pacing matches
        the exact core item for item).  Chunks never compute emission
        effects past the batch boundary — an emission that would cross it
        goes back to a real ``_EV_SRC_EMIT`` event, like the exact core's.
        Boundary-safe emissions toward a single consumer group are grouped
        per resolved channel and shipped through the batch-aware buffer
        path (``_SimChannel.send_run``)."""
        spec = self.src_spec[si]
        task = self.src_task[si]
        # fan-gated chains: the exact core evaluates a fan-in gate at
        # EMISSION time — after any bumps by items fired in between —
        # while a chunk would evaluate it at creation time.  A source
        # whose chain contains ANY fan_in != 1 stage therefore emits
        # strictly per item through the exact path (gate timing is then
        # identical by construction; such chains are rare — gates normally
        # sit behind non-source stages, e.g. the media job's Merger)
        stage = task
        while True:
            if stage.fan_in != 1:
                self._fire_source(si, now)
                return
            if stage.chain_next is None:
                break
            stage = self.tasks[stage.chain_next]
        limit = self._run_until
        boundary = self._batch_boundary(now)
        keys_per_task = spec.keys_per_task
        nkeys = spec.keys
        index = self.src_index[si]
        seq = self.src_seq[si]
        t = now
        # (channel -> (items, times)) per-chunk runs; per-channel emission
        # order is the exact core's (analytic times are increasing)
        runs: dict = {}
        while True:
            if keys_per_task is not None:
                key = index * keys_per_task + seq % keys_per_task
            elif nkeys:
                key = seq % nkeys
            else:
                key = seq
            item = SimItem(t, spec.item_bytes, key)
            svc, stages = task._chain_service(item)
            for s in stages:  # stateful chained stages count at start too
                if s.stateful:
                    s.state.bump(item.key)
            self.t_busy_w[task.ti] += svc
            emit_at = t + svc
            last = stages[-1]
            if emit_at >= boundary:
                # crossing emission: route it through the exact core's own
                # emit event so it orders correctly around the boundary
                # observer (dropped there if past the run cutoff), and end
                # the chunk — its fan-in gate must not see later bumps
                self._seq += 1
                self._push_rec((emit_at, self._seq, _EV_SRC_EMIT,
                                last, item, None))
                seq += 1
                period = 1e3 / max(spec.rate_at(t), 1e-9)
                t += period
                break
            if last._fan_count % last.fan_in == 0 and not last.is_sink:
                out = SimItem(item.created_at_ms, last.out_bytes, item.key)
                groups = last.out_groups
                # same masked-table lookup route() inlines; route()'s
                # retired-sender flush branch is irrelevant here — source
                # vertices are never scalable, so never retired
                if len(groups) == 1:
                    router, chans = groups[0]
                    if len(chans) == 1:
                        ch = chans[0]
                    else:
                        mask = router.mask
                        idx = (router.table[out.key & mask]
                               if mask is not None
                               and isinstance(out.key, int)
                               else router.owner(out.key))
                        if idx >= len(chans):
                            idx = len(chans) - 1
                        ch = chans[idx]
                    if ch.chained:
                        last.route(out, emit_at)
                    else:
                        run = runs.get(ch)
                        if run is None:
                            run = runs[ch] = ([], [])
                        run[0].append(out)
                        run[1].append(emit_at)
                else:
                    last.route(out, emit_at)
            seq += 1
            period = 1e3 / max(spec.rate_at(t), 1e-9)
            t += period
            if t >= boundary or t > limit:
                break
        self.src_seq[si] = seq
        for ch, (items, times) in runs.items():
            ch.send_run(items, times)
        self._seq += 1
        self._push_rec((t, self._seq, _EV_SOURCE, si, None, None))

    # -- run ---------------------------------------------------------------------------
    def run(self, duration_ms: float, max_events: int | None = None) -> "SimResult":
        self._run_until = duration_ms
        self._start_sources()
        self._next_control_ms = self.interval_ms / 4.0
        self._push(self._next_control_ms, _EV_CONTROL, None)
        if self.max_buffer_lifetime_ms is not None:
            self._next_flush_ms = self.max_buffer_lifetime_ms / 2.0
            self._push(self._next_flush_ms, _EV_FLUSH, None)
        max_ev = max_events if max_events is not None else (1 << 62)
        if (self.arena is not None and not self.batched
                and type(self._eq) is CalendarEventQueue):
            n_events = self._run_fast(duration_ms, max_ev)
        else:
            n_events = self._run_reference(duration_ms, max_ev)
        history = list(self._manager_history_archive)
        for mgr in self.managers.values():
            history.extend(mgr.history)
        timeline = {
            b: s / c for b, (s, c) in sorted(self.latency_timeline.items())
        }
        return SimResult(
            duration_ms=duration_ms,
            events=n_events,
            sink_latencies_ms=self.sink_latencies,
            sink_count_by_key=dict(self.sink_count_by_key),
            latency_timeline=timeline,
            final_buffer_sizes={
                cid: ch.capacity_bytes() for cid, ch in self.channels.items()
            },
            chained_groups=self.chained_groups,
            give_ups=self.give_ups,
            manager_history=history,
            total_bytes=self.total_bytes,
            total_buffers=self.total_buffers,
            scale_log=list(self.scale_log),
            drain_failures=list(self.drain_failures),
            unchain_log=list(self.unchain_log),
            pool_events=list(self.rg.pool.events),
            preflight_diagnostics=list(self.preflight_diagnostics),
            time_to_detect_ms=self.time_to_detect_ms,
            time_to_recover_ms=self.time_to_recover_ms,
            time_to_slo_recovery_ms=self.time_to_slo_recovery_ms,
            recovery_events=list(self.recovery_log),
            fault_log=(list(self.fault_plan.log)
                       if self.fault_plan is not None else []),
            emitted_by_key=dict(self.emitted_by_key),
            dropped_by_key=dict(self.dropped_by_key),
            replayed_by_key=dict(self.replayed_by_key),
        )

    def _run_reference(self, duration_ms: float, max_ev: int) -> int:
        """Reference dispatch loop: one method call per event effect.  Used
        by the heap scheduler (whose heap list is popped directly at C
        speed), batched mode, and instrumented runs; the semantics every
        inlined fast-path claim is verified against."""
        n_events = 0
        eq = self._eq
        push = self._push_rec
        clock = self.clock
        batched = self.batched
        cpu_cores = self.cpu_cores
        cpu_busy = self.cpu_busy
        cpu_ready = self.cpu_ready
        heap = eq.data if type(eq) is HeapEventQueue else None
        pop = _heappop
        eq_pop = eq.pop
        while True:
            if heap is not None:
                if not heap:
                    break
                rec = pop(heap)
            else:
                rec = eq_pop()
                if rec is None:
                    break
            t, _, kind, a, b, c = rec
            if t > duration_ms:
                break
            # pops are time-ordered; assign directly (advance_to's
            # monotonicity check is a per-event cost the order guarantees)
            clock._now = t
            if kind == _EV_COMPLETE:
                # free the core, run the completion (which starts the task's
                # next item), then drain the CPU ready queue — one dispatch,
                # no helper events
                ci = a.cpu_i
                cpu_busy[ci] -= 1
                a._complete(b, c, t)
                ready = cpu_ready[ci]
                while ready and cpu_busy[ci] < cpu_cores[ci]:
                    svc, t2, it2, st2 = ready.popleft()
                    cpu_busy[ci] += 1
                    self._seq += 1
                    push((t + svc, self._seq, _EV_COMPLETE, t2, it2, st2))
            elif kind == _EV_BATCH:
                # batched completion: retire the task's queued run in this
                # one event; a continued run re-claims the core until its
                # next scheduled event (_EV_BDONE / crossing _EV_BATCH)
                ci = a.cpu_i
                cpu_busy[ci] -= 1
                if a._complete_batch(b, c, t):
                    cpu_busy[ci] += 1
                else:
                    ready = cpu_ready[ci]
                    while ready and cpu_busy[ci] < cpu_cores[ci]:
                        svc, t2, it2, st2 = ready.popleft()
                        cpu_busy[ci] += 1
                        self._seq += 1
                        push((t + svc, self._seq, _EV_BATCH, t2, it2, st2))
            elif kind == _EV_BDONE:
                ci = a.cpu_i
                cpu_busy[ci] -= 1
                self.t_busy[a.ti] = False
                a._try_start(t)
                ready = cpu_ready[ci]
                while ready and cpu_busy[ci] < cpu_cores[ci]:
                    svc, t2, it2, st2 = ready.popleft()
                    cpu_busy[ci] += 1
                    self._seq += 1
                    push((t + svc, self._seq, _EV_BATCH, t2, it2, st2))
            elif kind == _EV_SHIP:
                a.enqueue(b, c, t)
            elif kind == _EV_SRC_EMIT:
                if a.crashed:
                    # the source's in-service item was lost with the crash
                    if self._fault_acct:
                        self._count_drop(b.key)
                elif a._fan_count % a.fan_in == 0:
                    out = SimItem(b.created_at_ms, a.out_bytes, b.key)
                    a.route(out, t)
            elif kind == _EV_SOURCE:
                if batched:
                    self._fire_source_batched(a, t)
                else:
                    self._fire_source(a, t)
            elif kind == _EV_CALL:
                _heappop(self._call_times)
                a()
            elif kind == _EV_CONTROL:
                self._control_tick()
            else:  # _EV_FLUSH
                self._flush_stale_tick()
            n_events += 1
            if n_events >= max_ev:
                break
        return n_events

    def _run_fast(self, duration_ms: float, max_ev: int) -> int:
        """Inlined dispatch for the exact core on the calendar queue with
        arena-backed channels (uninstrumented runs only — ``run`` picks the
        reference loop otherwise).

        Replays the reference loop's per-event effects with the same float
        operations in the same order and the same seq allocation, but with
        the hot handlers (COMPLETE / SRC_EMIT / SOURCE / SHIP) and the
        queue's bucket fast path expanded inline over the flat columns.
        Anything off the hot path — chained or fan-gated tasks, retired
        senders, multi-group routing, control-plane events — escapes to the
        exact reference method with the queue state synced around the call:

        * before an escape: ``eq.ci``/``eq.ring_count`` (a push during the
          escape insorts into the serving bucket at ``lo=eq.ci``) and
          ``self._seq`` are stored back;
        * after: ``ring_count``/``seq`` are re-read (pushes may have
          happened), plus the measured sets after control-plane escapes (a
          QoS-scope refresh rebuilds them as new objects).  ``eq.cur`` and
          the ring/spill structures are identity-stable across pushes —
          only ``eq.pop`` (called at bucket boundaries, where it may
          retune) replaces them, and escapes never pop.
        """
        eq = self._eq
        n_events = 0
        clock = self.clock
        # calendar-queue serving state, maintained in locals
        cur = eq.cur
        ci = eq.ci
        cur_b = eq.cur_b
        ring_count = eq.ring_count
        ring = eq.ring
        mask = eq.mask
        nb = eq.nb
        inv_w = eq.inv_w
        spill = eq.spill
        eq_pop = eq.pop
        seq = self._seq
        # flat state columns (identity-stable lists: construction/refresh
        # appends in place, never reassigns)
        t_busy = self.t_busy
        t_busy_w = self.t_busy_w
        t_busy_t = self.t_busy_t
        cpu_cores = self.cpu_cores
        cpu_busy = self.cpu_busy
        cpu_ready = self.cpu_ready
        arena = self.arena
        ar_items = arena.items
        ar_used = arena.used
        ar_open = arena.opened
        ar_cap = arena.cap
        ar_ver = arena.ver
        src_task = self.src_task
        src_seq = self.src_seq
        src_index = self.src_index
        src_bytes = self.src_bytes
        src_keys = self.src_keys
        src_kpt = self.src_kpt
        src_rate_fn = self.src_rate_fn
        src_period = self.src_period
        # rebuilt as new sets on QoS-scope refresh: re-read after escapes
        # that can trigger one (control ticks, injected callbacks)
        measured_tasks = self.measured_tasks
        measured_channels = self.measured_channels
        sink_counts = self.sink_count_by_key
        sink_lats = self.sink_latencies
        timeline = self.latency_timeline
        bucket_ms = self.latency_bucket_ms
        net = self.net
        net_over = net.per_buffer_overhead_ms
        net_bw = net.bandwidth_bytes_per_ms
        net_prop = net.propagation_ms
        net_same = net.same_worker_overhead_ms
        call_times = self._call_times
        new = object.__new__
        max_t = _MAX_T
        interval = self.interval_ms
        # the clock is only stored before escapes into reference code (and
        # once after the loop): every inlined effect threads ``t``
        # explicitly, so the per-event attribute store is pure overhead
        tprev = clock._now
        while True:
            # ---- CalendarEventQueue.pop, fast path inline
            if ci < len(cur):
                rec = cur[ci]
                ci += 1
                ring_count -= 1
            else:
                # bucket exhausted: advance (and maybe retune) via the
                # queue's own method — rare (~1/TARGET_OCCUPANCY pops)
                eq.ci = ci
                eq.ring_count = ring_count
                eq.pops = n_events
                rec = eq_pop()
                if rec is None:
                    break
                cur = eq.cur
                ci = eq.ci
                cur_b = eq.cur_b
                ring_count = eq.ring_count
                ring = eq.ring
                mask = eq.mask
                nb = eq.nb
                inv_w = eq.inv_w
                spill = eq.spill
            t, _, kind, a, b, c = rec
            if t > duration_ms:
                break
            # ---- dispatch, hottest kinds first
            if kind == _EV_COMPLETE:
                stages = c
                cj = a.cpu_i
                nbusy = cpu_busy[cj] - 1
                # written back immediately: the completion below can route
                # into a chained enqueue whose sibling start touches the
                # SAME cpu column
                cpu_busy[cj] = nbusy
                if len(stages) == 1 and a.fan_in == 1 and not a.retired:
                    # inline a._complete(...) for the plain unchained case
                    t_busy[a.ti] = False
                    # _finish_item (fan gate passes: fan_in == 1)
                    pend = a._pending_task_sample
                    if pend is not None:
                        vid = a.vid
                        if vid in measured_tasks:
                            d3 = a.reporter._task_lat
                            s3, c3 = d3.get(vid, _T0)
                            d3[vid] = (s3 + (t - pend), c3 + 1)
                        a._pending_task_sample = None
                    a.emitted += 1
                    item = b
                    if a.is_sink:
                        key = item.key
                        sink_counts[key] = sink_counts.get(key, 0) + 1
                        lat = t - item.created_at_ms
                        sink_lats.append(lat)
                        bk = int(t // bucket_ms)
                        s_, c_ = timeline.get(bk, _T0)
                        timeline[bk] = (s_ + lat, c_ + 1)
                    else:
                        out = new(SimItem)
                        out.created_at_ms = item.created_at_ms
                        out.size_bytes = a.out_bytes
                        out.key = item.key
                        out.tag = None
                        out.emitted_at_ms = 0.0
                        # ---- a.route(out, t) inline (single consumer
                        # group, live sender)
                        groups = a.out_groups
                        if len(groups) == 1 and not a.retired:
                            router, chans = groups[0]
                            if len(chans) == 1:
                                ch = chans[0]
                            else:
                                mk = router.mask
                                k = out.key
                                if mk is not None and isinstance(k, int):
                                    idx = router.table[k & mk]
                                else:
                                    idx = router.owner(k)
                                nch = len(chans)
                                if idx >= nch:
                                    idx = nch - 1
                                ch = chans[idx]
                            if ch.chained:
                                eq.ci = ci
                                eq.ring_count = ring_count
                                self._seq = seq
                                a.route(out, t)
                                ring_count = eq.ring_count
                                seq = self._seq
                            else:
                                # ---- ch.send(out, t) inline on the arena
                                out.emitted_at_ms = t
                                cid = ch.cid
                                if cid in measured_channels:
                                    # should_tag inline: one tag per
                                    # channel per interval (§3.3)
                                    lt = ch.src_reporter._last_tagged
                                    last = lt.get(cid)
                                    if last is None or t - last >= interval:
                                        lt[cid] = t
                                        out.tag = Tag(cid, t)
                                chj = ch.chi
                                if ar_open[chj] is None:
                                    ar_open[chj] = t
                                ar_items[chj].append(out)
                                u = ar_used[chj] + out.size_bytes
                                ar_used[chj] = u
                                if u >= ar_cap[chj]:
                                    # ---- ch.flush(t) inline
                                    items2 = ar_items[chj]
                                    opened = ar_open[chj]
                                    lifetime = (0.0 if opened is None
                                                else t - opened)
                                    ar_items[chj] = []
                                    ar_used[chj] = 0
                                    ar_open[chj] = None
                                    if cid in measured_channels:
                                        rep = ch.src_reporter
                                        d3 = rep._chan_oblt
                                        s3, c3 = d3.get(cid, _T0)
                                        d3[cid] = (s3 + lifetime, c3 + 1)
                                        rep._chan_buf[cid] = (
                                            ar_cap[chj], ar_ver[chj])
                                    if ch.cross_worker:
                                        delay = (net_over + u / net_bw
                                                 + net_prop)
                                    else:
                                        delay = net_same
                                    self.total_bytes += u
                                    self.total_buffers += 1
                                    seq += 1
                                    tt = t + delay
                                    rec2 = (tt, seq, _EV_SHIP,
                                            ch.dst_task, items2, cid)
                                    if tt < max_t:
                                        bq = int(tt * inv_w)
                                        db = bq - cur_b
                                        if 0 < db < nb:
                                            ring[bq & mask].append(rec2)
                                            ring_count += 1
                                        elif db <= 0:
                                            insort(cur, rec2, ci)
                                            ring_count += 1
                                        else:
                                            _heappush(spill, rec2)
                                    else:
                                        _heappush(spill, rec2)
                        else:
                            eq.ci = ci
                            eq.ring_count = ring_count
                            self._seq = seq
                            a.route(out, t)
                            ring_count = eq.ring_count
                            seq = self._seq
                    # ---- a._try_start(t) inline
                    q = a.queue
                    aj = a.ti
                    if q and not t_busy[aj] and not a.halted:
                        if a.chain_next is None and a.fan_in == 1:
                            it2 = q.popleft()
                            tg = it2.tag
                            if tg is not None:
                                # record_channel_latency inline
                                d3 = a.reporter._chan_lat
                                cd = tg.channel_id
                                s3, c3 = d3.get(cd, _T0)
                                d3[cd] = (
                                    s3 + (t - tg.created_at_ms), c3 + 1)
                                it2.tag = None
                            vid = a.vid
                            if (a._pending_task_sample is None
                                    and vid in measured_tasks):
                                # should_sample_task inline (mutating
                                # decision, gated exactly like reference)
                                d3 = a.reporter._last_task_sample
                                last = d3.get(vid)
                                if last is None or t - last >= interval:
                                    d3[vid] = t
                                    a._pending_task_sample = t
                            a._fan_count += 1
                            svc = a.svc_ms
                            if a.stateful:
                                d2 = a.state._data
                                k2 = it2.key
                                d2[k2] = d2.get(k2, 0) + 1
                            t_busy[aj] = True
                            t_busy_w[aj] += svc
                            t_busy_t[aj] += svc
                            ck = a.cpu_i
                            nb2 = cpu_busy[ck]
                            if nb2 < cpu_cores[ck]:
                                cpu_busy[ck] = nb2 + 1
                                seq += 1
                                tt = t + svc
                                rec2 = (tt, seq, _EV_COMPLETE,
                                        a, it2, [a])
                                if tt < max_t:
                                    bq = int(tt * inv_w)
                                    db = bq - cur_b
                                    if 0 < db < nb:
                                        ring[bq & mask].append(rec2)
                                        ring_count += 1
                                    elif db <= 0:
                                        insort(cur, rec2, ci)
                                        ring_count += 1
                                    else:
                                        _heappush(spill, rec2)
                                else:
                                    _heappush(spill, rec2)
                            else:
                                cpu_ready[ck].append((svc, a, it2, [a]))
                        else:
                            eq.ci = ci
                            eq.ring_count = ring_count
                            self._seq = seq
                            a._try_start(t)
                            ring_count = eq.ring_count
                            seq = self._seq
                else:
                    # chained / fan-gated / retired: reference completion
                    eq.ci = ci
                    eq.ring_count = ring_count
                    self._seq = seq
                    a._complete(rec[4], stages, t)
                    ring_count = eq.ring_count
                    seq = self._seq
                # ---- ready-queue drain (re-read: the completion above may
                # have claimed or freed cores on this cpu)
                ready = cpu_ready[cj]
                if ready:
                    nbusy = cpu_busy[cj]
                    cores = cpu_cores[cj]
                    while ready and nbusy < cores:
                        svc2, t2, it2, st2 = ready.popleft()
                        nbusy += 1
                        seq += 1
                        tt = t + svc2
                        rec2 = (tt, seq, _EV_COMPLETE, t2, it2, st2)
                        if tt < max_t:
                            bq = int(tt * inv_w)
                            db = bq - cur_b
                            if 0 < db < nb:
                                ring[bq & mask].append(rec2)
                                ring_count += 1
                            elif db <= 0:
                                insort(cur, rec2, ci)
                                ring_count += 1
                            else:
                                _heappush(spill, rec2)
                        else:
                            _heappush(spill, rec2)
                    cpu_busy[cj] = nbusy
            elif kind == _EV_SRC_EMIT:
                a = rec[3]
                fi = a.fan_in
                if fi == 1 or a._fan_count % fi == 0:
                    b = rec[4]
                    out = new(SimItem)
                    out.created_at_ms = b.created_at_ms
                    out.size_bytes = a.out_bytes
                    out.key = b.key
                    out.tag = None
                    out.emitted_at_ms = 0.0
                    # ---- a.route(out, t) inline (sources with no outputs
                    # or multiple consumer groups take the fallback)
                    groups = a.out_groups
                    if len(groups) == 1 and not a.retired:
                        router, chans = groups[0]
                        if len(chans) == 1:
                            ch = chans[0]
                        else:
                            mk = router.mask
                            k = out.key
                            if mk is not None and isinstance(k, int):
                                idx = router.table[k & mk]
                            else:
                                idx = router.owner(k)
                            nch = len(chans)
                            if idx >= nch:
                                idx = nch - 1
                            ch = chans[idx]
                        if ch.chained:
                            eq.ci = ci
                            eq.ring_count = ring_count
                            self._seq = seq
                            a.route(out, t)
                            ring_count = eq.ring_count
                            seq = self._seq
                        else:
                            out.emitted_at_ms = t
                            cid = ch.cid
                            if cid in measured_channels:
                                # should_tag inline: one tag per channel
                                # per interval (§3.3)
                                lt = ch.src_reporter._last_tagged
                                last = lt.get(cid)
                                if last is None or t - last >= interval:
                                    lt[cid] = t
                                    out.tag = Tag(cid, t)
                            chj = ch.chi
                            if ar_open[chj] is None:
                                ar_open[chj] = t
                            ar_items[chj].append(out)
                            u = ar_used[chj] + out.size_bytes
                            ar_used[chj] = u
                            if u >= ar_cap[chj]:
                                items2 = ar_items[chj]
                                opened = ar_open[chj]
                                lifetime = (0.0 if opened is None
                                            else t - opened)
                                ar_items[chj] = []
                                ar_used[chj] = 0
                                ar_open[chj] = None
                                if cid in measured_channels:
                                    rep = ch.src_reporter
                                    d3 = rep._chan_oblt
                                    s3, c3 = d3.get(cid, _T0)
                                    d3[cid] = (s3 + lifetime, c3 + 1)
                                    rep._chan_buf[cid] = (
                                        ar_cap[chj], ar_ver[chj])
                                if ch.cross_worker:
                                    delay = (net_over + u / net_bw
                                             + net_prop)
                                else:
                                    delay = net_same
                                self.total_bytes += u
                                self.total_buffers += 1
                                seq += 1
                                tt = t + delay
                                rec2 = (tt, seq, _EV_SHIP,
                                        ch.dst_task, items2, cid)
                                if tt < max_t:
                                    bq = int(tt * inv_w)
                                    db = bq - cur_b
                                    if 0 < db < nb:
                                        ring[bq & mask].append(rec2)
                                        ring_count += 1
                                    elif db <= 0:
                                        insort(cur, rec2, ci)
                                        ring_count += 1
                                    else:
                                        _heappush(spill, rec2)
                                else:
                                    _heappush(spill, rec2)
                    else:
                        eq.ci = ci
                        eq.ring_count = ring_count
                        self._seq = seq
                        a.route(out, t)
                        ring_count = eq.ring_count
                        seq = self._seq
            elif kind == _EV_SOURCE:
                si = rec[3]
                task = src_task[si]
                if task.chain_next is None and task.fan_in == 1:
                    # ---- _fire_source(si, t) inline (unchained source)
                    sq = src_seq[si]
                    kpt = src_kpt[si]
                    if kpt is not None:
                        key = src_index[si] * kpt + sq % kpt
                    else:
                        nk = src_keys[si]
                        key = sq % nk if nk else sq
                    item = new(SimItem)
                    item.created_at_ms = t
                    item.size_bytes = src_bytes[si]
                    item.key = key
                    item.tag = None
                    item.emitted_at_ms = 0.0
                    task._fan_count += 1
                    svc = task.svc_ms
                    if task.stateful:
                        d2 = task.state._data
                        d2[key] = d2.get(key, 0) + 1
                    t_busy_w[task.ti] += svc
                    seq += 1
                    tt = t + svc
                    rec2 = (tt, seq, _EV_SRC_EMIT, task, item, None)
                    if tt < max_t:
                        bq = int(tt * inv_w)
                        db = bq - cur_b
                        if 0 < db < nb:
                            ring[bq & mask].append(rec2)
                            ring_count += 1
                        elif db <= 0:
                            insort(cur, rec2, ci)
                            ring_count += 1
                        else:
                            _heappush(spill, rec2)
                    else:
                        _heappush(spill, rec2)
                    rf = src_rate_fn[si]
                    period = (src_period[si] if rf is None
                              else 1e3 / max(rf(t), 1e-9))
                    src_seq[si] = sq + 1
                    seq += 1
                    tt = t + period
                    rec2 = (tt, seq, _EV_SOURCE, si, None, None)
                    if tt < max_t:
                        bq = int(tt * inv_w)
                        db = bq - cur_b
                        if 0 < db < nb:
                            ring[bq & mask].append(rec2)
                            ring_count += 1
                        elif db <= 0:
                            insort(cur, rec2, ci)
                            ring_count += 1
                        else:
                            _heappush(spill, rec2)
                    else:
                        _heappush(spill, rec2)
                else:
                    eq.ci = ci
                    eq.ring_count = ring_count
                    self._seq = seq
                    self._fire_source(si, t)
                    ring_count = eq.ring_count
                    seq = self._seq
            elif kind == _EV_SHIP:
                a = rec[3]
                items = rec[4]
                start = False
                if not (a.retired or a.stateful):
                    a.queue.extend(items)
                    start = True
                elif a.stateful and not a.retired:
                    # inline key-ownership check: all-mine ships (the
                    # overwhelming majority) skip the re-home machinery
                    rt2 = a.router
                    mk = rt2.mask
                    all_mine = mk is not None
                    if all_mine:
                        tbl = rt2.table
                        ai = a.index
                        try:
                            for it3 in items:
                                if tbl[it3.key & mk] != ai:
                                    all_mine = False
                                    break
                        except TypeError:
                            all_mine = False
                    if all_mine:
                        a.queue.extend(items)
                        start = True
                    else:
                        eq.ci = ci
                        eq.ring_count = ring_count
                        self._seq = seq
                        a.enqueue(items, rec[5], t)
                        ring_count = eq.ring_count
                        seq = self._seq
                else:
                    eq.ci = ci
                    eq.ring_count = ring_count
                    self._seq = seq
                    a.enqueue(items, rec[5], t)
                    ring_count = eq.ring_count
                    seq = self._seq
                if start:
                    # ---- a._try_start(t) inline (busy/halted checked here)
                    q = a.queue
                    aj = a.ti
                    if q and not t_busy[aj] and not a.halted:
                        if a.chain_next is None and a.fan_in == 1:
                            it2 = q.popleft()
                            tg = it2.tag
                            if tg is not None:
                                # record_channel_latency inline
                                d3 = a.reporter._chan_lat
                                cd = tg.channel_id
                                s3, c3 = d3.get(cd, _T0)
                                d3[cd] = (
                                    s3 + (t - tg.created_at_ms), c3 + 1)
                                it2.tag = None
                            vid = a.vid
                            if (a._pending_task_sample is None
                                    and vid in measured_tasks):
                                # should_sample_task inline (mutating
                                # decision, gated exactly like reference)
                                d3 = a.reporter._last_task_sample
                                last = d3.get(vid)
                                if last is None or t - last >= interval:
                                    d3[vid] = t
                                    a._pending_task_sample = t
                            a._fan_count += 1
                            svc = a.svc_ms
                            if a.stateful:
                                d2 = a.state._data
                                k2 = it2.key
                                d2[k2] = d2.get(k2, 0) + 1
                            t_busy[aj] = True
                            t_busy_w[aj] += svc
                            t_busy_t[aj] += svc
                            ck = a.cpu_i
                            nb2 = cpu_busy[ck]
                            if nb2 < cpu_cores[ck]:
                                cpu_busy[ck] = nb2 + 1
                                seq += 1
                                tt = t + svc
                                rec2 = (tt, seq, _EV_COMPLETE,
                                        a, it2, [a])
                                if tt < max_t:
                                    bq = int(tt * inv_w)
                                    db = bq - cur_b
                                    if 0 < db < nb:
                                        ring[bq & mask].append(rec2)
                                        ring_count += 1
                                    elif db <= 0:
                                        insort(cur, rec2, ci)
                                        ring_count += 1
                                    else:
                                        _heappush(spill, rec2)
                                else:
                                    _heappush(spill, rec2)
                            else:
                                cpu_ready[ck].append((svc, a, it2, [a]))
                        else:
                            eq.ci = ci
                            eq.ring_count = ring_count
                            self._seq = seq
                            a._try_start(t)
                            ring_count = eq.ring_count
                            seq = self._seq
            elif kind == _EV_CALL:
                # injected callbacks read clock.now(): sync it first
                clock._now = t
                eq.ci = ci
                eq.ring_count = ring_count
                self._seq = seq
                _heappop(call_times)
                rec[3]()
                ring_count = eq.ring_count
                seq = self._seq
                measured_tasks = self.measured_tasks
                measured_channels = self.measured_channels
            elif kind == _EV_CONTROL:
                clock._now = t
                eq.ci = ci
                eq.ring_count = ring_count
                self._seq = seq
                self._control_tick()
                ring_count = eq.ring_count
                seq = self._seq
                measured_tasks = self.measured_tasks
                measured_channels = self.measured_channels
            else:  # _EV_FLUSH
                clock._now = t
                eq.ci = ci
                eq.ring_count = ring_count
                self._seq = seq
                self._flush_stale_tick()
                ring_count = eq.ring_count
                seq = self._seq
            tprev = t
            n_events += 1
            if n_events >= max_ev:
                break
        # leave the clock where the reference loop would: at the last
        # *dispatched* event's time (a past-horizon pop never assigns it)
        clock._now = tprev
        eq.ci = ci
        eq.ring_count = ring_count
        eq.pops = n_events
        self._seq = seq
        return n_events


@dataclass
class SimResult:
    duration_ms: float
    events: int
    sink_latencies_ms: list[float]
    latency_timeline: dict[int, float]  # bucket -> mean latency
    final_buffer_sizes: dict[str, int]
    chained_groups: list[tuple[str, ...]]
    give_ups: list[GiveUp]
    manager_history: list
    total_bytes: int
    total_buffers: int
    scale_log: list = field(default_factory=list)
    drain_failures: list = field(default_factory=list)
    #: chains dissolved live (unchain-before-retire): (task ids, reason)
    unchain_log: list = field(default_factory=list)
    #: worker-pool acquire/release audit (core/placement.py PoolEvent)
    pool_events: list = field(default_factory=list)
    #: sink arrivals per item key (per-stream accounting; cross-mode
    #: equivalence compares these between exact and batched runs)
    sink_count_by_key: dict = field(default_factory=dict)
    #: pre-flight WARN diagnostics (analysis/graph_check.py) carried onto
    #: the result so benchmark harnesses can surface them per row
    preflight_diagnostics: list = field(default_factory=list)
    #: crash-recovery metrics (None / empty on fault-free runs): crash ->
    #: dead-declaration, crash -> recovery-protocol-complete, and crash ->
    #: first control tick with every latency constraint satisfied again
    time_to_detect_ms: float | None = None
    time_to_recover_ms: float | None = None
    time_to_slo_recovery_ms: float | None = None
    #: completed recovery cycles (core/faults.py RecoveryEvent), in order
    recovery_events: list = field(default_factory=list)
    #: injected faults as they fired (core/faults.py FaultRecord)
    fault_log: list = field(default_factory=list)
    #: per-key conservation ledgers (maintained only under a fault plan):
    #: emitted counts every source fire (replays included), so exactly
    #: emitted[k] == sink_count_by_key[k] + dropped_by_key[k] once drained
    emitted_by_key: dict = field(default_factory=dict)
    dropped_by_key: dict = field(default_factory=dict)
    replayed_by_key: dict = field(default_factory=dict)

    def p95_latency_ms(self) -> float:
        """95th percentile of raw sink latencies (shared nearest-rank
        definition — core/measurement.py latency_percentile)."""
        return latency_percentile(self.sink_latencies_ms, 0.95)

    def mean_latency_ms(self, after_ms: float = 0.0) -> float:
        if not self.latency_timeline:
            return float("nan")
        b0 = int(after_ms // 1_000)
        vals = [v for b, v in self.latency_timeline.items() if b >= b0]
        if not vals:
            return float("nan")
        return sum(vals) / len(vals)

    def max_latency_ms(self, after_ms: float = 0.0) -> float:
        b0 = int(after_ms // 1_000)
        vals = [v for b, v in self.latency_timeline.items() if b >= b0]
        return max(vals) if vals else float("nan")

    @property
    def throughput_items_per_s(self) -> float:
        return len(self.sink_latencies_ms) / max(self.duration_ms / 1e3, 1e-9)


# -- runtime invariant sanitizer hook (analysis/sanitize.py) -----------------
# Zero-cost when disabled (the classes above keep their original bytecode);
# under REPRO_SANITIZE=1 the sim clock becomes a checked property (NS-S002),
# every control tick sweeps the channel-conservation ledgers (NS-S001), and
# chained hand-over channels are excluded from the delivered<=shipped check.
from ..analysis import race as _race  # noqa: E402
from ..analysis import sanitize as _sanitize  # noqa: E402

#: instrumented runs force the object-per-entity layout: channels keep real
#: OutputBuffer objects (the checkers wrap that class's methods) and the
#: dispatch stays on the reference loop so every wrapped method is actually
#: called.  Evaluated at construction time, so flag changes via env vars
#: are picked up per process like the other instrumentation hooks.
_INSTRUMENTED = _sanitize.SANITIZE or _race.RACE_CHECK

if _sanitize.SANITIZE:  # pragma: no cover - exercised via subprocess tests
    _sanitize.instrument_simulator(StreamSimulator, _SimTask, SimClock)
