"""Discrete-event simulator: the paper's control plane at paper scale.

Runs the *identical* QoS code (setup.py, measurement.py, manager.py,
buffers.py, chaining.py) on a simulated 200-node cluster — tasks are
single-server queues with configured per-item CPU cost, channels have
output buffers, serialization/transport overhead and bandwidth, exactly the
Fig. 1 processing pattern.  Used by benchmarks to reproduce Fig. 2 and the
Fig. 7/8/9 scenario suite at n=200, and by tests for deterministic QoS
behaviour checks.

Simplifications vs. the threaded engine (recorded here on purpose):
* CPython thread-scheduling noise is absent — latencies are deterministic,
* per-worker CPU contention is modeled per task only (a worker is assumed to
  have enough cores for its unchained tasks, like the paper's 8-core nodes).

Elastic re-parallelization (paper §6) goes through the SAME shared runtime
re-wiring layer as the threaded engine (core/elastic.py RuntimeRewirer):
``scale_out``/``scale_in`` mutate the running simulation — tasks join or
retire, channels re-wire per job-edge pattern, retiring tasks hand their
queues to surviving siblings (no item loss), and QoS manager/reporter
scopes are refreshed.  Attached ``ElasticController``s and the manager's
``ScaleRequest`` countermeasure drive the identical ``ScaleDecision`` path
on both backends.
"""
from __future__ import annotations

import heapq
import itertools
import random
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable

from .buffers import BufferSizingPolicy, OutputBuffer
from .chaining import ChainRequest
from .clock import SimClock
from .constraints import JobConstraint
from .elastic import RuntimeRewirer, ScaleRequest, split_constraints
from .graphs import JobGraph, RuntimeGraph, RuntimeVertex
from .manager import Action, BufferSizeUpdate, GiveUp, QoSManager
from .measurement import QoSReporter, Tag
from .placement import WorkerPool
from .routing import StateStore
from .setup import compute_qos_setup, compute_reporter_setup


@dataclass
class SimNetConfig:
    """1 GBit/s links, small fixed ship overhead per buffer (meta data, memory
    management, thread sync — §2.2.1), cheap same-worker hand-over."""

    bandwidth_bytes_per_ms: float = 125_000.0  # 1 Gbit/s
    per_buffer_overhead_ms: float = 0.10
    #: queue hand-over between threads on the same worker (wakeup, sync,
    #: scheduling under load) — what dynamic task chaining eliminates.
    same_worker_overhead_ms: float = 2.0
    propagation_ms: float = 0.15


@dataclass
class SimItem:
    created_at_ms: float
    size_bytes: int
    key: int
    tag: Tag | None = None
    emitted_at_ms: float = 0.0


@dataclass
class SimSourceSpec:
    rate_items_per_s: float
    item_bytes: int = 128
    #: global round-robin key space (stream-group ids); with
    #: ``keys_per_task`` set, source subtask p cycles only over its own keys
    #: [p*keys_per_task, (p+1)*keys_per_task) — the paper's Partitioner
    #: forwards each stream group to the one Decoder responsible for it.
    keys: int | None = None
    keys_per_task: int | None = None
    #: optional bursty pacing: elapsed_ms -> items/s (same contract as
    #: SourceSpec.rate_fn on the threaded engine)
    rate_fn: Callable[[float], float] | None = None

    def rate_at(self, elapsed_ms: float) -> float:
        if self.rate_fn is not None:
            return self.rate_fn(elapsed_ms)
        return self.rate_items_per_s


class _WorkerCPU:
    """Multi-server CPU model: one per worker node (the paper's testbed ran
    eight tasks of four types per 8-core node — §4.2).  Unchained tasks each
    occupy a core for their service time; a chained series occupies ONE core
    for the summed service time (one thread, §3.5.2).  Ready work queues
    FIFO when all cores are busy, which models the scheduling delay that
    task chaining removes."""

    __slots__ = ("sim", "cores", "busy", "ready")

    def __init__(self, sim: "StreamSimulator", cores: int) -> None:
        self.sim = sim
        self.cores = cores
        self.busy = 0
        self.ready: deque[tuple[float, Callable[[], None]]] = deque()

    def submit(self, svc_ms: float, done: Callable[[], None]) -> None:
        if self.busy < self.cores:
            self._start(svc_ms, done)
        else:
            self.ready.append((svc_ms, done))

    def _start(self, svc_ms: float, done: Callable[[], None]) -> None:
        self.busy += 1

        def fin() -> None:
            self.busy -= 1
            done()
            while self.ready and self.busy < self.cores:
                s, d = self.ready.popleft()
                self._start(s, d)

        self.sim.schedule(self.sim.clock.now() + svc_ms, fin)


class _SimChannel:
    """Sender-side output buffer + transport for one channel."""

    __slots__ = ("channel", "buffer", "sim", "cross_worker")

    def __init__(self, channel, sim: "StreamSimulator", capacity: int) -> None:
        self.channel = channel
        self.buffer = OutputBuffer(channel.id, capacity)
        self.sim = sim
        self.cross_worker = sim.rg.worker(channel.src) != sim.rg.worker(channel.dst)

    def send(self, item: SimItem) -> None:
        sim = self.sim
        now = sim.clock.now()
        item.emitted_at_ms = now
        rep = sim.reporters[sim.rg.worker(self.channel.src)]
        if self.channel.id in sim.measured_channels and rep.should_tag(self.channel.id):
            item.tag = Tag(self.channel.id, now)
        if self.buffer.append(item, item.size_bytes, now):
            self.flush()

    def flush(self) -> None:
        if self.buffer.empty:
            return
        sim = self.sim
        now = sim.clock.now()
        items, nbytes, lifetime = self.buffer.take(now)
        rep = sim.reporters[sim.rg.worker(self.channel.src)]
        if self.channel.id in sim.measured_channels:
            rep.record_output_buffer_lifetime(
                self.channel.id, lifetime, self.buffer.capacity_bytes,
                self.buffer.version,
            )
        net = sim.net
        if self.cross_worker:
            delay = (
                net.per_buffer_overhead_ms
                + nbytes / net.bandwidth_bytes_per_ms
                + net.propagation_ms
            )
        else:
            delay = net.same_worker_overhead_ms
        sim.total_bytes += nbytes
        sim.total_buffers += 1
        dst = self.channel.dst
        cid = self.channel.id
        sim.schedule(now + delay, lambda: sim.tasks[dst].enqueue(items, cid))


class _SimTask:
    """Single-server queue; when head of a chain, service covers the whole
    chain (§3.5.2 — one thread runs all chained tasks)."""

    def __init__(self, vertex: RuntimeVertex, sim: "StreamSimulator") -> None:
        self.vertex = vertex
        self.sim = sim
        jv = sim.jg.vertices[vertex.job_vertex]
        self.svc_ms = jv.sim_cpu_ms
        self.fan_in = max(jv.sim_fan_in, 1)
        self.out_bytes = jv.sim_item_bytes
        self.stateful = jv.stateful
        #: per-key state; for stateful vertices the simulator maintains a
        #: per-key processed-item count (its tasks are cost models without
        #: user code) and migration moves it along key ranges
        self.state = StateStore()
        self.is_sink = not sim.jg.out_edges(vertex.job_vertex)
        self.queue: deque[SimItem] = deque()
        self.busy = False
        self.halted = False
        self.retired = False           # elastically scaled in
        self.chained_into: RuntimeVertex | None = None  # member of a chain
        self.chain_next: RuntimeVertex | None = None    # next stage if chained
        self._fan_count = 0
        self._pending_task_sample: float | None = None
        self.busy_ms_window = 0.0
        self.emitted = 0          # lifetime emissions (elastic telemetry)
        self.busy_ms_total = 0.0
        # emission routing: dst job vertex -> channels sorted by dst index
        self.out_by_jv: dict[str, list] = {}
        self._inflight_since: float | None = None

    def enqueue(self, items: list[SimItem], channel_id: str) -> None:
        jv = self.vertex.job_vertex
        if self.retired:
            # straggler delivery after scale-in: hand each item to its key
            # range's surviving owner so nothing is lost and keyed state
            # stays with its one owner
            group = self.sim.rg.tasks_of(jv)
            if group:
                router = self.sim.rg.routers[jv]
                for it in items:
                    owner = router.owner(it.key)
                    target = self.sim.tasks.get(
                        group[min(owner, len(group) - 1)])
                    if target is None or target.retired:
                        # routing table and group transiently disagree: pick
                        # any survivor directly (never recurse into another
                        # retired task)
                        target = next(
                            (t for g in group
                             if (t := self.sim.tasks.get(g)) is not None
                             and not t.retired), None)
                    if target is not None:
                        target.enqueue([it], channel_id)
                return
        if self.stateful:
            # key-ownership enforcement: items whose range migrated away (or
            # that were in flight across a routing-table swap) are re-homed
            # to the range's owner — its state lives there
            router = self.sim.rg.routers[jv]
            mine: list[SimItem] = []
            for it in items:
                owner = router.owner(it.key)
                if owner != self.vertex.index:
                    target = self.sim.tasks.get(RuntimeVertex(jv, owner))
                    if target is not None and target is not self \
                            and not target.retired:
                        target.enqueue([it], channel_id)
                        continue
                mine.append(it)
            items = mine
            if not items:
                return
        self.queue.extend(items)
        self._try_start()

    def halt(self, halted: bool) -> None:
        self.halted = halted
        if not halted:
            self._try_start()

    def _try_start(self) -> None:
        if self.busy or self.halted or not self.queue:
            return
        sim = self.sim
        item = self.queue.popleft()
        now = sim.clock.now()
        # tag evaluated just before user code (§3.3) — includes queue wait
        if item.tag is not None:
            sim.reporters[sim.rg.worker(self.vertex)].record_channel_latency(
                item.tag.channel_id, now - item.tag.created_at_ms
            )
            item.tag = None
        vid = self.vertex.id
        rep = sim.reporters[sim.rg.worker(self.vertex)]
        if (
            self._pending_task_sample is None
            and vid in sim.measured_tasks
            and rep.should_sample_task(vid)
        ):
            self._pending_task_sample = now
        # total service time across the chain this item will traverse; the
        # whole chain runs on one core of this task's worker (§3.5.2)
        svc, stages = self._chain_service(item)
        # keyed aggregation happens at service START: a migration event
        # fired while this item is in service then snapshots a store that
        # already counts it (a completion-time bump would land in the old
        # owner's store AFTER its ranges were snapshotted away)
        for t in stages:
            if t.stateful:
                t.state.bump(item.key)
        self.busy = True
        self.busy_ms_window += svc
        self.busy_ms_total += svc
        sim.cpus[sim.rg.worker(self.vertex)].submit(
            svc, lambda: self._complete(item, stages)
        )

    def _chain_service(self, item: SimItem) -> tuple[float, list["_SimTask"]]:
        """Walk the chain from this task; figure out which stages run for this
        item (fan-in gates) and the summed service time."""
        stages: list[_SimTask] = []
        svc = 0.0
        t: _SimTask | None = self
        while t is not None:
            svc += t.svc_ms
            stages.append(t)
            t._fan_count += 1
            if t._fan_count % t.fan_in != 0:
                break  # item absorbed here (waiting for group completion)
            t = None if stages[-1].chain_next is None else self.sim.tasks[
                stages[-1].chain_next
            ]
        return svc, stages

    def _complete(self, item: SimItem, stages: list["_SimTask"]) -> None:
        sim = self.sim
        now = sim.clock.now()
        self.busy = False
        last = stages[-1]
        emitted = last._fan_count % last.fan_in == 0
        if emitted:
            if self._pending_task_sample is not None:
                vid = self.vertex.id
                if vid in sim.measured_tasks:
                    sim.reporters[sim.rg.worker(self.vertex)].record_task_latency(
                        vid, now - self._pending_task_sample
                    )
                self._pending_task_sample = None
            # task-latency samples for interior chained stages: service only
            for t in stages[1:]:
                vid = t.vertex.id
                if vid in sim.measured_tasks and sim.reporters[
                    sim.rg.worker(t.vertex)
                ].should_sample_task(vid):
                    sim.reporters[sim.rg.worker(t.vertex)].record_task_latency(
                        vid, t.svc_ms
                    )
            last.emitted += 1
            if last.is_sink:
                sim.record_sink_latency(now - item.created_at_ms, now)
            else:
                out = SimItem(item.created_at_ms, last.out_bytes, item.key)
                last.route(out)
        self._try_start()

    def route(self, item: SimItem) -> None:
        routers = self.sim.rg.routers
        for jv_name, chans in self.out_by_jv.items():
            if len(chans) == 1:
                ch = chans[0]
            else:
                # key-range routing via the consumer group's KeyRouter
                # (channels sorted by dst index; clamped while a rescale is
                # transiently re-wiring this sender)
                idx = min(routers[jv_name].owner(item.key), len(chans) - 1)
                ch = chans[idx]
            if self.sim.chained_channels.get(ch.channel.id, False):
                # direct hand-over: zero-cost, record ~0 channel latency sample
                sim = self.sim
                rep = sim.reporters[sim.rg.worker(ch.channel.src)]
                if ch.channel.id in sim.measured_channels and rep.should_tag(
                    ch.channel.id
                ):
                    rep2 = sim.reporters[sim.rg.worker(ch.channel.dst)]
                    rep2.record_channel_latency(ch.channel.id, 0.0)
                sim.tasks[ch.channel.dst].enqueue([item], ch.channel.id)
            else:
                ch.send(item)
                if self.retired:
                    # the channel was unlinked from the runtime graph; no
                    # later buffer-full event will flush it, so ship now
                    ch.flush()


class StreamSimulator(RuntimeRewirer):
    def __init__(
        self,
        jg: JobGraph,
        constraints: list,
        num_workers: int | None = None,
        sources: dict[str, SimSourceSpec] | None = None,
        initial_buffer_bytes: int = 32 * 1024,
        measurement_interval_ms: float = 1_000.0,
        enable_qos: bool = True,
        enable_chaining: bool = True,
        policy: BufferSizingPolicy | None = None,
        net: SimNetConfig | None = None,
        seed: int = 0,
        latency_bucket_ms: float = 1_000.0,
        cores_per_worker: int = 8,
        max_buffer_lifetime_ms: float | None = 5_000.0,
        pool: WorkerPool | None = None,
    ) -> None:
        self.jg = jg
        #: max output-buffer lifetime (§3.5.1 companion; same contract as
        #: StreamEngine): an under-filled buffer ships once it has been open
        #: this long, so low rates cannot strand items forever.  None
        #: disables (pure Fig. 2 buffer-size sweeps).
        self.max_buffer_lifetime_ms = max_buffer_lifetime_ms
        self.constraints, self.throughput_constraints = split_constraints(
            constraints)
        # worker placement: an explicit WorkerPool (elastic policies,
        # acquire/release) or a fixed modulo fleet of ``num_workers``
        self.rg = RuntimeGraph(jg, num_workers, pool=pool)
        self.clock = SimClock()
        self.net = net or SimNetConfig()
        self.enable_qos = enable_qos
        self.enable_chaining = enable_chaining
        self.interval_ms = measurement_interval_ms
        self.initial_buffer_bytes = initial_buffer_bytes
        self.policy = policy
        self.seed = seed
        self.rng = random.Random(seed)
        self.sources = sources or {}
        self.latency_bucket_ms = latency_bucket_ms
        self.cores_per_worker = cores_per_worker

        self.allocations = compute_qos_setup(jg, self.constraints, self.rg)
        self.reporter_setup = compute_reporter_setup(self.allocations, self.rg)
        self.reporters = {
            w: QoSReporter(w, self.clock, measurement_interval_ms,
                           rng=random.Random(seed * 7919 + w))
            for w in self.rg.worker_ids()
        }
        for w, routes in self.reporter_setup.task_routes.items():
            for mgr, tasks in routes.items():
                self.reporters[w].assign_manager(mgr, (), tasks)
        for w, routes in self.reporter_setup.channel_routes.items():
            for mgr, chans in routes.items():
                self.reporters[w].assign_manager(mgr, chans, ())
        self.managers = {
            w: QoSManager(alloc, self.rg, self.clock, policy=policy,
                          throughput_constraints=self.throughput_constraints)
            for w, alloc in self.allocations.items()
        }
        self.measured_channels: set[str] = set()
        self.measured_tasks: set[str] = set()
        for r in self.reporters.values():
            self.measured_channels |= r.interested_channels()
            self.measured_tasks |= r.interested_tasks()

        self.cpus: dict[int, _WorkerCPU] = {
            w: _WorkerCPU(self, cores_per_worker)
            for w in self.rg.worker_ids()
        }
        self.tasks: dict[RuntimeVertex, _SimTask] = {
            v: _SimTask(v, self) for v in self.rg.vertices
        }
        self.channels: dict[str, _SimChannel] = {}
        for c in self.rg.channels:
            sc = _SimChannel(c, self, initial_buffer_bytes)
            self.channels[c.id] = sc
            self.tasks[c.src].out_by_jv.setdefault(c.dst.job_vertex, []).append(sc)
        for t in self.tasks.values():  # deterministic routing order
            for jv_name in t.out_by_jv:
                t.out_by_jv[jv_name].sort(key=lambda sc: sc.channel.dst.index)

        self.chained_channels: dict[str, bool] = {}
        self.chained_groups: list[tuple[str, ...]] = []
        self.give_ups: list[GiveUp] = []
        self._init_rewirer()
        self.sink_latencies: list[float] = []
        self.latency_timeline: dict[int, tuple[float, int]] = {}
        self.total_bytes = 0
        self.total_buffers = 0

        self._heap: list[tuple[float, int, Callable[[], None]]] = []
        self._seq = itertools.count()

    # -- event machinery ---------------------------------------------------------
    def schedule(self, at_ms: float, fn: Callable[[], None]) -> None:
        heapq.heappush(self._heap, (at_ms, next(self._seq), fn))

    def record_sink_latency(self, lat_ms: float, now: float) -> None:
        self.sink_latencies.append(lat_ms)
        b = int(now // self.latency_bucket_ms)
        s, c = self.latency_timeline.get(b, (0.0, 0))
        self.latency_timeline[b] = (s + lat_ms, c + 1)

    # -- QoS control events ---------------------------------------------------------
    def _cpu_utilization(self, v: RuntimeVertex, window_ms: float) -> float:
        t = self.tasks[v]
        util = t.busy_ms_window / max(window_ms, 1e-9)
        t.busy_ms_window = 0.0
        return min(util, 1.0)

    def _control_tick(self) -> None:
        tick = self.interval_ms / 4.0
        for v in list(self.rg.vertices):
            if v.id in self.measured_tasks:
                t = self.tasks[v]
                self.reporters[self.rg.worker(v)].record_task_cpu(
                    v.id, self._cpu_utilization(v, tick),
                    t.chained_into is not None or t.chain_next is not None,
                )
        managers = self.managers
        for rep in self.reporters.values():
            for mgr_id, report in rep.maybe_flush():
                mgr = managers.get(mgr_id)
                if mgr is not None:
                    mgr.receive_report(report)
        if self.enable_qos:
            # snapshot: a routed ScaleRequest rebuilds self.managers live
            for mgr in list(self.managers.values()):
                for action in mgr.check():
                    self._route_action(action)
        self.schedule(self.clock.now() + tick, self._control_tick)

    def _flush_stale_tick(self) -> None:
        """Max-buffer-lifetime sweep (§3.5.1 companion, same contract as the
        engine's control-loop sweep): ship under-filled buffers that have
        been open longer than ``max_buffer_lifetime_ms``."""
        now = self.clock.now()
        lifetime = self.max_buffer_lifetime_ms
        for ch in list(self.channels.values()):
            buf = ch.buffer
            if (buf.items and buf.opened_at_ms is not None
                    and now - buf.opened_at_ms >= lifetime):
                ch.flush()
        self.schedule(now + lifetime / 2.0, self._flush_stale_tick)

    def _route_action(self, action: Action) -> None:
        if isinstance(action, BufferSizeUpdate):
            ch = self.channels.get(action.channel_id)
            if ch is not None:
                ch.buffer.try_update_size(
                    action.new_size_bytes, action.base_version
                )
        elif isinstance(action, ChainRequest):
            if self.enable_chaining:
                self._apply_chain(action)
        elif isinstance(action, ScaleRequest):
            try:
                self.scale_out(action.job_vertex, action.to_parallelism,
                               reason=action.reason)
            except ValueError:
                # vertex not scalable: inapplicable countermeasure, never
                # fatal to the simulation
                pass
        elif isinstance(action, GiveUp):
            self.give_ups.append(action)

    def _apply_chain(self, req: ChainRequest) -> None:
        tasks = [self.tasks[v] for v in req.tasks]
        if any(t.chained_into is not None or t.chain_next is not None for t in tasks):
            return
        # chaining is only legal for co-located tasks (§3.5.2 condition 1):
        # re-check against the live placement, mirroring the threaded engine
        workers = {self.rg.worker(v) for v in req.tasks}
        if len(workers) != 1:
            self.drain_failures.append(
                f"apply_chain({[v.id for v in req.tasks]}): tasks span "
                f"workers {sorted(workers)}; chain refused")
            return
        # §3.5.2 drain: in the event model queued items of downstream tasks are
        # simply processed before any new item reaches them via the chain (new
        # items enter at the head); re-wiring is atomic at this event time.
        for a, b in zip(req.tasks, req.tasks[1:]):
            for c in self.rg.out_channels(a):
                if c.dst == b:
                    self.channels[c.id].flush()
                    self.chained_channels[c.id] = True
            self.tasks[a].chain_next = b
            self.tasks[b].chained_into = req.tasks[0]
        self.chained_groups.append(tuple(v.id for v in req.tasks))
        # live-chain registry: scale_in consults this to unchain a retiring
        # member (head included) before retiring it
        self.active_chains.append(tuple(req.tasks))

    def _dissolve_chain(self, chain) -> bool:
        """Reverse of _apply_chain (unchaining, for scale-in): clear the
        chain pointers and revert the fused channels to buffered transport.
        Atomic at this event time; items already in service finish under the
        chain's summed service time, new arrivals run per-task."""
        for a, b in zip(chain, chain[1:]):
            for c in self.rg.out_channels(a):
                if c.dst == b:
                    self.chained_channels.pop(c.id, None)
            ta, tb = self.tasks.get(a), self.tasks.get(b)
            if ta is not None:
                ta.chain_next = None
            if tb is not None:
                tb.chained_into = None
        for v in chain:
            t = self.tasks.get(v)
            if t is not None:
                t._try_start()  # queued items resume under per-task service
        return True

    def _add_worker(self, w: int) -> None:
        # pool acquired a worker mid-run: per-worker CPU model + reporter
        self.cpus[w] = _WorkerCPU(self, self.cores_per_worker)
        self.reporters[w] = QoSReporter(
            w, self.clock, self.interval_ms,
            rng=random.Random(self.seed * 7919 + w))

    # -- elastic re-wiring hooks (RuntimeRewirer; core/elastic.py, paper §6) ------
    def _spawn_task(self, v: RuntimeVertex) -> None:
        self.tasks[v] = _SimTask(v, self)

    def _open_channel(self, c) -> None:
        sc = _SimChannel(c, self, self.initial_buffer_bytes)
        self.channels[c.id] = sc
        src_task = self.tasks[c.src]
        lst = list(src_task.out_by_jv.get(c.dst.job_vertex, ()))
        lst.append(sc)
        lst.sort(key=lambda s2: s2.channel.dst.index)
        src_task.out_by_jv[c.dst.job_vertex] = lst

    def _unroute_channel(self, c) -> None:
        src_task = self.tasks.get(c.src)
        sc = self.channels.get(c.id)
        if src_task is not None and sc is not None:
            src_task.out_by_jv[c.dst.job_vertex] = [
                x for x in src_task.out_by_jv.get(c.dst.job_vertex, ())
                if x is not sc
            ]
        if sc is not None:
            sc.flush()  # ship what the closed channel still buffers
        self.channels.pop(c.id, None)

    def _drain_tasks(self, vs) -> bool:
        # event model: retiring tasks hand their queues to surviving
        # siblings at retire time; nothing to wait on
        return True

    def _task_state(self, v: RuntimeVertex) -> StateStore | None:
        t = self.tasks.get(v)
        return None if t is None else t.state

    def _reroute_queued(self, vs) -> None:
        # after a routing-table commit: items of moved key ranges still
        # queued at their old owners are re-homed in the same event (the
        # enqueue-side ownership check covers in-flight deliveries)
        for v in vs:
            t = self.tasks.get(v)
            if t is None or not t.stateful:
                continue
            router = self.rg.routers[v.job_vertex]
            pending = list(t.queue)
            t.queue.clear()
            keep: list[SimItem] = []
            for it in pending:
                owner = router.owner(it.key)
                if owner != v.index:
                    target = self.tasks.get(RuntimeVertex(v.job_vertex, owner))
                    if target is not None and not target.retired:
                        target.enqueue([it], "rebalance")
                        continue
                keep.append(it)
            t.queue.extend(keep)
            t._try_start()

    def _retire_task(self, v: RuntimeVertex) -> None:
        t = self.tasks.get(v)
        if t is None:
            return
        t.retired = True
        group = self.rg.tasks_of(v.job_vertex)
        if not group:
            return
        router = self.rg.routers[v.job_vertex]
        items = list(t.queue)
        t.queue.clear()
        for it in items:
            owner = min(router.owner(it.key), len(group) - 1)
            self.tasks[group[owner]].enqueue([it], "rebalance")

    def _flush_task_outputs(self, v: RuntimeVertex) -> None:
        t = self.tasks.get(v)
        if t is None:
            return
        for chans in list(t.out_by_jv.values()):
            for sc in list(chans):
                sc.flush()
                self.channels.pop(sc.channel.id, None)

    def _task_is_chained(self, v: RuntimeVertex) -> bool:
        t = self.tasks.get(v)
        return t is not None and (
            t.chained_into is not None or t.chain_next is not None)

    def _task_emitted(self, v: RuntimeVertex) -> int:
        t = self.tasks.get(v)
        return 0 if t is None else t.emitted

    def _task_busy_ms(self, v: RuntimeVertex) -> float:
        t = self.tasks.get(v)
        return 0.0 if t is None else t.busy_ms_total

    def _schedule_elastic(self, st: dict, period_ms: float) -> None:
        def tick() -> None:
            self.elastic_check(st)
            self.schedule(self.clock.now() + period_ms, tick)

        self.schedule(self.clock.now() + period_ms, tick)

    def apply_scale_out(self, job_vertex: str, new_parallelism: int) -> None:
        """Back-compat alias for the shared re-wiring path."""
        self.scale_out(job_vertex, new_parallelism, reason="manual")

    # -- sources ---------------------------------------------------------------------
    def _start_sources(self) -> None:
        for jv_name, spec in self.sources.items():
            for v in self.rg.tasks_of(jv_name):
                period = 1e3 / spec.rate_items_per_s
                offset = self.rng.uniform(0, period)
                self.schedule(offset, self._make_source_event(v, spec, 0))

    def _make_source_event(self, v: RuntimeVertex, spec: SimSourceSpec, seq: int):
        def fire() -> None:
            now = self.clock.now()
            if spec.keys_per_task is not None:
                key = v.index * spec.keys_per_task + seq % spec.keys_per_task
            elif spec.keys:
                key = seq % spec.keys
            else:
                key = seq
            item = SimItem(now, spec.item_bytes, key)
            task = self.tasks[v]
            # a source "processes" the item (its cpu cost) then routes it
            svc, stages = task._chain_service(item)
            for t in stages:  # stateful chained stages count at start too
                if t.stateful:
                    t.state.bump(item.key)
            task.busy_ms_window += svc
            last = stages[-1]

            def done() -> None:
                if last._fan_count % last.fan_in == 0:
                    out = SimItem(item.created_at_ms, last.out_bytes, item.key)
                    last.route(out)

            self.schedule(now + svc, done)
            period = 1e3 / max(spec.rate_at(now), 1e-9)
            self.schedule(now + period, self._make_source_event(v, spec, seq + 1))

        return fire

    # -- run ---------------------------------------------------------------------------
    def run(self, duration_ms: float, max_events: int | None = None) -> "SimResult":
        self._start_sources()
        self.schedule(self.interval_ms / 4.0, self._control_tick)
        if self.max_buffer_lifetime_ms is not None:
            self.schedule(self.max_buffer_lifetime_ms / 2.0,
                          self._flush_stale_tick)
        n_events = 0
        while self._heap:
            t, _, fn = heapq.heappop(self._heap)
            if t > duration_ms:
                break
            self.clock.advance_to(t)
            fn()
            n_events += 1
            if max_events is not None and n_events >= max_events:
                break
        history = list(self._manager_history_archive)
        for mgr in self.managers.values():
            history.extend(mgr.history)
        timeline = {
            b: s / c for b, (s, c) in sorted(self.latency_timeline.items())
        }
        return SimResult(
            duration_ms=duration_ms,
            events=n_events,
            sink_latencies_ms=self.sink_latencies,
            latency_timeline=timeline,
            final_buffer_sizes={
                cid: ch.buffer.capacity_bytes for cid, ch in self.channels.items()
            },
            chained_groups=self.chained_groups,
            give_ups=self.give_ups,
            manager_history=history,
            total_bytes=self.total_bytes,
            total_buffers=self.total_buffers,
            scale_log=list(self.scale_log),
            drain_failures=list(self.drain_failures),
            unchain_log=list(self.unchain_log),
            pool_events=list(self.rg.pool.events),
        )


@dataclass
class SimResult:
    duration_ms: float
    events: int
    sink_latencies_ms: list[float]
    latency_timeline: dict[int, float]  # bucket -> mean latency
    final_buffer_sizes: dict[str, int]
    chained_groups: list[tuple[str, ...]]
    give_ups: list[GiveUp]
    manager_history: list
    total_bytes: int
    total_buffers: int
    scale_log: list = field(default_factory=list)
    drain_failures: list = field(default_factory=list)
    #: chains dissolved live (unchain-before-retire): (task ids, reason)
    unchain_log: list = field(default_factory=list)
    #: worker-pool acquire/release audit (core/placement.py PoolEvent)
    pool_events: list = field(default_factory=list)

    def mean_latency_ms(self, after_ms: float = 0.0) -> float:
        if not self.latency_timeline:
            return float("nan")
        b0 = int(after_ms // 1_000)
        vals = [v for b, v in self.latency_timeline.items() if b >= b0]
        if not vals:
            return float("nan")
        return sum(vals) / len(vals)

    def max_latency_ms(self, after_ms: float = 0.0) -> float:
        b0 = int(after_ms // 1_000)
        vals = [v for b, v in self.latency_timeline.items() if b >= b0]
        return max(vals) if vals else float("nan")

    @property
    def throughput_items_per_s(self) -> float:
        return len(self.sink_latencies_ms) / max(self.duration_ms / 1e3, 1e-9)
