"""Latency model and constraints (paper §3.2).

* task latency ``tl(d, v, in->out)``      — time inside user code (§3.2.1)
* channel latency ``cl(d, e)``            — exit of src user code -> entry of
                                            dst user code, incl. output-buffer
                                            residency + transport (§3.2.2)
* sequence latency ``sl(d, S)``           — recursive sum along a sequence of
                                            connected tasks/channels (§3.2.3)
* job constraint ``jc = (JS, l, t)``      — on the job graph (§3.2.4)
* runtime constraint ``c = (S, l, t)``    — Eq. (1): the arithmetic mean of
  ``sl`` over items entering S during any span of t time units must be <= l.

Job sequences are expressed over the *job graph*; each induces a (possibly
enormous: m^k) set of runtime sequences.  Runtime constraints are therefore
**never** materialized globally; QoS managers evaluate them lazily on their
subgraph (see manager.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, Iterator, Sequence

from .graphs import Channel, JobGraph, RuntimeGraph, RuntimeSubgraph, RuntimeVertex

# ---------------------------------------------------------------------------
# Job-level sequences & constraints
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class JobSequenceElement:
    """Either a job vertex (``kind='vertex'``) or a job edge (``kind='edge'``)."""

    kind: str  # 'vertex' | 'edge'
    vertex: str | None = None
    edge: tuple[str, str] | None = None

    @staticmethod
    def v(name: str) -> "JobSequenceElement":
        return JobSequenceElement("vertex", vertex=name)

    @staticmethod
    def e(src: str, dst: str) -> "JobSequenceElement":
        return JobSequenceElement("edge", edge=(src, dst))

    def __repr__(self) -> str:
        return self.vertex if self.kind == "vertex" else f"{self.edge[0]}->{self.edge[1]}"


@dataclass(frozen=True)
class JobSequence:
    """n-tuple of connected job vertices/edges; first/last may be either (§3.2.4)."""

    elements: tuple[JobSequenceElement, ...]

    def __post_init__(self) -> None:
        if not self.elements:
            raise ValueError("empty job sequence")
        for a, b in zip(self.elements, self.elements[1:]):
            if a.kind == b.kind:
                raise ValueError("sequence must alternate vertices and edges")
            if a.kind == "vertex" and b.edge[0] != a.vertex:
                raise ValueError(f"disconnected: {a} then {b}")
            if a.kind == "edge" and b.vertex != a.edge[1]:
                raise ValueError(f"disconnected: {a} then {b}")

    # -- helpers -------------------------------------------------------------
    @staticmethod
    def of(*names_or_edges) -> "JobSequence":
        """Build from strings (vertices) and (src, dst) tuples (edges)."""
        els = tuple(
            JobSequenceElement.v(x) if isinstance(x, str) else JobSequenceElement.e(*x)
            for x in names_or_edges
        )
        return JobSequence(els)

    @staticmethod
    def full_path(path: Sequence[str], include_endpoints: bool = False) -> "JobSequence":
        """Sequence covering a job-graph path.  With ``include_endpoints=False``
        the first/last elements are the edges (the paper's evaluation
        constrains ``(e_1, v_D, e_2, v_M, e_3, v_O, e_4, v_E, e_5)`` — tasks
        between the Partitioner and the RTP Server, with both boundary
        *channels* included but not the boundary tasks themselves)."""
        els: list[JobSequenceElement] = []
        for i, name in enumerate(path):
            if include_endpoints or 0 < i < len(path) - 1:
                els.append(JobSequenceElement.v(name))
            if i < len(path) - 1:
                els.append(JobSequenceElement.e(name, path[i + 1]))
        # Re-order: path walk gives v,e,v,e,...; when endpoints are excluded we
        # start with the first edge.
        seq = sorted(els, key=lambda el: _order_key(el, list(path)))
        return JobSequence(tuple(seq))

    def vertices(self) -> list[str]:
        return [el.vertex for el in self.elements if el.kind == "vertex"]

    def edges(self) -> list[tuple[str, str]]:
        return [el.edge for el in self.elements if el.kind == "edge"]

    def adjacent_task_pairs(self) -> list[tuple[str, str]]:
        """Consecutive *task* pairs along the sequence — the candidate
        §3.5.2 chain pairs.  Shared by the pre-flight chaining
        pre-computation (analysis/graph_check.py) and the static
        feasibility pass (analysis/feasibility.py) so both reason about
        the same pair set."""
        ts = self.vertices()
        return list(zip(ts, ts[1:]))

    def covered_path(self) -> tuple[str, ...]:
        """The job-vertex path spanned by this sequence, including endpoint
        vertices of boundary edges."""
        path: list[str] = []
        for el in self.elements:
            if el.kind == "vertex":
                if not path or path[-1] != el.vertex:
                    path.append(el.vertex)
            else:
                s, d = el.edge
                if not path or path[-1] != s:
                    path.append(s)
                path.append(d)
        return tuple(path)

    def __len__(self) -> int:
        return len(self.elements)

    def __repr__(self) -> str:
        return "JS(" + ", ".join(map(repr, self.elements)) + ")"


def _order_key(el: JobSequenceElement, path: list[str]) -> float:
    if el.kind == "vertex":
        return float(path.index(el.vertex))
    return path.index(el.edge[0]) + 0.5


@dataclass(frozen=True)
class JobConstraint:
    """``jc = (JS, l, t)``: upper latency limit ``l`` (ms) over any time span
    of ``t`` ms, for all runtime sequences induced by ``sequence`` (§3.2.4)."""

    sequence: JobSequence
    latency_limit_ms: float
    window_ms: float
    name: str = "constraint"

    def num_runtime_sequences(self, rg: RuntimeGraph) -> int:
        """|induced runtime sequences| — the paper's m^3 = 512e6 count for the
        media job at m=800.  Computed combinatorially, never materialized."""
        count = 0
        # product over job-edge multiplicities along each maximal run; a
        # sequence is one concrete channel per job edge and the implied
        # endpoint tasks.  For ALL_TO_ALL edges a path through k parallel
        # vertex groups of size m has m^k concrete instances.
        path = self.sequence.covered_path()
        total = 1
        for name in path:
            total *= rg.job_graph.vertices[name].parallelism
        # POINTWISE edges collapse the two adjacent factors into one.
        for (s, d) in self.sequence.edges():
            je = rg.job_graph.edge(s, d)
            if je.pattern == "pointwise":
                total //= rg.job_graph.vertices[d].parallelism
        return total


# ---------------------------------------------------------------------------
# Runtime-level sequences & constraints
# ---------------------------------------------------------------------------

RuntimeSequenceElement = RuntimeVertex | Channel


@dataclass(frozen=True)
class RuntimeSequence:
    """A concrete n-tuple of connected tasks and channels (§3.2.3)."""

    elements: tuple[RuntimeSequenceElement, ...]

    def vertices(self) -> list[RuntimeVertex]:
        return [el for el in self.elements if isinstance(el, RuntimeVertex)]

    def channels(self) -> list[Channel]:
        return [el for el in self.elements if isinstance(el, Channel)]

    def __len__(self) -> int:
        return len(self.elements)

    def __repr__(self) -> str:
        return "S(" + " ".join(e.id for e in self.elements) + ")"


@dataclass(frozen=True)
class RuntimeConstraint:
    """``c = (S, l, t)`` with Eq. (1) semantics."""

    sequence: RuntimeSequence
    latency_limit_ms: float
    window_ms: float
    job_constraint: JobConstraint | None = None


def sequence_latency(latencies: Sequence[float]) -> float:
    """``sl(d, S)`` for one item given per-element latencies — the recursive
    definition in §3.2.3 telescopes to a sum of element latencies."""
    return float(sum(latencies))


# ---------------------------------------------------------------------------
# Enumeration helpers (used by managers on their *small* subgraphs and by
# tests; never on the full runtime graph)
# ---------------------------------------------------------------------------


def enumerate_runtime_sequences(
    jc: JobConstraint,
    rg: RuntimeGraph,
    subgraph: RuntimeSubgraph | None = None,
    limit: int | None = None,
) -> Iterator[RuntimeSequence]:
    """Enumerate the runtime sequences of ``jc`` (optionally restricted to a
    manager subgraph).  DFS over concrete channels following the job sequence
    pattern.  ``limit`` guards accidental blow-up."""
    js = jc.sequence
    path = js.covered_path()
    starts_with_vertex = js.elements[0].kind == "vertex"
    ends_with_vertex = js.elements[-1].kind == "vertex"

    def vertex_ok(v: RuntimeVertex) -> bool:
        return subgraph is None or v in subgraph

    def chan_ok(c: Channel) -> bool:
        return subgraph is None or c in subgraph

    count = 0

    def emit(chain: list[RuntimeSequenceElement]) -> RuntimeSequence:
        els = list(chain)
        if not starts_with_vertex:
            els = els[1:]  # drop leading task (sequence starts at its out edge)
        if not ends_with_vertex:
            els = els[:-1]
        return RuntimeSequence(tuple(els))

    def dfs(pos: int, v: RuntimeVertex, chain: list[RuntimeSequenceElement]):
        nonlocal count
        if limit is not None and count >= limit:
            return
        if pos == len(path) - 1:
            count += 1
            yield emit(chain)
            return
        nxt = path[pos + 1]
        for c in rg.out_channels(v):
            if c.dst.job_vertex != nxt or not chan_ok(c) or not vertex_ok(c.dst):
                continue
            chain.append(c)
            chain.append(c.dst)
            yield from dfs(pos + 1, c.dst, chain)
            chain.pop()
            chain.pop()

    for v0 in rg.tasks_of(path[0]):
        if vertex_ok(v0):
            yield from dfs(0, v0, [v0])


def constraint_elements(
    jc: JobConstraint, rg: RuntimeGraph
) -> tuple[set[RuntimeVertex], set[Channel]]:
    """All runtime vertices/channels that participate in any sequence of
    ``jc`` — i.e. what must be *measured*.  Linear in graph size."""
    vs: set[RuntimeVertex] = set()
    cs: set[Channel] = set()
    for name in jc.sequence.vertices():
        vs.update(rg.tasks_of(name))
    for (s, d) in jc.sequence.edges():
        cs.update(rg.channels_of(s, d))
    return vs, cs
