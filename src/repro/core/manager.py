"""QoS Manager role (paper §3.4.1, §3.5).

A manager runs on a worker node, owns a runtime subgraph ``G_i`` plus the
constraint scopes assigned by the master (setup.py), ingests reports from its
QoS Reporters, and reacts to latency-constraint violations:

1. detect violations: per constraint, the estimate of Eq. (1)'s left side is
   the sum of per-element windowed running averages along a sequence (§3.3).
   Sequences are **never enumerated**; the worst owned sequence is found with
   a max-plus dynamic program over the layered subgraph (linear in |G_i|),
   anchored at the manager's owned anchor tasks,
2. countermeasures (§3.5): first adaptive output-buffer sizing on the worst
   sequence's channels (Eq. 2/3, first-writer-wins versioning), then dynamic
   task chaining (longest chainable series, co-location judged against the
   live worker placement — core/placement.py); after each adjustment the
   manager waits one constraint window so that stale measurements flush out,
3. elastic scale-out (§6, core/elastic.py) as the third countermeasure:
   when buffers and chaining are exhausted but a throughput-constrained
   stage on the violated path is saturated, the manager emits a
   ``ScaleRequest`` that the execution layer routes to the shared runtime
   re-wiring layer (``RuntimeRewirer``),
4. when preconditions for all countermeasures are exhausted and the
   constraint still stands violated, the failure is reported to the master
   (who notifies the user).
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Iterable

from .buffers import BufferSizingPolicy
from .chaining import ChainRequest, TaskRuntimeInfo, find_chain
from .clock import Clock
from .constraints import JobConstraint
from .elastic import ScaleRequest, ThroughputConstraint
from .estimation import ProactiveConfig, RateEstimator
from .graphs import Channel, RuntimeGraph, RuntimeVertex
from .measurement import QoSReport
from .setup import ConstraintScope, ManagerAllocation

NEG_INF = float("-inf")


# ---------------------------------------------------------------------------
# Actions emitted by the manager (routed by the execution layer)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class BufferSizeUpdate:
    channel_id: str
    src_worker: int
    new_size_bytes: int
    base_version: int


@dataclass(frozen=True)
class GiveUp:
    """Report a failed optimization attempt to the master (§3.5)."""

    constraint_name: str
    manager_worker: int
    estimate_ms: float


Action = BufferSizeUpdate | ChainRequest | ScaleRequest | GiveUp


# ---------------------------------------------------------------------------
# Windowed element store
# ---------------------------------------------------------------------------


class _Window:
    """(ts, value) ring with eviction at ``max_window_ms``; means over any
    window <= max.  Like measurement.RunningAverage, eviction also runs on
    ``add()`` so a store that keeps receiving reports but is rarely read
    stays bounded (evicted entries could never reach a later ``mean()``)."""

    __slots__ = ("max_window_ms", "items")

    def __init__(self, max_window_ms: float) -> None:
        self.max_window_ms = max_window_ms
        self.items: deque[tuple[float, float]] = deque()

    def add(self, ts: float, v: float) -> None:
        items = self.items
        lo = ts - self.max_window_ms
        while items and items[0][0] < lo:
            items.popleft()
        items.append((ts, v))

    def mean(self, now: float, window_ms: float) -> float | None:
        while self.items and self.items[0][0] < now - self.max_window_ms:
            self.items.popleft()
        lo = now - window_ms
        vals = [v for ts, v in self.items if ts >= lo]
        if not vals:
            return None
        return sum(vals) / len(vals)


# ---------------------------------------------------------------------------
# The manager
# ---------------------------------------------------------------------------


@dataclass
class ViolationRecord:
    constraint_name: str
    estimate_ms: float
    at_ms: float
    actions: tuple[Action, ...]


@dataclass
class ScopeAnalysis:
    """Result of one violation-detection DP pass over a manager subgraph."""

    worst_estimate_ms: float
    worst_elements: list  # RuntimeVertex | Channel along the worst sequence
    violated_channels: list  # Channel on >= 1 violated owned sequence
    #: per owned anchor task: (estimate, elements) of its worst sequence,
    #: sorted by estimate descending — chaining candidates beyond the worst.
    per_anchor: list[tuple[float, list]] = field(default_factory=list)


class QoSManager:
    def __init__(
        self,
        allocation: ManagerAllocation,
        rg: RuntimeGraph,
        clock: Clock,
        policy: BufferSizingPolicy | None = None,
        cpu_threshold: float = 0.90,
        chain_mode: str = "drain",
        throughput_constraints: Iterable[ThroughputConstraint] = (),
        scale_step: int = 2,
        scale_max_parallelism: int = 64,
        scale_util_threshold: float = 0.85,
        proactive: ProactiveConfig | None = None,
        estimators: dict[str, RateEstimator] | None = None,
    ) -> None:
        self.worker = allocation.worker
        self.allocation = allocation
        self.rg = rg
        self.clock = clock
        self.policy = policy or BufferSizingPolicy()
        self.cpu_threshold = cpu_threshold
        self.chain_mode = chain_mode
        self.throughput_constraints = tuple(throughput_constraints)
        self.scale_step = scale_step
        self.scale_max_parallelism = scale_max_parallelism
        self.scale_util_threshold = scale_util_threshold
        # predictive QoS (core/estimation.py): the execution layer owns the
        # estimator registry ("src:<jv>" / "stage:<jv>" -> RateEstimator)
        # and shares it with every manager; with proactive None or disabled
        # the forecast path never runs and decisions are bit-identical.
        self.proactive = proactive
        self.estimators: dict[str, RateEstimator] = (
            estimators if estimators is not None else {})
        #: consecutive low-forecast proactive checks per "constraint:stage"
        #: (scale-in give-back needs a sustained signal, not one quiet tick)
        self._low_forecast_ticks: dict[str, int] = {}
        #: scope index -> source job vertices feeding its path (reachability
        #: over the job graph, cached — the job graph never changes shape)
        self._scope_sources: dict[int, frozenset[str]] = {}

        max_window = max(
            (s.constraint.window_ms for s in allocation.scopes), default=15_000.0
        )
        self._max_window = max_window
        # element stores
        self._chan_lat: dict[str, _Window] = {}
        self._chan_oblt: dict[str, _Window] = {}
        self._chan_buf: dict[str, tuple[int, int]] = {}  # id -> (bytes, version)
        self._task_lat: dict[str, _Window] = {}
        self._task_cpu: dict[str, tuple[float, bool]] = {}  # id -> (util, chained)
        # control state
        self._scope_cooldown_until: dict[int, float] = {}
        self._gave_up: set[int] = set()
        # oscillation damping: once a channel's proposed update reverses
        # direction (shrink<->grow) it is considered settled for a while —
        # the iterative buffer adjustment (§3.5.1) has converged for it and
        # chaining may proceed (§3.5.2 "reduce latencies further").
        self._last_update_dir: dict[str, int] = {}
        self._settled_until: dict[str, float] = {}
        self.settle_windows: float = 4.0
        # subgraph adjacency indexed once
        self._out_idx: dict[RuntimeVertex, list[Channel]] = {}
        self._in_idx: dict[RuntimeVertex, list[Channel]] = {}
        for c in allocation.subgraph.channels:
            self._out_idx.setdefault(c.src, []).append(c)
            self._in_idx.setdefault(c.dst, []).append(c)
        self.history: list[ViolationRecord] = []

    # -- warm start across QoS-scope refreshes --------------------------------
    def adopt_state(self, old: "QoSManager") -> None:
        """Carry a predecessor manager's state across an elastic re-wiring
        (RuntimeRewirer._refresh_qos_scopes): element stores (measurement
        windows) for every channel/task that survived into this manager's
        subgraph, the §3.5.1 buffer bookkeeping, and per-constraint
        cooldowns (matched by constraint name, since scope indices shift).
        Elements that joined in the re-wiring have no entries and start
        cold; retired elements are filtered out."""
        chan_ids = {c.id for c in self.allocation.subgraph.channels}
        task_ids = {v.id for v in self.allocation.subgraph.vertices}
        for cid, w in old._chan_lat.items():
            if cid in chan_ids and cid not in self._chan_lat:
                self._chan_lat[cid] = w
        for cid, w in old._chan_oblt.items():
            if cid in chan_ids and cid not in self._chan_oblt:
                self._chan_oblt[cid] = w
        for cid, bv in old._chan_buf.items():
            if cid in chan_ids and cid not in self._chan_buf:
                self._chan_buf[cid] = bv
        for tid, w in old._task_lat.items():
            if tid in task_ids and tid not in self._task_lat:
                self._task_lat[tid] = w
        for tid, uc in old._task_cpu.items():
            if tid in task_ids and tid not in self._task_cpu:
                self._task_cpu[tid] = uc
        for cid, d in old._last_update_dir.items():
            if cid in chan_ids:
                self._last_update_dir.setdefault(cid, d)
        for cid, t in old._settled_until.items():
            if cid in chan_ids:
                self._settled_until[cid] = max(
                    self._settled_until.get(cid, 0.0), t)
        for key, n in old._low_forecast_ticks.items():
            self._low_forecast_ticks.setdefault(key, n)
        old_cooldowns = {
            old.allocation.scopes[i].constraint.name: t
            for i, t in old._scope_cooldown_until.items()
            if i < len(old.allocation.scopes)
        }
        for idx, scope in enumerate(self.allocation.scopes):
            t = old_cooldowns.get(scope.constraint.name)
            if t is not None:
                self._scope_cooldown_until[idx] = max(
                    self._scope_cooldown_until.get(idx, 0.0), t)

    # -- report ingestion -----------------------------------------------------
    def receive_report(self, report: QoSReport) -> None:
        now = report.sent_at_ms
        for cs in report.channel_stats:
            if cs.mean_latency_ms is not None:
                self._chan_lat.setdefault(cs.channel_id, _Window(self._max_window)).add(
                    now, cs.mean_latency_ms
                )
            if cs.mean_oblt_ms is not None:
                self._chan_oblt.setdefault(cs.channel_id, _Window(self._max_window)).add(
                    now, cs.mean_oblt_ms
                )
            if cs.buffer_size_bytes is not None:
                old = self._chan_buf.get(cs.channel_id)
                if old is None or cs.buffer_size_version >= old[1]:
                    self._chan_buf[cs.channel_id] = (
                        cs.buffer_size_bytes,
                        cs.buffer_size_version,
                    )
        for ts in report.task_stats:
            if ts.mean_latency_ms is not None:
                self._task_lat.setdefault(ts.vertex_id, _Window(self._max_window)).add(
                    now, ts.mean_latency_ms
                )
            self._task_cpu[ts.vertex_id] = (ts.cpu_utilization, ts.chained)

    # -- element estimates ------------------------------------------------------
    def channel_latency(self, c: Channel, window: float) -> float | None:
        w = self._chan_lat.get(c.id)
        return None if w is None else w.mean(self.clock.now(), window)

    def task_latency(self, v: RuntimeVertex, window: float) -> float | None:
        w = self._task_lat.get(v.id)
        return None if w is None else w.mean(self.clock.now(), window)

    def oblt(self, c: Channel, window: float) -> float | None:
        w = self._chan_oblt.get(c.id)
        return None if w is None else w.mean(self.clock.now(), window)

    # -- violation detection ------------------------------------------------------
    def analyze(self, scope: ConstraintScope) -> "ScopeAnalysis | None":
        """Max-plus DP over the layered subgraph (linear in |G_i|; runtime
        sequences are never enumerated).  Computes

        * the worst *owned* evaluable sequence (estimate + element list),
        * the set of channels lying on **any** violated owned sequence —
          buffer adjustment targets (§3.5: countermeasures are initiated for
          all violating sequences; a channel is adjusted at most once per
          cycle no matter how many violated sequences cross it).

        Owned = passing through ``scope.anchor_tasks`` (ownership rule from
        setup.py).  Returns None when nothing is evaluable yet (§4.3.2: the
        manager waits for measurement data).
        """
        jc = scope.constraint
        path = scope.path
        window = jc.window_ms
        limit = jc.latency_limit_ms
        measured_vertices = set(jc.sequence.vertices())
        layer_of = {name: i for i, name in enumerate(path)}
        anchor_layer = layer_of[scope.anchor_vertex]
        owned = set(scope.anchor_tasks)

        def vlat(v: RuntimeVertex) -> float | None:
            if v.job_vertex not in measured_vertices:
                return 0.0
            return self.task_latency(v, window)

        # F(v): max latency of a valid suffix starting *after* v (excludes
        # vlat(v)); B(v): max latency of a valid prefix ending *before* v.
        # F'(v)/B'(v): same, restricted to passing through an owned anchor.
        fwd_memo: dict[RuntimeVertex, tuple[float, Channel | None]] = {}
        bwd_memo: dict[RuntimeVertex, tuple[float, Channel | None]] = {}
        fwd_own_memo: dict[RuntimeVertex, float] = {}
        bwd_own_memo: dict[RuntimeVertex, float] = {}

        def fwd(v: RuntimeVertex) -> tuple[float, Channel | None]:
            if layer_of[v.job_vertex] == len(path) - 1:
                return 0.0, None
            if v in fwd_memo:
                return fwd_memo[v]
            best, arg = NEG_INF, None
            for c in self._out_idx.get(v, ()):  # restricted to subgraph
                cl = self.channel_latency(c, window)
                if cl is None:
                    continue
                wl = vlat(c.dst)
                if wl is None:
                    continue
                rest, _ = fwd(c.dst)
                if rest == NEG_INF:
                    continue
                tot = cl + wl + rest
                if tot > best:
                    best, arg = tot, c
            fwd_memo[v] = (best, arg)
            return best, arg

        def bwd(v: RuntimeVertex) -> tuple[float, Channel | None]:
            if layer_of[v.job_vertex] == 0:
                return 0.0, None
            if v in bwd_memo:
                return bwd_memo[v]
            best, arg = NEG_INF, None
            for c in self._in_idx.get(v, ()):
                cl = self.channel_latency(c, window)
                if cl is None:
                    continue
                ul = vlat(c.src)
                if ul is None:
                    continue
                rest, _ = bwd(c.src)
                if rest == NEG_INF:
                    continue
                tot = cl + ul + rest
                if tot > best:
                    best, arg = tot, c
            bwd_memo[v] = (best, arg)
            return best, arg

        def fwd_owned(v: RuntimeVertex) -> float:
            """Max suffix after v that passes through an owned anchor
            (only meaningful for layers <= anchor_layer)."""
            lay = layer_of[v.job_vertex]
            if lay == anchor_layer:
                return fwd(v)[0] if v in owned else NEG_INF
            if v in fwd_own_memo:
                return fwd_own_memo[v]
            best = NEG_INF
            for c in self._out_idx.get(v, ()):
                cl = self.channel_latency(c, window)
                if cl is None:
                    continue
                wl = vlat(c.dst)
                if wl is None:
                    continue
                rest = fwd_owned(c.dst)
                if rest == NEG_INF:
                    continue
                best = max(best, cl + wl + rest)
            fwd_own_memo[v] = best
            return best

        def bwd_owned(v: RuntimeVertex) -> float:
            lay = layer_of[v.job_vertex]
            if lay == anchor_layer:
                return bwd(v)[0] if v in owned else NEG_INF
            if v in bwd_own_memo:
                return bwd_own_memo[v]
            best = NEG_INF
            for c in self._in_idx.get(v, ()):
                cl = self.channel_latency(c, window)
                if cl is None:
                    continue
                ul = vlat(c.src)
                if ul is None:
                    continue
                rest = bwd_owned(c.src)
                if rest == NEG_INF:
                    continue
                best = max(best, cl + ul + rest)
            bwd_own_memo[v] = best
            return best

        # worst owned sequence, overall and per anchor task
        anchor_totals: list[tuple[float, RuntimeVertex]] = []
        best_total, best_anchor = NEG_INF, None
        for a in scope.anchor_tasks:
            al = vlat(a)
            if al is None:
                continue
            f, _ = fwd(a)
            b, _ = bwd(a)
            if f == NEG_INF or b == NEG_INF:
                continue
            tot = b + al + f
            anchor_totals.append((tot, a))
            if tot > best_total:
                best_total, best_anchor = tot, a
        if best_anchor is None:
            return None
        anchor_totals.sort(key=lambda x: -x[0])

        # channels on any violated owned sequence
        violated_channels: list[Channel] = []
        for c in self.allocation.subgraph.channels:
            cl = self.channel_latency(c, window)
            if cl is None:
                continue
            ul, wl = vlat(c.src), vlat(c.dst)
            if ul is None or wl is None:
                continue
            lay = layer_of.get(c.src.job_vertex)
            if lay is None or layer_of.get(c.dst.job_vertex) != lay + 1:
                continue
            if lay + 1 <= anchor_layer:
                b, f = bwd(c.src)[0], fwd_owned(c.dst)
            else:
                b, f = bwd_owned(c.src), fwd(c.dst)[0]
            if b == NEG_INF or f == NEG_INF:
                continue
            if b + ul + cl + wl + f > limit:
                violated_channels.append(c)

        # reconstruct worst path elements per anchor (channels + vertices)
        def reconstruct(anchor: RuntimeVertex) -> list[RuntimeVertex | Channel]:
            elements: list[RuntimeVertex | Channel] = []
            back: list[RuntimeVertex | Channel] = []
            v = anchor
            while True:
                _, c = bwd(v)
                if c is None:
                    break
                back.append(c)
                if c.src.job_vertex in measured_vertices:
                    back.append(c.src)
                v = c.src
            elements.extend(reversed(back))
            if anchor.job_vertex in measured_vertices:
                elements.append(anchor)
            v = anchor
            while True:
                _, c = fwd(v)
                if c is None:
                    break
                elements.append(c)
                if c.dst.job_vertex in measured_vertices:
                    elements.append(c.dst)
                v = c.dst
            return elements

        per_anchor = [(tot, reconstruct(a)) for tot, a in anchor_totals]
        return ScopeAnalysis(
            best_total, per_anchor[0][1], violated_channels, per_anchor
        )

    # kept for tests/back-compat: (estimate, elements) of the worst sequence
    def worst_sequence(
        self, scope: ConstraintScope
    ) -> tuple[float, list[RuntimeVertex | Channel]] | None:
        res = self.analyze(scope)
        if res is None:
            return None
        return res.worst_estimate_ms, res.worst_elements

    def defer_until(self, until_ms: float) -> None:
        """Hold all countermeasure cycles until ``until_ms`` (used by the
        re-wiring layer so a freshly scoped manager waits one constraint
        window before acting — §3.5's post-adjustment discipline)."""
        for idx in range(len(self.allocation.scopes)):
            self._scope_cooldown_until[idx] = max(
                self._scope_cooldown_until.get(idx, 0.0), until_ms)

    # -- main control step -------------------------------------------------------
    def check(self) -> list[Action]:
        """Run one violation-detection + countermeasure cycle; returns actions
        for the execution layer to route."""
        now = self.clock.now()
        actions: list[Action] = []
        for idx, scope in enumerate(self.allocation.scopes):
            if idx in self._gave_up:
                continue
            if now < self._scope_cooldown_until.get(idx, 0.0):
                continue
            res = self.analyze(scope)
            if res is None:
                continue  # not enough measurement data yet
            estimate = res.worst_estimate_ms
            limit = scope.constraint.latency_limit_ms
            if estimate <= limit:
                continue
            scope_actions = self._countermeasures(scope, res)
            if scope_actions:
                actions.extend(scope_actions)
                self._scope_cooldown_until[idx] = now + scope.constraint.window_ms
                self.history.append(
                    ViolationRecord(
                        scope.constraint.name, estimate, now, tuple(scope_actions)
                    )
                )
            else:
                # Preconditions for countermeasures exhausted (§3.5): report
                # to the master (once) so the user can revise the job or the
                # constraint; keep monitoring with a long cooldown — load may
                # shift and make countermeasures applicable again.
                if idx not in self._gave_up:
                    self._gave_up.add(idx)
                    give = GiveUp(scope.constraint.name, self.worker, estimate)
                    actions.append(give)
                    self.history.append(
                        ViolationRecord(scope.constraint.name, estimate, now, (give,))
                    )
                self._scope_cooldown_until[idx] = (
                    now + 4.0 * scope.constraint.window_ms
                )
        # Proactive path (predictive QoS): runs AFTER the reactive loop and
        # honors the same per-scope cooldowns, so a scope the reactive path
        # just acted on (or that is cooling down from an earlier action) is
        # never double-treated in the same cycle.
        if (self.proactive is not None and self.proactive.enabled
                and self.estimators):
            actions.extend(self._proactive_check(now))
        return actions

    # -- proactive path (forecast-driven, core/estimation.py) -------------------
    def _sources_feeding(self, scope: ConstraintScope) -> frozenset[str]:
        """Source job vertices upstream of (or on) the scope's path."""
        jg = self.rg.job_graph
        seen: set[str] = set()
        srcs: set[str] = set()
        stack = list(scope.path)
        while stack:
            name = stack.pop()
            if name in seen:
                continue
            seen.add(name)
            if jg.vertices[name].is_source:
                srcs.add(name)
            for e in jg.in_edges(name):
                stack.append(e.src)
        return frozenset(srcs)

    def _forecast_ratio(self, idx: int, scope: ConstraintScope) -> float | None:
        """Offered-load ratio forecast/now over the scope's source streams.

        The key identity making the §3 model usable at the forecast rate:
        stage selectivities cancel in the ratio, so a stage's predicted
        utilization is just ``measured_util * ratio`` — no per-stage
        throughput model needed, only the source estimators and the CPU
        gauges the reporters already ship."""
        cfg = self.proactive
        srcs = self._scope_sources.get(idx)
        if srcs is None:
            srcs = self._sources_feeding(scope)
            self._scope_sources[idx] = srcs
        now_sum = fc_sum = 0.0
        any_est = False
        for jv in srcs:
            est = self.estimators.get(f"src:{jv}")
            if est is None:
                continue
            any_est = True
            now_sum += est.rate_now()
            fc_sum += est.forecast(cfg.horizon_ms)
        if not any_est or now_sum <= 0.0:
            return None
        return fc_sum / now_sum

    def _proactive_check(self, now: float) -> list[Action]:
        """Forecast-driven countermeasures (the predictive half of §3.5):
        act on scopes that are NOT yet violated but whose forecast predicts
        a violation within the horizon — and give capacity back on a
        sustained low forecast.  Composes with the reactive path through
        the shared per-scope cooldowns plus a hysteresis band."""
        cfg = self.proactive
        actions: list[Action] = []
        for idx, scope in enumerate(self.allocation.scopes):
            if idx in self._gave_up:
                continue
            if now < self._scope_cooldown_until.get(idx, 0.0):
                continue
            res = self.analyze(scope)
            if res is None:
                continue
            limit = scope.constraint.latency_limit_ms
            if res.worst_estimate_ms > limit:
                continue  # already violated: the reactive path's domain
            ratio = self._forecast_ratio(idx, scope)
            if ratio is None:
                continue
            scope_actions = self._proactive_countermeasures(
                scope, res, ratio, now)
            if scope_actions:
                actions.extend(scope_actions)
                self._scope_cooldown_until[idx] = (
                    now + scope.constraint.window_ms)
                self.history.append(ViolationRecord(
                    scope.constraint.name,
                    res.worst_estimate_ms * ratio,  # forecast-scaled
                    now, tuple(scope_actions)))
        return actions

    def _proactive_countermeasures(
        self,
        scope: ConstraintScope,
        analysis: ScopeAnalysis,
        ratio: float,
        now: float,
    ) -> list[Action]:
        cfg = self.proactive
        actions: list[Action] = []
        for tc in self.throughput_constraints:
            if tc.job_vertex not in scope.path:
                continue
            if not self._vertex_is_scalable(tc.job_vertex):
                continue
            tasks = self.rg.tasks_of(tc.job_vertex)
            utils = [self._task_cpu[v.id][0] for v in tasks
                     if v.id in self._task_cpu]
            if not utils:
                continue
            mean_util = sum(utils) / len(utils)
            predicted = mean_util * ratio  # selectivity cancels (see above)
            key = f"{scope.constraint.name}:{tc.job_vertex}"
            cur = len(tasks)
            cap = min(self.scale_max_parallelism, tc.max_parallelism)
            if (predicted > self.scale_util_threshold * cfg.hysteresis
                    and cur < cap):
                # size the step to absorb the forecast, bounded by the
                # reactive step so proactive can never out-jump reactive
                want = max(cur + 1, math.ceil(
                    cur * predicted / self.scale_util_threshold))
                to = min(want, cur + self.scale_step, cap)
                actions.append(ScaleRequest(
                    tc.job_vertex, cur, to,
                    f"proactive: forecast util {predicted:.2f} within "
                    f"{cfg.horizon_ms:.0f}ms horizon "
                    f"(now {mean_util:.2f}, rate x{ratio:.2f})"))
                self._low_forecast_ticks.pop(key, None)
            elif (predicted < cfg.giveback_util
                    and mean_util < cfg.giveback_util):
                base = self.rg.job_graph.vertices[tc.job_vertex].parallelism
                ticks = self._low_forecast_ticks.get(key, 0) + 1
                self._low_forecast_ticks[key] = ticks
                if ticks >= cfg.giveback_ticks and cur > base:
                    to = max(cur - self.scale_step, base)
                    # never shrink into a predicted re-violation
                    if (mean_util * cur / max(to, 1)
                            < self.scale_util_threshold):
                        actions.append(ScaleRequest(
                            tc.job_vertex, cur, to,
                            f"proactive: sustained low forecast "
                            f"(util {mean_util:.2f}, "
                            f"predicted {predicted:.2f} "
                            f"for {ticks} checks)"))
                        self._low_forecast_ticks.pop(key, None)
            else:
                self._low_forecast_ticks.pop(key, None)
        if actions:
            return actions
        # Fallback when no scalable stage can absorb the forecast: if the
        # first-order forecast-scaled estimate breaches the limit, pre-adapt
        # the buffers on the worst owned sequence — the reactive Eq. 2/3
        # proposal fed the oblt the channel WOULD have at the forecast rate
        # (buffer fill time scales inversely with offered load).
        limit = scope.constraint.latency_limit_ms
        if ratio <= cfg.hysteresis:
            return []
        if analysis.worst_estimate_ms * ratio <= limit:
            return []
        window = scope.constraint.window_ms
        for el in analysis.worst_elements:
            if not isinstance(el, Channel):
                continue
            if now < self._settled_until.get(el.id, 0.0):
                continue
            ob = self.oblt(el, window)
            if ob is None:
                continue
            obl = (ob / ratio) / 2.0
            buf = self._chan_buf.get(el.id)
            if buf is None:
                continue
            size, version = buf
            src_lat = self.task_latency(el.src, window)
            new = self.policy.propose(size, obl, src_lat)
            if new is not None and new != size:
                direction = 1 if new > size else -1
                last = self._last_update_dir.get(el.id)
                if last is not None and last != direction:
                    self._settled_until[el.id] = (
                        now + self.settle_windows * window)
                    self._last_update_dir.pop(el.id, None)
                    continue
                self._last_update_dir[el.id] = direction
                actions.append(BufferSizeUpdate(
                    channel_id=el.id,
                    src_worker=self.rg.worker(el.src),
                    new_size_bytes=new,
                    base_version=version,
                ))
        return actions

    # -- countermeasures ----------------------------------------------------------
    def _countermeasures(
        self,
        scope: ConstraintScope,
        analysis: ScopeAnalysis,
    ) -> list[Action]:
        window = scope.constraint.window_ms
        now = self.clock.now()
        actions: list[Action] = []
        # 1. adaptive output buffer sizing, per channel individually (§3.5.1),
        #    applied to every channel lying on a violated owned sequence.
        for el in analysis.violated_channels:
            if now < self._settled_until.get(el.id, 0.0):
                continue  # oscillation damping: this channel has converged
            ob = self.oblt(el, window)
            if ob is None:
                continue
            obl = ob / 2.0
            buf = self._chan_buf.get(el.id)
            if buf is None:
                continue
            size, version = buf
            src_lat = self.task_latency(el.src, window)
            new = self.policy.propose(size, obl, src_lat)
            if new is not None and new != size:
                direction = 1 if new > size else -1
                last = self._last_update_dir.get(el.id)
                if last is not None and last != direction:
                    # grow<->shrink flip: the iterative adjustment has hit its
                    # fixed point for this channel; stop touching it so that
                    # chaining can take over (§3.5.2).
                    self._settled_until[el.id] = now + self.settle_windows * window
                    self._last_update_dir.pop(el.id, None)
                    continue
                self._last_update_dir[el.id] = direction
                actions.append(
                    BufferSizeUpdate(
                        channel_id=el.id,
                        src_worker=self.rg.worker(el.src),
                        new_size_bytes=new,
                        base_version=version,
                    )
                )
        if actions:
            return actions
        # 2. dynamic task chaining (§3.5.2) once buffers are settled: try the
        #    owned anchor paths worst-first until one yields a chain.
        limit = scope.constraint.latency_limit_ms

        def info(v: RuntimeVertex) -> TaskRuntimeInfo | None:
            cpu = self._task_cpu.get(v.id)
            if cpu is None:
                return None
            return TaskRuntimeInfo(
                worker=self.rg.worker(v), cpu_utilization=cpu[0], chained=cpu[1]
            )

        for estimate, elements in analysis.per_anchor:
            if estimate <= limit:
                break  # sorted desc: the rest are not violated
            seq_tasks = [el for el in elements if isinstance(el, RuntimeVertex)]
            req = find_chain(
                seq_tasks,
                self.rg,
                self.allocation.subgraph,
                info,
                self.cpu_threshold,
                self.chain_mode,
            )
            if req is not None:
                return [req]
        # 3. elastic scale-out (§6): buffers settled and no chain available.
        #    If a throughput-constrained stage on this path is saturated, the
        #    latency violation is a capacity problem — request more replicas
        #    instead of giving up.
        scale = self._propose_scale(scope)
        if scale is not None:
            return [scale]
        return []

    def _vertex_is_scalable(self, job_vertex: str) -> bool:
        """Mirror the re-wiring layer's preconditions: sources and
        POINTWISE-pinned neighbourhoods cannot be re-parallelized, so no
        ScaleRequest may target them."""
        jg = self.rg.job_graph
        in_edges = jg.in_edges(job_vertex)
        if not in_edges or jg.vertices[job_vertex].is_source:
            return False
        from .graphs import ALL_TO_ALL
        return all(e.pattern == ALL_TO_ALL
                   for e in in_edges + jg.out_edges(job_vertex))

    def _propose_scale(self, scope: ConstraintScope) -> ScaleRequest | None:
        for tc in self.throughput_constraints:
            if tc.job_vertex not in scope.path:
                continue
            if not self._vertex_is_scalable(tc.job_vertex):
                continue
            tasks = self.rg.tasks_of(tc.job_vertex)
            utils = [self._task_cpu[v.id][0] for v in tasks
                     if v.id in self._task_cpu]
            if not utils:
                continue
            mean_util = sum(utils) / len(utils)
            if mean_util < self.scale_util_threshold:
                continue  # not saturated: more replicas would not help
            cap = min(self.scale_max_parallelism, tc.max_parallelism)
            cur = len(tasks)
            if cur >= cap:
                continue
            return ScaleRequest(
                tc.job_vertex, cur,
                min(cur + self.scale_step, cap),
                f"latency violated with {tc.job_vertex} saturated "
                f"(util {mean_util:.2f})",
            )
        return None
