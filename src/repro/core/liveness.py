"""Worker liveness: timeout-based failure detection shared by every plane.

Promoted out of ``runtime/fault_tolerance.py`` so the streaming backends and
the training supervisor run the SAME detector (the paper's §3.6 coexistence
argument cuts both ways: the QoS plane must notice dead workers, and the
recovery plane must reuse the QoS plane's clock discipline).  Two fixes over
the training-plane original:

* the clock default is ``is None``-checked (not truthiness), and both
  executors pass their own ``clock.now`` — so a ``SimClock`` drives
  detection in simulated milliseconds and runs stay deterministic;
* the lock comes from ``analysis.race.make_lock`` (NS-L006): liveness sits
  on the engine's control-thread hot loop and its discipline is observed
  under ``REPRO_RACE_CHECK=1``.
"""
from __future__ import annotations

import time
from typing import Callable, Iterable

from ..analysis import race as _race


class HeartbeatMonitor:
    """Per-worker liveness with timeout-based failure detection.

    Workers (or the executor acting for them) call ``beat(w)``; the control
    loop polls ``dead_workers()`` and hands the result to the recovery path.
    A worker is dead once its last beat is more than ``timeout_ms`` ago on
    the injected ``clock`` (milliseconds; wall monotonic by default, the
    executor's sim/real clock in the streaming backends).
    """

    def __init__(self, workers: Iterable[int], timeout_ms: float = 10_000.0,
                 clock: Callable[[], float] | None = None) -> None:
        self.timeout_ms = timeout_ms
        self._clock = (clock if clock is not None
                       else (lambda: time.monotonic() * 1e3))
        now = self._clock()
        self._last: dict[int, float] = {w: now for w in workers}
        self._lock = _race.make_lock()

    def beat(self, worker: int) -> None:
        with self._lock:
            self._last[worker] = self._clock()

    def add(self, worker: int) -> None:
        """Start tracking a newly acquired worker (fresh grace period)."""
        with self._lock:
            self._last[worker] = self._clock()

    def dead_workers(self) -> list[int]:
        now = self._clock()
        with self._lock:
            return [w for w, t in self._last.items()
                    if now - t > self.timeout_ms]

    def remove(self, worker: int) -> None:
        with self._lock:
            self._last.pop(worker, None)

    def tracked(self) -> list[int]:
        with self._lock:
            return sorted(self._last)
