"""Clock abstraction so the QoS control plane runs unmodified on real time
(threaded engine) and simulated time (discrete-event simulator).

All latencies in this codebase are in **milliseconds** (the paper quotes ms).
"""
from __future__ import annotations

import time


class Clock:
    """Interface: ``now()`` returns current time in milliseconds."""

    def now(self) -> float:  # pragma: no cover - interface
        raise NotImplementedError


class RealClock(Clock):
    """Wall-clock time (monotonic), in milliseconds."""

    def __init__(self) -> None:
        self._t0 = time.monotonic()

    def now(self) -> float:
        return (time.monotonic() - self._t0) * 1e3


class SimClock(Clock):
    """Simulated time, advanced by the discrete-event loop."""

    def __init__(self) -> None:
        self._now = 0.0

    def now(self) -> float:
        return self._now

    def advance_to(self, t: float) -> None:
        if t < self._now:
            raise ValueError(f"time went backwards: {t} < {self._now}")
        self._now = t
