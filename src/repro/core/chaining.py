"""Dynamic task chaining (paper §3.5.2, Fig. 3) + §3.6 fault-tolerance veto.

Chaining pulls a series of tasks into one execution thread, eliminating the
queues and thread-safe hand-over between them.  A series v_1..v_n inside a
constrained sequence is *chainable* iff:

1. all tasks run as separate threads within the same process on the same
   worker node (which excludes already-chained tasks),
2. the sum of their CPU utilizations is below the capacity of one core (or a
   fraction of it, default 90 %),
3. they form a path through the manager's runtime subgraph,
4. interior tasks have exactly one incoming and one outgoing channel; only
   v_1 may have multiple incoming and only v_n multiple outgoing channels,
5. (§3.6) no task is annotated ``chainable=False`` — the fault-tolerance veto
   that keeps materialization points intact.

The QoS manager chains the **longest** chainable series found in a violated
sequence.  When establishing a chain the worker either *drops* the in-flight
queues between the tasks or *drains* them first (§3.5.2); both are supported.

Condition 1's worker equality is evaluated against the live placement layer
(core/placement.py): ``TaskRuntimeInfo.worker`` is ``rg.worker(v)``, i.e.
the WorkerPool's assignment, and both execution backends re-check
co-location when a ChainRequest is applied (a rescale may have raced the
decision).  Chains are also *reversible*: the re-wiring layer
(core/elastic.py) can unchain a series — the exact inverse of establishing
it — which is how scale-in retires tasks that were fused into a chain.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

from .graphs import RuntimeGraph, RuntimeSubgraph, RuntimeVertex

DEFAULT_CPU_THRESHOLD = 0.90

DROP_QUEUES = "drop"
DRAIN_QUEUES = "drain"


@dataclass
class TaskRuntimeInfo:
    """What the chaining decision needs to know about one task."""

    worker: int
    cpu_utilization: float
    chained: bool


@dataclass(frozen=True)
class ChainRequest:
    """Manager -> worker instruction to chain ``tasks`` (in dataflow order)."""

    tasks: tuple[RuntimeVertex, ...]
    worker: int
    mode: str = DRAIN_QUEUES


def chainable_series(
    tasks: list[RuntimeVertex],
    rg: RuntimeGraph,
    subgraph: RuntimeSubgraph,
    info: Callable[[RuntimeVertex], TaskRuntimeInfo | None],
    cpu_threshold: float = DEFAULT_CPU_THRESHOLD,
) -> list[RuntimeVertex]:
    """Longest chainable contiguous series within ``tasks`` (the task elements
    of a violated runtime sequence, in order).  Returns [] if none with >= 2
    tasks exists."""
    n = len(tasks)
    best: list[RuntimeVertex] = []

    def ok_pairwise(i: int, j: int) -> bool:
        """Conditions for the contiguous run tasks[i..j] (inclusive)."""
        run = tasks[i : j + 1]
        infos = [info(v) for v in run]
        if any(x is None for x in infos):
            return False
        # (1) same worker, none already chained
        workers = {x.worker for x in infos}
        if len(workers) != 1 or any(x.chained for x in infos):
            return False
        # (5) fault-tolerance veto; keyed-state vertices are materialization
        #     points too — a fused stage bypasses KeyRouter ownership (items
        #     are handed over in the head's thread), which would scatter
        #     per-key state off its owner and break elastic migration
        if any(not rg.job_graph.vertices[v.job_vertex].chainable
               or rg.job_graph.vertices[v.job_vertex].stateful
               for v in run):
            return False
        # (2) CPU budget
        if sum(x.cpu_utilization for x in infos) >= cpu_threshold:
            return False
        # (3) path through the manager's subgraph
        for a, b in zip(run, run[1:]):
            if not any(c.dst == b for c in subgraph.out_channels(a)):
                return False
        # (4) in/out degree, measured on the *full* runtime graph
        for k, v in enumerate(run):
            if k > 0 and len(rg.in_channels(v)) != 1:
                return False
            if k < len(run) - 1 and len(rg.out_channels(v)) != 1:
                return False
        return True

    # O(n^2) scan is fine: sequences are short (task count ~ pipeline depth).
    for i in range(n):
        for j in range(n - 1, i, -1):  # longest first
            if j - i + 1 <= len(best):
                break
            if ok_pairwise(i, j):
                cand = tasks[i : j + 1]
                if len(cand) > len(best):
                    best = cand
                break
    return best


def find_chain(
    sequence_tasks: list[RuntimeVertex],
    rg: RuntimeGraph,
    subgraph: RuntimeSubgraph,
    info: Callable[[RuntimeVertex], TaskRuntimeInfo | None],
    cpu_threshold: float = DEFAULT_CPU_THRESHOLD,
    mode: str = DRAIN_QUEUES,
) -> ChainRequest | None:
    series = chainable_series(sequence_tasks, rg, subgraph, info, cpu_threshold)
    if len(series) < 2:
        return None
    worker = info(series[0]).worker
    return ChainRequest(tuple(series), worker, mode)
