"""Pluggable rate estimation for predictive QoS (ROADMAP "Predictive QoS").

Every countermeasure in the paper's scheme (§3.4) is reactive: a constraint
must already be violated before BufferSizeUpdate / ChainRequest /
ScaleRequest fire, so a flash crowd always buys a violation window equal to
detection + cooldown + scale-out latency.  This module supplies the missing
half — per-source-stream and per-constrained-stage :class:`RateEstimator`
instances (the sfctss shape: pluggable, updated on a fixed period from the
control tick) exposing ``rate_now()`` and ``forecast(horizon_ms)`` so the
QoS manager can evaluate the §3 latency/throughput model at the *forecast*
rate and act before the SLO trips.

Three estimator families, selectable by ``ProactiveConfig.estimator``:

* ``"ewma"`` — exponentially weighted moving average; flat forecast (no
  trend).  Cheap, stable, and the baseline the other two must beat.
* ``"trend"`` — least-squares linear fit over a sliding time window;
  extrapolates the fitted slope.  Exact on linear ramps (the flash-crowd
  front), noisy on short windows.
* ``"holt"`` — Holt double-exponential smoothing with time-aware updates
  (irregular tick spacing is handled by folding ``dt`` into the level
  extrapolation).  Tracks ramps with smoothing, the default.

Determinism contract: estimators are pure arithmetic over the sample stream
— no RNG, no events, no clock reads.  With ``proactive=None`` (or
``ProactiveConfig(enabled=False)`` shadow mode) the bookkeeping changes NO
scheduling decisions; the golden decision traces pin this.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field


class RateEstimator:
    """Interface: feed rate samples, read back a now-cast and a forecast.

    ``update(now_ms, rate)`` is called on the control-tick period with the
    instantaneous rate (items/s) observed since the previous tick;
    ``rate_now()`` returns the smoothed current rate and
    ``forecast(horizon_ms)`` the predicted rate ``horizon_ms`` from the
    last update (clamped at zero — a rate cannot go negative)."""

    def update(self, now_ms: float, rate: float) -> None:
        raise NotImplementedError

    def rate_now(self) -> float:
        raise NotImplementedError

    def forecast(self, horizon_ms: float) -> float:
        raise NotImplementedError


class EwmaEstimator(RateEstimator):
    """Exponentially weighted moving average; flat (no-trend) forecast."""

    def __init__(self, alpha: float = 0.3) -> None:
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha {alpha} outside (0, 1]")
        self.alpha = alpha
        self._level: float | None = None

    def update(self, now_ms: float, rate: float) -> None:
        if self._level is None:
            self._level = rate
        else:
            self._level += self.alpha * (rate - self._level)

    def rate_now(self) -> float:
        return self._level if self._level is not None else 0.0

    def forecast(self, horizon_ms: float) -> float:
        return max(self.rate_now(), 0.0)


class SlidingWindowTrendEstimator(RateEstimator):
    """Least-squares linear fit over a sliding window; extrapolates slope.

    Exact on linear ramps: fed a ramp, ``forecast(h)`` returns the true
    rate at ``now + h`` (until the ramp leaves the window)."""

    def __init__(self, window_ms: float = 5_000.0) -> None:
        if window_ms <= 0:
            raise ValueError(f"window_ms {window_ms} must be positive")
        self.window_ms = window_ms
        self._samples: deque[tuple[float, float]] = deque()

    def update(self, now_ms: float, rate: float) -> None:
        self._samples.append((now_ms, rate))
        cutoff = now_ms - self.window_ms
        while self._samples and self._samples[0][0] < cutoff:
            self._samples.popleft()

    def _fit(self) -> tuple[float, float, float]:
        """Return (slope_per_ms, intercept_at_t0, t0)."""
        n = len(self._samples)
        if n == 0:
            return 0.0, 0.0, 0.0
        t0 = self._samples[-1][0]
        if n == 1:
            return 0.0, self._samples[0][1], t0
        # center times on the last sample for numeric stability
        sx = sy = sxx = sxy = 0.0
        for t, r in self._samples:
            x = t - t0
            sx += x
            sy += r
            sxx += x * x
            sxy += x * r
        denom = n * sxx - sx * sx
        if denom <= 0.0:
            return 0.0, sy / n, t0
        slope = (n * sxy - sx * sy) / denom
        intercept = (sy - slope * sx) / n
        return slope, intercept, t0

    def rate_now(self) -> float:
        _, intercept, _ = self._fit()
        return max(intercept, 0.0)

    def forecast(self, horizon_ms: float) -> float:
        slope, intercept, _ = self._fit()
        return max(intercept + slope * horizon_ms, 0.0)


class HoltEstimator(RateEstimator):
    """Holt double-exponential smoothing (level + trend), time-aware.

    Classic Holt assumes evenly spaced samples; control ticks are nearly
    even but drift under load, so the level extrapolation folds the actual
    ``dt`` in and the trend is maintained per millisecond."""

    def __init__(self, alpha: float = 0.5, beta: float = 0.3) -> None:
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha {alpha} outside (0, 1]")
        if not 0.0 < beta <= 1.0:
            raise ValueError(f"beta {beta} outside (0, 1]")
        self.alpha = alpha
        self.beta = beta
        self._level: float | None = None
        self._trend = 0.0  # per ms
        self._last_ms: float | None = None

    def update(self, now_ms: float, rate: float) -> None:
        if self._level is None or self._last_ms is None:
            self._level = rate
            self._last_ms = now_ms
            return
        dt = now_ms - self._last_ms
        if dt <= 0.0:
            # duplicate tick timestamp: fold the sample into the level only
            self._level += self.alpha * (rate - self._level)
            return
        prev = self._level
        self._level = (self.alpha * rate
                       + (1.0 - self.alpha) * (prev + self._trend * dt))
        self._trend = (self.beta * ((self._level - prev) / dt)
                       + (1.0 - self.beta) * self._trend)
        self._last_ms = now_ms

    def rate_now(self) -> float:
        return max(self._level, 0.0) if self._level is not None else 0.0

    def forecast(self, horizon_ms: float) -> float:
        if self._level is None:
            return 0.0
        return max(self._level + self._trend * horizon_ms, 0.0)


#: registry of estimator kinds for ``ProactiveConfig.estimator`` /
#: ``make_estimator`` — add an entry here to plug in a new estimator
#: (docs/predictive.md walks through it).
ESTIMATOR_KINDS: dict[str, type[RateEstimator]] = {
    "ewma": EwmaEstimator,
    "trend": SlidingWindowTrendEstimator,
    "holt": HoltEstimator,
}


def make_estimator(kind: str, **kwargs) -> RateEstimator:
    """Instantiate a registered estimator kind (``ESTIMATOR_KINDS``)."""
    try:
        cls = ESTIMATOR_KINDS[kind]
    except KeyError:
        raise ValueError(
            f"unknown estimator kind {kind!r} "
            f"(registered: {sorted(ESTIMATOR_KINDS)})") from None
    return cls(**kwargs)


@dataclass(frozen=True)
class ProactiveConfig:
    """Configuration for the forecast-driven proactive decision path.

    Passing an instance as the ``proactive=`` argument of either backend
    turns estimator bookkeeping on; ``enabled=False`` is shadow mode (the
    estimators run, no proactive actions fire — used to pin the
    decision-neutrality invariant against the golden traces).

    * ``horizon_ms`` — how far ahead the forecast looks; a predicted
      violation inside the horizon triggers countermeasures now.  Must be
      at least the control tick (``measurement_interval_ms / 4``) —
      anything shorter forecasts the past (pre-flight rule NS-E003).
    * ``estimator`` — registered kind (``ESTIMATOR_KINDS``);
      ``estimator_args`` are forwarded to its constructor.
    * ``update_period_ms`` — estimator sample period; ``None`` means every
      control tick (the default and the finest available granularity).
    * ``hysteresis`` — multiplicative guard band (> 1) between the reactive
      threshold and the proactive one, so forecast noise at the boundary
      cannot thrash against the reactive path.
    * ``giveback_util`` / ``giveback_ticks`` — scale-in on sustained low
      forecast: predicted AND current utilization below ``giveback_util``
      for ``giveback_ticks`` consecutive proactive checks gives replicas
      back (never below the job-declared base parallelism).
    """

    horizon_ms: float = 3_000.0
    estimator: str = "holt"
    update_period_ms: float | None = None
    hysteresis: float = 1.05
    giveback_util: float = 0.30
    giveback_ticks: int = 4
    enabled: bool = True
    estimator_args: dict = field(default_factory=dict)
