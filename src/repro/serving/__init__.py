"""QoS-constrained streaming serving (the paper's technique, serving-plane)."""

from .qos_server import QoSServer, RequestSpec, ServingResult  # noqa: F401
