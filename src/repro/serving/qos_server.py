"""QoS-constrained model serving on the Nephele streaming core.

The paper's two degrees of freedom, re-read for TPU serving (DESIGN.md §2.2):

* **output buffer size -> dynamic batch size.**  Requests accumulate in the
  Ingress->Prefill channel's output buffer; the buffer ships when full, and
  the shipped buffer IS the model batch (JobVertex.batch_fn).  The QoS
  manager's adaptive buffer sizing (Eq. 2/3) therefore tunes the serving
  batch size against the latency SLO: big buffers = high MXU occupancy /
  throughput, small buffers = low queueing latency — Fig. 2, serving
  edition.
* **dynamic task chaining -> stage fusion.**  When per-stage utilization is
  low, the manager chains Prefill->Decode into one thread: one dispatch
  chain without queue hand-over (on TPU: no host round-trip between the two
  jitted calls).  The §3.6 veto applies to stages whose boundary is a
  materialization point.

* **elastic scale-out -> replica autoscaling.**  With ``elastic=True`` an
  ``ElasticController`` (core/elastic.py) watches Decode throughput +
  utilization and grows/shrinks the Decode replica group live through the
  shared runtime re-wiring layer — the same ``ScaleDecision`` path the
  simulator executes at paper scale.  A ``ThroughputConstraint`` is also
  registered with the QoS managers, arming the manager's third
  countermeasure (scale-out before GiveUp) under the latency SLO.

Pipeline:  Ingress (source) -> Prefill (batch) -> Decode -> Egress (sink).
Batch shapes are bucketed to powers of two so the jit cache stays bounded.

Results carry per-Decode-replica **token-throughput** and **KV-cache
occupancy** gauges (``ServingResult.replica_metrics``) — the saturation
signals a token-level autoscaler needs (request throughput undercounts load
when generation lengths vary; KV occupancy is the memory bound).  With
``autoscaler="tokens"`` the elastic controller consumes exactly these
signals through the ``attach_elastic(sample=...)`` seam instead of the
default request-count telemetry.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..core import (
    ALL_TO_ALL,
    POINTWISE,
    ElasticController,
    JobConstraint,
    JobGraph,
    JobSequence,
    JobVertex,
    SourceSpec,
    StreamEngine,
    ThroughputConstraint,
)
from ..core.buffers import BufferSizingPolicy
from ..models import Model


#: completed-session records older than this many request ids are pruned
#: from the Decode replicas' keyed state (ids are a monotonic sequence).
SESSION_RETENTION = 4096


@dataclass
class RequestSpec:
    """Synthetic open-loop request generator (benchmark driver)."""

    rate_per_s: float = 20.0
    prompt_len: int = 32
    gen_len: int = 8
    vocab: int = 256


@dataclass
class ServingResult:
    latencies_ms: list[float]
    batch_sizes: list[int]
    completed: int
    duration_ms: float
    chained_groups: list
    final_buffer_sizes: dict
    scale_log: list = field(default_factory=list)
    decode_replicas: int = 1
    #: per-Decode-replica gauges: replica id -> {tokens_generated,
    #: token_throughput_per_s, live_duration_ms, kv_cache_sessions,
    #: kv_cache_tokens, live}.  Token throughput (not request throughput)
    #: and KV-cache occupancy are the real saturation signals for LLM
    #: decode; the ``autoscaler="tokens"`` controller consumes the same
    #: signals live.  Throughput is denominated by each replica's live
    #: duration, so mid-run-spawned replicas report their true rate.
    replica_metrics: dict = field(default_factory=dict)

    @property
    def total_token_throughput_per_s(self) -> float:
        return sum(m["token_throughput_per_s"]
                   for m in self.replica_metrics.values())

    @property
    def mean_latency_ms(self) -> float:
        xs = self.latencies_ms
        return sum(xs) / len(xs) if xs else float("nan")

    @property
    def settled_mean_ms(self) -> float:
        """Mean over the last half of completions (post-convergence)."""
        xs = self.latencies_ms
        if not xs:
            return float("nan")
        tail = xs[len(xs) // 2:]
        return sum(tail) / len(tail)

    def p(self, q: float) -> float:
        xs = sorted(self.latencies_ms)
        return xs[min(len(xs) - 1, int(q * len(xs)))] if xs else float("nan")

    @property
    def throughput_rps(self) -> float:
        return self.completed / max(self.duration_ms / 1e3, 1e-9)

    @property
    def mean_batch(self) -> float:
        bs = self.batch_sizes
        return sum(bs) / len(bs) if bs else float("nan")


def _bucket(n: int) -> int:
    b = 1
    while b < n:
        b *= 2
    return b


class QoSServer:
    def __init__(
        self,
        model: Model,
        params,
        spec: RequestSpec,
        *,
        latency_limit_ms: float = 250.0,
        window_ms: float = 3_000.0,
        measurement_interval_ms: float = 500.0,
        initial_buffer_bytes: int = 4096,
        enable_qos: bool = True,
        enable_chaining: bool = True,
        num_workers: int = 1,
        unchainable_decode: bool = False,
        elastic: bool = False,
        max_decode_replicas: int = 4,
        decode_min_rps: float | None = None,
        autoscaler: str = "requests",
        kv_token_budget_per_replica: int | None = None,
    ) -> None:
        if autoscaler not in ("requests", "tokens"):
            raise ValueError(
                f"autoscaler must be 'requests' or 'tokens', "
                f"got {autoscaler!r}")
        self.model = model
        self.params = params
        self.spec = spec
        self.autoscaler = autoscaler
        self.max_len = spec.prompt_len + spec.gen_len + 8
        #: KV budget per Decode replica (tokens) for the occupancy fraction
        #: fed to the token autoscaler; the default is the session store's
        #: own capacity bound (retention window x max sequence length).
        self.kv_token_budget_per_replica = (
            kv_token_budget_per_replica
            if kv_token_budget_per_replica is not None
            else SESSION_RETENTION * self.max_len)
        self._jit_prefill = {}
        self._jit_decode = {}
        self.batch_sizes: list[int] = []
        #: per-replica generated-token counters (replica id -> tokens);
        #: sampled with the KV-cache occupancy gauges into replica_metrics
        self._replica_tokens: dict[str, int] = {}
        self._lock = threading.Lock()

        cfg = model.cfg
        req_bytes = spec.prompt_len * 4 + 16

        def prefill_fn(payloads, emit, ctx):
            reqs = payloads
            n = len(reqs)
            with self._lock:
                self.batch_sizes.append(n)
            bsz = _bucket(n)
            toks = np.zeros((bsz, spec.prompt_len), np.int32)
            for i, r in enumerate(reqs):
                toks[i] = r["tokens"]
            fn = self._prefill_for(bsz)
            batch = {"tokens": jnp.asarray(toks)}
            logits, cache = fn(self.params, batch)
            emit(
                {"cache": cache, "logits": logits, "reqs": reqs, "bsz": bsz},
                size_bytes=n * 64,
            )

        def decode_fn(payload, emit, ctx):
            st = payload
            bsz, reqs = st["bsz"], st["reqs"]
            fn = self._decode_for(bsz)
            cache = st["cache"]
            tok = jnp.argmax(st["logits"], -1).astype(jnp.int32)
            out_tokens = [tok]
            for i in range(spec.gen_len - 1):
                pos = jnp.full((bsz,), spec.prompt_len + i, jnp.int32)
                logits, cache = fn(self.params, cache, tok, pos)
                tok = jnp.argmax(logits, -1).astype(jnp.int32)
                out_tokens.append(tok)
            outs = np.stack([np.asarray(t) for t in out_tokens], 1)
            with self._lock:
                rid = ctx.vertex.id
                self._replica_tokens[rid] = (
                    self._replica_tokens.get(rid, 0)
                    + len(reqs) * len(out_tokens))
            sessions = getattr(ctx, "state", None)
            for i, r in enumerate(reqs):
                if sessions is not None:
                    # per-request session record keyed by request id (KV
                    # position + generated count): elastic Decode replicas
                    # migrate it with their key ranges instead of dropping
                    # it when the replica group is rescaled.  Request ids
                    # are monotonic, so pruning the id one retention window
                    # behind bounds the store in a long-running server.
                    sessions.put(r["id"], {
                        "generated": len(out_tokens),
                        "kv_pos": spec.prompt_len + len(out_tokens) - 1,
                    })
                    sessions.pop(r["id"] - SESSION_RETENTION, None)
                emit(
                    {"request_id": r["id"], "tokens": outs[i].tolist()},
                    size_bytes=64,
                    created_at_ms=r["t_arrival"],
                    key=r["id"],
                )

        self.jg = JobGraph("qos-serving")
        self.jg.add_vertex(JobVertex("Ingress", 1, is_source=True))
        self.jg.add_vertex(JobVertex("Prefill", 1, fn=prefill_fn,
                                     batch_fn=True))
        # elastic Decode needs ALL_TO_ALL wiring so the replica group can
        # grow, and stateful=True keys the per-request session records to
        # the replica group's KeyRouter so a rescale migrates them with
        # their key ranges (stateful also vetoes chaining — a fused stage
        # would bypass ownership).  Chaining itself no longer conflicts
        # with elasticity: the re-wiring layer unchains before retiring
        # (reverse of §3.5.2), so only the explicit §3.6 annotation vetoes.
        self.jg.add_vertex(JobVertex(
            "Decode", 1, fn=decode_fn, stateful=elastic,
            chainable=not unchainable_decode))
        self.jg.add_vertex(JobVertex("Egress", 1, is_sink=True))
        self.jg.add_edge("Ingress", "Prefill", POINTWISE)
        self.jg.add_edge("Prefill", "Decode",
                         ALL_TO_ALL if elastic else POINTWISE)
        self.jg.add_edge("Decode", "Egress", ALL_TO_ALL)

        seq = JobSequence.of(
            ("Ingress", "Prefill"), "Prefill", ("Prefill", "Decode"),
            "Decode", ("Decode", "Egress"),
        )
        self.constraints = [
            JobConstraint(seq, latency_limit_ms, window_ms, name="slo")
        ]
        self.elastic_ctl: ElasticController | None = None
        if elastic:
            tc = ThroughputConstraint(
                "Decode", decode_min_rps or spec.rate_per_s,
                window_ms=window_ms,
                # the replica budget binds BOTH scaling authorities (the
                # controller and the manager's ScaleRequest countermeasure)
                max_parallelism=max_decode_replicas)
            # registering the throughput constraint with the engine arms the
            # manager's scale-out countermeasure under the latency SLO
            self.constraints.append(tc)
            if autoscaler == "tokens":
                # token-denominated controller: the watched rate is decoded
                # tokens/s, so the minimum is the request floor priced in
                # tokens.  This constraint is NOT registered with the
                # engine — the manager's ScaleRequest countermeasure keeps
                # the request-denominated tc above, whose window estimates
                # stay in request units.
                token_tc = ThroughputConstraint(
                    "Decode",
                    (decode_min_rps or spec.rate_per_s) * spec.gen_len,
                    window_ms=window_ms,
                    max_parallelism=max_decode_replicas)
                self.elastic_ctl = ElasticController(
                    token_tc, hi_water=0.75, lo_water=0.20,
                    max_parallelism=max_decode_replicas, step=1,
                    cooldown_ms=2.0 * window_ms)
            else:
                self.elastic_ctl = ElasticController(
                    tc, hi_water=0.75, lo_water=0.20,
                    max_parallelism=max_decode_replicas, step=1,
                    cooldown_ms=2.0 * window_ms)

        rng = np.random.default_rng(0)
        counter = [0]

        def make_payload(seq_no: int):
            counter[0] += 1
            return (
                {
                    "id": seq_no,
                    "tokens": rng.integers(
                        3, spec.vocab, size=spec.prompt_len
                    ).astype(np.int32),
                    "t_arrival": self.engine.clock.now(),
                },
                req_bytes,
            )

        self.engine = StreamEngine(
            self.jg,
            self.constraints,
            num_workers=num_workers,
            sources={
                "Ingress": SourceSpec(
                    rate_items_per_s=spec.rate_per_s,
                    make_payload=make_payload,
                )
            },
            initial_buffer_bytes=initial_buffer_bytes,
            measurement_interval_ms=measurement_interval_ms,
            enable_qos=enable_qos,
            enable_chaining=enable_chaining,
            policy=BufferSizingPolicy(omega_bytes=initial_buffer_bytes * 8),
        )
        if self.elastic_ctl is not None:
            if autoscaler == "tokens":
                # token-aware autoscaling: replace the default emitted/busy
                # telemetry with per-replica token throughput + KV-cache
                # occupancy (the real Decode saturation signals — request
                # counts undercount load when generation lengths vary)
                self._tok_last_ms = self.engine.clock.now()
                self._tok_last_tokens = 0
                self._tok_last_busy = 0.0
                self.engine.attach_elastic(self.elastic_ctl,
                                           sample=self._token_sample)
            else:
                self.engine.attach_elastic(self.elastic_ctl)

    # -- jit caches (bucketed batch shapes) ------------------------------------
    def _prefill_for(self, bsz: int):
        if bsz not in self._jit_prefill:
            self._jit_prefill[bsz] = jax.jit(
                lambda p, b: self.model.prefill(p, b, self.max_len)
            )
        return self._jit_prefill[bsz]

    def _decode_for(self, bsz: int):
        if bsz not in self._jit_decode:
            self._jit_decode[bsz] = jax.jit(self.model.decode_step)
        return self._jit_decode[bsz]

    # -- metrics ---------------------------------------------------------------
    def _kv_tokens_of(self, ex) -> tuple[int, int]:
        """(live sessions, occupied KV tokens) of one Decode executor."""
        sessions = list(ex.state.items()) if ex is not None else []
        return len(sessions), sum(
            rec["kv_pos"] + 1 for _, rec in sessions
            if isinstance(rec, dict) and "kv_pos" in rec)

    def _token_sample(self, now_ms: float) -> tuple[float, float]:
        """Telemetry for the token-aware autoscaler: (decoded tokens/s,
        utilization) where utilization is the worse of compute pressure
        (busy fraction of the live replica group) and memory pressure
        (KV-cache occupancy against the per-replica token budget).

        Owns its own deltas, per the ``attach_elastic(sample=...)``
        contract: calling it re-baselines, which the elastic loop does
        after every applied decision so a rescale never skews the next
        sample."""
        with self._lock:
            total_tokens = sum(self._replica_tokens.values())
        tasks = self.engine.rg.tasks_of("Decode")
        busy = sum(self.engine._task_busy_ms(v) for v in tasks)
        dt = max(now_ms - self._tok_last_ms, 1e-9)
        rate = max(total_tokens - self._tok_last_tokens, 0) / (dt / 1e3)
        busy_util = (max(busy - self._tok_last_busy, 0.0) / dt
                     / max(len(tasks), 1))
        self._tok_last_ms = now_ms
        self._tok_last_tokens = total_tokens
        self._tok_last_busy = busy
        execs = {v.id: ex for v, ex in self.engine.executors.items()
                 if v.job_vertex == "Decode"}
        kv_tokens = sum(self._kv_tokens_of(execs.get(v.id))[1]
                        for v in tasks)
        kv_frac = kv_tokens / max(
            self.kv_token_budget_per_replica * max(len(tasks), 1), 1)
        return rate, min(max(busy_util, kv_frac), 1.0)

    def replica_metrics(self, duration_ms: float) -> dict:
        """Per-Decode-replica token-throughput and KV-cache-occupancy gauges.
        KV occupancy comes from the replica's keyed session records: live
        sessions and their KV positions are exactly what the token-level
        autoscaler treats as cache pressure.

        Token throughput is denominated by each replica's *live* duration —
        the span between its spawn (or run start, for the initial group)
        and its retirement (or run end): a replica scaled out mid-run must
        not have its rate diluted by the time before it existed."""
        out: dict[str, dict] = {}
        t0 = getattr(self.engine, "_t0", 0.0)
        end = t0 + duration_ms
        with self._lock:
            tokens = dict(self._replica_tokens)
        # cover retired replicas too: a replica scaled in mid-run still
        # generated tokens (its sessions migrated to the survivors, so its
        # KV gauges read from its now-evicted store — i.e. zero)
        execs = {v.id: ex for v, ex in self.engine.executors.items()
                 if v.job_vertex == "Decode"}
        live = {v.id for v in self.engine.rg.tasks_of("Decode")}
        for rid in sorted(live | set(tokens) | set(execs)):
            ex = execs.get(rid)
            if ex is not None:
                # initial executors are spawned before start() stamps _t0;
                # clamp both ends into the [t0, end] run window
                born = max(getattr(ex, "spawned_at_ms", t0), t0)
                died = getattr(ex, "retired_at_ms", None)
                live_ms = min(died, end) - born if died is not None \
                    else end - born
            else:
                live_ms = duration_ms
            live_ms = max(live_ms, 1e-6)
            n_sessions, kv_toks = self._kv_tokens_of(ex)
            toks = tokens.get(rid, 0)
            out[rid] = {
                "tokens_generated": toks,
                "token_throughput_per_s": toks / max(live_ms / 1e3, 1e-9),
                "live_duration_ms": live_ms,
                "kv_cache_sessions": n_sessions,
                "kv_cache_tokens": kv_toks,
                "live": rid in live,
            }
        return out

    # -- run ----------------------------------------------------------------------
    def run(self, duration_ms: float) -> ServingResult:
        res = self.engine.run(duration_ms)
        return ServingResult(
            latencies_ms=res.sink_latencies_ms,
            batch_sizes=self.batch_sizes,
            completed=res.items_at_sinks,
            duration_ms=res.duration_ms,
            chained_groups=res.chained_groups,
            final_buffer_sizes=res.final_buffer_sizes,
            scale_log=list(res.scale_log),
            decode_replicas=len(self.engine.rg.tasks_of("Decode")),
            replica_metrics=self.replica_metrics(res.duration_ms),
        )
