"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
        --steps 200 --batch 8 --seq 256

Wires together: streaming data pipeline (replayable), model, optimizer,
sharded train step (on whatever mesh the host offers), checkpointing with
async saves, the TrainingSupervisor restart loop, and straggler/heartbeat
monitoring.  On this CPU container it trains the reduced configs
(examples/train_end_to_end.py drives a ~100M model); on TPU the same driver
takes the full configs + production mesh.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import get_config
from repro.data import ByteTokenizer, PackedBatchIterator, SyntheticCorpus
from repro.launch.mesh import make_host_mesh
from repro.launch.partition import (
    batch_shardings,
    make_rules,
    opt_state_shardings,
    param_shardings,
)
from repro.launch.steps import make_train_step
from repro.models import build_model
from repro.optim import build_optimizer, cosine_schedule
from repro.runtime import HeartbeatMonitor, StragglerDetector, TrainingSupervisor
from repro.sharding import use_sharding_rules


def train(
    arch: str = "qwen3-1.7b",
    smoke: bool = True,
    steps: int = 100,
    batch: int = 8,
    seq: int = 256,
    lr: float = 3e-4,
    grad_accum: int = 1,
    ckpt_dir: str | None = None,
    save_every: int = 50,
    log_every: int = 10,
    fail_at: dict | None = None,
    cfg_overrides: dict | None = None,
    params=None,
):
    cfg = get_config(arch, smoke=smoke)
    if cfg_overrides:
        cfg = cfg.with_(**cfg_overrides)
    model = build_model(cfg)
    mesh = make_host_mesh()
    rules = make_rules(cfg, mesh, seq_len=seq, global_batch=batch)

    tok = ByteTokenizer()
    if cfg.vocab_size < tok.vocab_size:
        raise ValueError("smoke config vocab too small for byte tokenizer")
    data = PackedBatchIterator(SyntheticCorpus(), tok, batch, seq)

    opt = build_optimizer(
        cfg.optimizer, cosine_schedule(lr, min(20, steps // 10 + 1), steps)
    )
    with mesh, use_sharding_rules(rules, mesh):
        if params is None:
            params = model.init_params(jax.random.PRNGKey(0))
        p_sh = param_shardings(model.logical_axes(), mesh, rules)
        params = jax.device_put(params, p_sh)
        opt_state = opt.init(params)
        o_sh = opt_state_shardings(
            jax.eval_shape(lambda: opt_state), jax.eval_shape(lambda: params),
            p_sh,
        )
        step_fn = jax.jit(
            make_train_step(model, opt, grad_accum=grad_accum),
            in_shardings=(p_sh, o_sh, None),
            out_shardings=(p_sh, o_sh, None),
            donate_argnums=(0, 1),
        )

        hb = HeartbeatMonitor(list(range(mesh.devices.size)))
        stragglers = StragglerDetector()
        losses: list[float] = []
        t_start = time.time()

        def one_step(state, step):
            params, opt_state = state["params"], state["opt"]
            b = next(data)
            t0 = time.time()
            params, opt_state, metrics = step_fn(
                params, opt_state,
                {k: jnp.asarray(v) for k, v in b.items()},
            )
            dt = (time.time() - t0) * 1e3
            for w in range(mesh.devices.size):
                hb.beat(w)
                stragglers.record(w, dt)
            loss = float(metrics["loss"])
            losses.append(loss)
            if log_every and (step + 1) % log_every == 0:
                print(f"step {step+1:5d}  loss {loss:.4f}  "
                      f"gnorm {float(metrics['grad_norm']):.3f}  "
                      f"{dt:.0f} ms/step")
            return {"params": params, "opt": opt_state}

        state = {"params": params, "opt": opt_state}
        if ckpt_dir:
            sup = TrainingSupervisor(Checkpointer(ckpt_dir),
                                     save_every=save_every)
            state, done = sup.run(
                state, one_step, steps,
                data_state_fn=data.state,
                fail_at=fail_at,
                on_restore=lambda extra: data.restore(
                    extra.get("data", data.state())),
            )
        else:
            for s in range(steps):
                state = one_step(state, s)
        wall = time.time() - t_start
        return {
            "losses": losses,
            "params": state["params"],
            "wall_s": wall,
            "steps_per_s": steps / wall,
            "dead_workers": hb.dead_workers(),
            "stragglers": stragglers.stragglers(),
        }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--full", action="store_true",
                    help="full (non-smoke) config — TPU scale")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    out = train(
        arch=args.arch, smoke=not args.full, steps=args.steps,
        batch=args.batch, seq=args.seq, lr=args.lr,
        grad_accum=args.grad_accum, ckpt_dir=args.ckpt_dir,
    )
    print(f"final loss {out['losses'][-1]:.4f}  "
          f"({out['steps_per_s']:.2f} steps/s)")


if __name__ == "__main__":
    main()
