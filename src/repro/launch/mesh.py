"""Production mesh definition.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips ("data", "model");
multi-pod: 2x16x16 = 512 chips ("pod", "data", "model") — the "pod" axis
composes with "data" for DP/FSDP so the same partition rules scale to N
pods (set pods=N)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False, pods: int = 2):
    if multi_pod:
        shape = (pods, 16, 16)
        axes = ("pod", "data", "model")
    else:
        shape = (16, 16)
        axes = ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / smoke runs)."""
    n = len(jax.devices())
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"))
