"""Sharding rules: logical axes -> mesh axes, per (arch x shape x mesh).

The baseline policy (recorded per cell in EXPERIMENTS.md):

* params: FSDP over ("pod","data") on the "fsdp" logical axis + TP over
  "model" on heads / mlp / vocab (ZeRO-3 via GSPMD: params all-gather
  per layer, grads reduce-scatter),
* activations: batch over ("pod","data"), residual-stream sequence over
  "model" (sequence parallelism), heads/mlp over "model" inside blocks,
* MoE: "tp" = every expert's FFN dim sharded over "model" (no all-to-all);
  "ep" = experts over "model" (all-to-all dispatch) — a hillclimb option,
* divisibility-aware: any logical axis whose dim does not divide its mesh
  axis falls back to replication (e.g. 24 heads on a 16-way model axis for
  llama3.2-3b, kv_heads=8 < 16 everywhere).

All decisions are *rules*, so a hillclimb iteration is a rule change, not a
model change.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig
from ..sharding import resolve_spec

DP_AXES = ("pod", "data")


def _axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, tuple):
        return math.prod(_axis_size(mesh, a) for a in name)
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def make_rules(cfg: ModelConfig, mesh: Mesh, *, seq_len: int,
               global_batch: int, overrides: dict | None = None) -> dict:
    """Divisibility-aware logical->mesh rules for one dry-run cell."""
    model = _axis_size(mesh, "model")
    dp = _axis_size(mesh, DP_AXES)

    def fits(dim: int, axis_size: int) -> bool:
        return dim > 0 and dim % axis_size == 0

    rules: dict = {
        "batch": DP_AXES if fits(global_batch, dp) else None,
        "fsdp": DP_AXES,  # all param fsdp dims are d_model/d_ff-sized: even
        "embed": None,
        "heads": "model" if fits(cfg.num_heads, model) else None,
        "kv_heads": "model" if fits(cfg.num_kv_heads, model) else None,
        "mlp": "model",
        "vocab": "model" if fits(cfg.padded_vocab, model) else None,
        "expert": "model"
        if (cfg.is_moe and cfg.expert_sharding == "ep"
            and fits(cfg.num_experts, model))
        else None,
        "seq": "model" if fits(seq_len, model) else None,
        "layers": None,
    }
    # row-parallel attention: when the head count does not divide the model
    # axis (llama3.2's 24 heads, whisper's 6), shard the attention q rows
    # (sequence) over "model" instead of replicating the whole attention
    # computation on every model shard (16x wasted FLOPs at prefill_32k)
    rules["attn_seq"] = (
        "model"
        if rules["heads"] is None and cfg.num_heads and fits(seq_len, model)
        else None
    )
    # "mlp" guards: every mlp-tagged dim must divide the model axis
    mlp_dims = [cfg.d_ff]
    if cfg.family in ("ssm", "hybrid"):
        mlp_dims = [d for d in (cfg.d_ff, cfg.d_inner) if d]
        # the SSD head reshape [di] -> [H, P] must align with the shard
        # boundaries (whole heads per shard), else every chunk slice
        # reshards (mamba2-130m: 24 heads on a 16-way axis -> replicate)
        if (cfg.ssm_heads % model or
                (cfg.d_inner // model) % cfg.ssm_head_dim):
            rules["mlp"] = None
    if not all(fits(d, model) for d in mlp_dims):
        rules["mlp"] = None
    # fsdp guard: smallest fsdp-tagged dim is d_model (heads*dh etc. >= it)
    if not fits(cfg.d_model, dp):
        rules["fsdp"] = None
    if overrides:
        rules.update(overrides)
    return rules


# ---------------------------------------------------------------------------
# shardings for params / optimizer state / batches / caches
# ---------------------------------------------------------------------------


def param_shardings(model_axes, mesh: Mesh, rules: dict):
    """model_axes: pytree of logical-axes tuples (Model.logical_axes())."""
    return jax.tree.map(
        lambda axes: NamedSharding(mesh, resolve_spec(axes, rules, mesh)),
        model_axes,
        is_leaf=lambda x: isinstance(x, tuple),
    )


def opt_state_shardings(opt_state_shape, params_shape, param_shard):
    """Derive optimizer-state shardings from parameter shardings.

    m/v (same shape as the param) inherit its sharding; Adafactor's factored
    vr (shape[:-1]) / vc (shape[:-2] + shape[-1:]) drop the corresponding
    spec entries; anything else is replicated.
    """
    flat_p = {
        tuple(k): (v, s)
        for (k, v), (_, s) in zip(
            _flat_with_path(params_shape), _flat_with_path(param_shard)
        )
    }
    mesh = next(iter(flat_p.values()))[1].mesh if flat_p else None

    def assign(path, leaf):
        # match the enclosing param by path prefix inside state trees like
        # {"mu": {<param path>: {"m": ..}}, "v": {<param path>: {"vr": ..}}}
        for pp, (pshape, pshard) in flat_p.items():
            if _is_subpath(pp, path):
                spec = pshard.spec
                if leaf.shape == pshape.shape:
                    return pshard
                if leaf.shape == pshape.shape[:-1]:
                    return NamedSharding(mesh, P(*spec[:-1]))
                if leaf.shape == pshape.shape[:-2] + pshape.shape[-1:]:
                    return NamedSharding(mesh, P(*spec[:-2], *spec[-1:]))
                break
        return NamedSharding(mesh, P())

    flat, treedef = jax.tree_util.tree_flatten_with_path(opt_state_shape)
    out = [assign(tuple(_key_str(k) for k in path), leaf)
           for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


def _key_str(k):
    return getattr(k, "key", getattr(k, "idx", getattr(k, "name", str(k))))


def _flat_with_path(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [
        (tuple(_key_str(k) for k in path), leaf) for path, leaf in flat
    ]


def _is_subpath(param_path: tuple, state_path: tuple) -> bool:
    """param path appears as a contiguous subsequence of the state path."""
    n, m = len(param_path), len(state_path)
    for i in range(m - n + 1):
        if state_path[i : i + n] == param_path:
            return True
    return False


def batch_shardings(batch_specs, mesh: Mesh, rules: dict):
    """Input batches: leading dim is batch; everything else replicated."""
    def spec_for(leaf):
        axes = ("batch",) + (None,) * (len(leaf.shape) - 1)
        return NamedSharding(mesh, resolve_spec(axes, rules, mesh))

    return jax.tree.map(spec_for, batch_specs)


def cache_shardings(cfg: ModelConfig, cache_specs, mesh: Mesh, rules: dict):
    """KV/SSM cache shardings.  Heuristic by array rank+name:

    * attention k/v  [L, B, W, Hkv, Dh]: batch over DP, W (seq) over model
      (flash-decoding split-K), kv_heads replicated,
    * pos tables [B, W]: batch over DP,
    * ssm conv [L(,k), B, W-1, conv]: batch over DP, conv over model,
    * ssm state [L(,k), B, H, N, P]: batch over DP, N over model if even.
    """
    model = _axis_size(mesh, "model")

    def spec_for(path, leaf):
        name = path[-1]
        shape = leaf.shape
        b = resolve_spec(("batch",), rules, mesh)[0]
        if name in ("k", "v", "attn_k", "attn_v", "cross_k", "cross_v"):
            lead = (None,) * (len(shape) - 4)
            seq = "model" if shape[-3] % model == 0 else None
            return NamedSharding(mesh, P(*lead, b, seq, None, None))
        if name in ("pos", "attn_pos"):
            return NamedSharding(mesh, P(b, None))
        if name in ("conv_x", "conv_bc", "tail_conv_x", "tail_conv_bc"):
            lead = (None,) * (len(shape) - 3)
            cd = "model" if shape[-1] % model == 0 else None
            return NamedSharding(mesh, P(*lead, b, None, cd))
        if name in ("state", "tail_state"):
            lead = (None,) * (len(shape) - 4)
            n_ax = "model" if shape[-2] % model == 0 else None
            return NamedSharding(mesh, P(*lead, b, None, n_ax, None))
        return NamedSharding(mesh, P())

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_specs)
    out = [
        spec_for(tuple(_key_str(k) for k in path), leaf)
        for path, leaf in flat
    ]
    return jax.tree_util.tree_unflatten(treedef, out)
