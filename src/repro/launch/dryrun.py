import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.
#   512 placeholder host devices back both the 16x16 single-pod mesh and the
#   2x16x16 multi-pod mesh.  Never set this globally (tests/benches must see
#   one device).
"""Multi-pod dry-run: .lower().compile() every (arch x input-shape x mesh)
cell on the production mesh, prove it fits, and extract the roofline terms.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x7b \
        --shape train_4k [--multi-pod] [--out artifacts/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Per cell this prints/records:
  * compiled.memory_analysis()  — per-device bytes: proves the cell fits,
  * compiled.cost_analysis()    — HLO FLOPs / bytes for the roofline,
  * collective link-bytes parsed from the partitioned HLO (hlo_analysis),
  * the sharding rules used (the baseline policy; hillclimbs override).
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.configs.shapes import LONG_CONTEXT_ARCHS, SHAPES, cells
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.partition import (
    batch_shardings,
    cache_shardings,
    make_rules,
    opt_state_shardings,
    param_shardings,
)
from repro.launch.steps import (
    make_decode_step,
    make_prefill_step,
    make_train_step,
    pick_grad_accum,
)
from repro.models import build_model
from repro.optim import build_optimizer, cosine_schedule
from repro.sharding import use_sharding_rules

# Baseline microbatch gradient-accumulation factors (memory-driven; the
# per-cell EXPERIMENTS.md entries record the final values).
GRAD_ACCUM = {
    # llama3-405b / dbrx-132b use nested-remat scans (scan_remat_groups)
    # instead of microbatching: FSDP params are gathered O(1) times per step
    # rather than once per microbatch (see EXPERIMENTS.md §Perf).
    "llama3-405b": 1,
    "dbrx-132b": 4,
    "mixtral-8x7b": 4,
    "zamba2-7b": 8,
    "yi-6b": 2,
    "phi-3-vision-4.2b": 2,
    "llama3.2-3b": 2,
    "qwen3-1.7b": 1,
    "mamba2-130m": 1,
    "whisper-tiny": 1,
}


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             overrides: dict | None = None, grad_accum: int | None = None,
             save_hlo: bool = False, out_dir: Path | None = None,
             cfg_overrides: dict | None = None, tag: str = "") -> dict:
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = cfg.with_(**cfg_overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    dp = n_dev // 16  # model axis is 16 in both meshes
    model = build_model(cfg)
    rules = make_rules(cfg, mesh, seq_len=shape.seq_len,
                       global_batch=shape.global_batch, overrides=overrides)

    t0 = time.time()
    with mesh, use_sharding_rules(rules, mesh):
        aparams = model.abstract_params()
        p_sh = param_shardings(model.logical_axes(), mesh, rules)
        if shape.mode == "train":
            opt = build_optimizer(
                cfg.optimizer, cosine_schedule(3e-4, 100, 10_000)
            )
            aopt = jax.eval_shape(opt.init, aparams)
            o_sh = opt_state_shardings(aopt, aparams, p_sh)
            abatch = model.input_specs(
                seq_len=shape.seq_len, batch=shape.global_batch, mode="train"
            )
            b_sh = batch_shardings(abatch, mesh, rules)
            ga = grad_accum if grad_accum is not None else pick_grad_accum(
                shape.global_batch, dp, GRAD_ACCUM.get(arch, 1)
            )
            step = make_train_step(model, opt, grad_accum=ga)
            jitted = jax.jit(
                step,
                in_shardings=(p_sh, o_sh, b_sh),
                out_shardings=(p_sh, o_sh, None),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(aparams, aopt, abatch)
        elif shape.mode == "prefill":
            abatch = model.input_specs(
                seq_len=shape.seq_len, batch=shape.global_batch,
                mode="prefill",
            )
            b_sh = batch_shardings(abatch, mesh, rules)
            acache = model.init_cache_schema(shape.global_batch,
                                             shape.seq_len)
            c_sh = cache_shardings(cfg, acache, mesh, rules)
            step = make_prefill_step(model, shape.seq_len)
            jitted = jax.jit(
                step,
                in_shardings=(p_sh, b_sh),
                out_shardings=(None, c_sh),
            )
            lowered = jitted.lower(aparams, abatch)
            ga = 0
        else:  # decode
            specs = model.input_specs(
                seq_len=shape.seq_len, batch=shape.global_batch, mode="decode"
            )
            acache = specs["cache"]
            c_sh = cache_shardings(cfg, acache, mesh, rules)
            tok_sh = batch_shardings(
                {"t": specs["token"], "p": specs["pos"]}, mesh, rules
            )
            step = make_decode_step(model)
            jitted = jax.jit(
                step,
                in_shardings=(p_sh, c_sh, tok_sh["t"], tok_sh["p"]),
                out_shardings=(None, c_sh),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(aparams, acache, specs["token"],
                                   specs["pos"])
            ga = 0
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    mem_d = {}
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                mem_d[k] = int(v)
    cost = compiled.cost_analysis() or {}
    cost_d = {k: float(v) for k, v in cost.items()
              if isinstance(v, (int, float))}

    hlo = compiled.as_text()
    hla = analyze_hlo(hlo)

    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": int(n_dev),
        "mode": shape.mode,
        "grad_accum": int(ga),
        "rules": {k: (list(v) if isinstance(v, tuple) else v)
                  for k, v in rules.items()},
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory_analysis": mem_d,
        "cost_analysis": cost_d,  # per-computation-execution (no loop trips)
        "hlo_analysis": hla.as_dict(),  # loop-aware per-device totals
        "param_count": int(cfg.param_count()),
        "active_param_count": int(cfg.active_param_count()),
        "hlo_bytes": len(hlo),
    }
    print(f"== {arch} x {shape_name} [{record['mesh']}] ==")
    print(f"  lower {t_lower:.1f}s  compile {t_compile:.1f}s  "
          f"grad_accum={ga}")
    print(f"  memory_analysis: { {k: f'{v/2**30:.2f} GiB' for k, v in mem_d.items()} }")
    print(f"  per-device: flops={hla.flops:.3e}  "
          f"mem_bytes={hla.memory_bytes:.3e}  "
          f"coll_bytes={hla.total_collective_bytes:.3e}")
    print(f"  collectives: {dict(hla.collective_counts)}  "
          f"loops={hla.loop_trips[:8]}")
    if out_dir is not None:
        out_dir.mkdir(parents=True, exist_ok=True)
        suffix = f"__{tag}" if tag else ""
        name = f"{arch}__{shape_name}__{record['mesh']}{suffix}.json"
        (out_dir / name).write_text(json.dumps(record, indent=1))
        if save_hlo:
            (out_dir / name.replace(".json", ".hlo.txt")).write_text(hlo)
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--grad-accum", type=int, default=None)
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--cfg", action="append", default=[],
                    help="ModelConfig override, e.g. --cfg scan_remat_groups=14")
    args = ap.parse_args()
    out = Path(args.out)
    cfg_overrides = {}
    for kv in args.cfg:
        k, _, v = kv.partition("=")
        try:
            v = int(v)
        except ValueError:
            try:
                v = float(v)
            except ValueError:
                v = {"true": True, "false": False, "none": None}.get(
                    v.lower(), v)
        cfg_overrides[k] = v

    todo = (
        cells(list_archs())
        if args.all
        else [(args.arch, args.shape)]
    )
    failures = []
    for arch, shape in todo:
        try:
            run_cell(arch, shape, multi_pod=args.multi_pod,
                     grad_accum=args.grad_accum, save_hlo=args.save_hlo,
                     out_dir=out, cfg_overrides=cfg_overrides or None,
                     tag=args.tag)
        except Exception as e:  # noqa: BLE001 — report all cell failures
            failures.append((arch, shape, repr(e)))
            print(f"FAILED {arch} x {shape}: {e}")
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} cells failed: "
                         f"{[(a, s) for a, s, _ in failures]}")


if __name__ == "__main__":
    main()
