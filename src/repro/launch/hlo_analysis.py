"""Post-SPMD HLO program analysis for the roofline.

``compiled.cost_analysis()`` reports FLOPs / bytes / collective traffic
**per single execution of each computation** — it does not multiply while-
loop trip counts, so a scan-over-layers model under-reports by ~L*x.  This
module parses the partitioned HLO text into its computation graph, recovers
loop trip counts from the loop-condition constants, and accumulates:

* ``flops``         — 2*M*N*K for every dot (+ conv estimate), x trip counts,
* ``memory_bytes``  — operand+result bytes of every non-fused op (the same
                      per-op convention XLA's cost model uses), x trips,
* collective link-bytes with ring-algorithm factors:
      all-gather        (n-1)/n * output_bytes
      reduce-scatter    (n-1)/n * input_bytes
      all-reduce        2 (n-1)/n * input_bytes   (RS + AG)
      all-to-all        (n-1)/n * input_bytes
      collective-permute        1 * input_bytes

Shapes in the partitioned module are per-device, so all sums are
**per-device** quantities.
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]*(?:e[0-9]+m[0-9]+(?:fn)?)?)\[([0-9,]*)\]")
_HDR_RE = re.compile(r"^(ENTRY\s+)?%([\w.\-]+)\s*\((.*)\)\s*->\s*.*\{\s*$")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_INSTR_RE = re.compile(r"^\s*(\(.*?\)|\S+)\s+([a-z][\w\-]*)\(")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SKIP_MEM_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "add-dependency",
    "partition-id", "replica-id", "iota", "rng-get-and-update-state",
}


def _parse_dims(dims: str) -> int:
    if not dims:
        return 1
    n = 1
    for d in dims.split(","):
        n *= int(d)
    return n


def _shape_bytes(text: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        total += _parse_dims(dims) * _DTYPE_BYTES[dt]
    return total


def _first_shape_dims(text: str) -> list[int] | None:
    m = _SHAPE_RE.search(text)
    if not m or m.group(1) not in _DTYPE_BYTES:
        return None
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


@dataclass
class _Op:
    name: str
    kind: str
    line: str
    result_bytes: float
    result_dims: list[int]


@dataclass
class _Computation:
    name: str
    is_entry: bool = False
    ops: list[_Op] = field(default_factory=list)
    raw_lines: list[str] = field(default_factory=list)
    param_bytes: dict[str, float] = field(default_factory=dict)


@dataclass
class HLOAnalysis:
    flops: float = 0.0
    memory_bytes: float = 0.0
    transcendentals: float = 0.0
    collective_link_bytes: dict = field(
        default_factory=lambda: defaultdict(float))
    collective_raw_bytes: dict = field(
        default_factory=lambda: defaultdict(float))
    collective_counts: dict = field(default_factory=lambda: defaultdict(int))
    loop_trips: list[int] = field(default_factory=list)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_link_bytes.values())

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "memory_bytes": self.memory_bytes,
            "collective_link_bytes_total": self.total_collective_bytes,
            "collective_link_bytes": dict(self.collective_link_bytes),
            "collective_raw_bytes": dict(self.collective_raw_bytes),
            "collective_counts": dict(self.collective_counts),
            "loop_trips": self.loop_trips,
        }


def _parse_module(text: str) -> tuple[dict[str, _Computation], dict[str, float],
                                      dict[str, list[int]]]:
    comps: dict[str, _Computation] = {}
    result_bytes: dict[str, float] = {}
    result_dims: dict[str, list[int]] = {}
    cur: _Computation | None = None
    for ln in text.splitlines():
        m = _HDR_RE.match(ln)
        if m:
            cur = _Computation(m.group(2), is_entry=bool(m.group(1)))
            comps[cur.name] = cur
            # parameters: "name: shape, name: shape"
            for pm in re.finditer(r"([\w.\-]+):\s*(\(?[^,()]*\)?)",
                                  m.group(3)):
                result_bytes[pm.group(1)] = _shape_bytes(pm.group(2))
                d = _first_shape_dims(pm.group(2))
                if d is not None:
                    result_dims[pm.group(1)] = d
            continue
        if cur is None:
            continue
        if ln.strip() == "}":
            cur = None
            continue
        cur.raw_lines.append(ln)
        om = _OP_RE.match(ln)
        if not om:
            continue
        name, rest = om.group(1), om.group(2)
        im = _INSTR_RE.match(rest)
        if not im:
            continue
        shape_txt, kind = im.group(1), im.group(2)
        rb = _shape_bytes(shape_txt)
        rd = _first_shape_dims(shape_txt) or []
        result_bytes[name] = rb
        result_dims[name] = rd
        cur.ops.append(_Op(name, kind, ln, rb, rd))
    return comps, result_bytes, result_dims


def _callees(comps: dict[str, _Computation]) -> tuple[dict, set, dict]:
    """Returns (while_edges: caller->(body, cond, trip), fused: set of
    computation names, call_edges: caller->[names])."""
    while_edges: dict[str, list[tuple[str, str]]] = defaultdict(list)
    call_edges: dict[str, list[str]] = defaultdict(list)
    fused: set[str] = set()
    for c in comps.values():
        for op in c.ops:
            ln = op.line
            if op.kind == "while":
                m = re.search(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)",
                              ln)
                if m:
                    while_edges[c.name].append((m.group(2), m.group(1)))
            elif op.kind == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)", ln)
                if m:
                    fused.add(m.group(1))
            elif op.kind in ("call", "async-start", "custom-call"):
                m = re.search(r"to_apply=%?([\w.\-]+)", ln)
                if m:
                    call_edges[c.name].append(m.group(1))
            elif op.kind == "conditional":
                for m in re.finditer(
                    r"(?:true_computation|false_computation|branch_computations=\{)[^,)]*%([\w.\-]+)",
                    ln,
                ):
                    call_edges[c.name].append(m.group(1))
            # reduce/sort/map bodies: tiny scalar computations -> exclude
            elif re.search(r"to_apply=%?([\w.\-]+)", ln):
                fused.add(re.search(r"to_apply=%?([\w.\-]+)", ln).group(1))
    return while_edges, fused, call_edges


def _trip_count(cond: _Computation) -> int:
    """Trip count heuristic: the largest s32[] constant in the loop
    condition computation (the induction bound of jax scans/fori loops)."""
    best = 1
    for ln in cond.raw_lines:
        for m in re.finditer(r"s32\[\]\s+constant\((\d+)\)", ln):
            best = max(best, int(m.group(1)))
    return best


def _dot_flops(op: _Op, result_dims_tbl: dict[str, list[int]]) -> float:
    ln = op.line
    out = math.prod(op.result_dims) if op.result_dims else 1
    # K: product of lhs contracting dims
    cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ln)
    lhs_name_m = re.search(r"\w\(\s*(?:[a-z0-9\[\],{}\. ]*%)?([\w.\-]+)", ln)
    k = 1
    if cm:
        # operand shapes may be inline or referenced by name
        call = ln[ln.index("("):]
        inline = _first_shape_dims(call)
        lhs_dims = None
        if inline:
            lhs_dims = inline
        else:
            m2 = re.search(r"\(%([\w.\-]+)", call)
            if m2:
                lhs_dims = result_dims_tbl.get(m2.group(1))
        if lhs_dims:
            for idx in (cm.group(1).split(",") if cm.group(1) else []):
                i = int(idx)
                if i < len(lhs_dims):
                    k *= lhs_dims[i]
    return 2.0 * out * k


def _conv_flops(op: _Op) -> float:
    # estimate: 2 * result_elems * prod(window dims)  (depthwise-style; the
    # only convs in this codebase are the mamba/whisper depthwise stems)
    out = math.prod(op.result_dims) if op.result_dims else 1
    m = re.search(r"window=\{size=([0-9x]+)", op.line)
    k = 1
    if m:
        for d in m.group(1).split("x"):
            k *= int(d)
    return 2.0 * out * k


def _group_size(line: str, default: int = 2) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return max(1, len([x for x in m.group(1).split(",") if x.strip()]))
    return default


def analyze_hlo(text: str) -> HLOAnalysis:
    comps, result_bytes, result_dims = _parse_module(text)
    while_edges, fused, call_edges = _callees(comps)

    # multipliers via DFS from ENTRY
    mult: dict[str, float] = {}
    entry = next((c.name for c in comps.values() if c.is_entry), None)
    out = HLOAnalysis()

    def visit(name: str, m: float) -> None:
        if name not in comps:
            return
        mult[name] = mult.get(name, 0.0) + m
        for body, cond in while_edges.get(name, ()):  # loops
            trip = _trip_count(comps[cond]) if cond in comps else 1
            out.loop_trips.append(trip)
            visit(body, m * trip)
            visit(cond, m * (trip + 1))
        for callee in call_edges.get(name, ()):
            visit(callee, m)

    if entry:
        visit(entry, 1.0)

    def operand_bytes(ln: str) -> float:
        call = ln[ln.index("(") :] if "(" in ln else ""
        # cut at the closing paren of the call
        end = call.find(")")
        call = call[: end + 1] if end >= 0 else call
        inline = _shape_bytes(call)
        if inline:
            return inline
        tot = 0.0
        for m in re.finditer(r"%([\w.\-]+)", call):
            tot += result_bytes.get(m.group(1), 0.0)
        return tot

    for cname, m in mult.items():
        comp = comps[cname]
        in_fusion = cname in fused
        for op in comp.ops:
            if op.kind == "dot":
                out.flops += m * _dot_flops(op, result_dims)
                if not in_fusion:
                    out.memory_bytes += m * (op.result_bytes
                                             + operand_bytes(op.line))
                continue
            if op.kind == "convolution":
                out.flops += m * _conv_flops(op)
                if not in_fusion:
                    out.memory_bytes += m * (op.result_bytes
                                             + operand_bytes(op.line))
                continue
            base_kind = op.kind.replace("-start", "")
            if base_kind in _COLLECTIVES:
                ob = operand_bytes(op.line)
                rb = op.result_bytes
                n = _group_size(op.line)
                size = max(rb, ob)
                if base_kind == "all-gather":
                    link = (n - 1) / n * (rb or size)
                elif base_kind == "reduce-scatter":
                    link = (n - 1) / n * (ob or size)
                elif base_kind == "all-reduce":
                    link = 2 * (n - 1) / n * (ob or size)
                elif base_kind == "all-to-all":
                    link = (n - 1) / n * (ob or size)
                else:
                    link = ob or size
                out.collective_link_bytes[base_kind] += m * link
                out.collective_raw_bytes[base_kind] += m * size
                out.collective_counts[base_kind] += int(m)
                continue
            if in_fusion or op.kind in _SKIP_MEM_OPS or op.kind.endswith(
                "-done"):
                continue
            if op.kind == "dynamic-slice":
                # touches only the slice: read slice + write result
                out.memory_bytes += m * 2 * op.result_bytes
                continue
            if op.kind == "scatter":
                # in-place on TPU: read updates + write touched slots; the
                # full operand/result are aliased, not re-streamed
                ob_all = []
                call = op.line[op.line.index("(") :] if "(" in op.line else ""
                end = call.find(")")
                call = call[: end + 1] if end >= 0 else call
                for mm in re.finditer(r"%([\w.\-]+)", call):
                    b = result_bytes.get(mm.group(1), 0.0)
                    if 0 < b < op.result_bytes:
                        ob_all.append(b)
                upd = max(ob_all) if ob_all else op.result_bytes
                out.memory_bytes += m * 2 * upd
                continue
            if op.kind == "dynamic-update-slice":
                # in-place on TPU: read update + write slice (the full-array
                # operand/result are aliased, not re-streamed)
                ob_all = []
                call = op.line[op.line.index("(") :] if "(" in op.line else ""
                end = call.find(")")
                call = call[: end + 1] if end >= 0 else call
                for mm in re.finditer(r"%([\w.\-]+)", call):
                    b = result_bytes.get(mm.group(1), 0.0)
                    if 0 < b < op.result_bytes:
                        ob_all.append(b)
                upd = max(ob_all) if ob_all else op.result_bytes
                out.memory_bytes += m * 2 * upd
                continue
            out.memory_bytes += m * (op.result_bytes + operand_bytes(op.line))
    return out


# Back-compat shim for callers that only need collectives.
def collect_collectives(text: str):
    a = analyze_hlo(text)

    class _Shim:
        link_bytes = a.collective_link_bytes
        raw_bytes = a.collective_raw_bytes
        counts = a.collective_counts
        total_link_bytes = a.total_collective_bytes

        def as_dict(self):
            return {
                "total_link_bytes": a.total_collective_bytes,
                "link_bytes": dict(a.collective_link_bytes),
                "raw_bytes": dict(a.collective_raw_bytes),
                "counts": dict(a.collective_counts),
            }

    return _Shim()
