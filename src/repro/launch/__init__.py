"""Launchers: production mesh, sharding rules, multi-pod dry-run,
training and serving drivers."""
