"""jit-able step functions: train_step (with microbatch gradient
accumulation), prefill_step, decode_step."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from ..models.model import Model
from ..optim import Optimizer, apply_updates, clip_by_global_norm


def pick_grad_accum(global_batch: int, dp: int, desired: int) -> int:
    """Largest accum factor <= desired keeping microbatch divisible by dp."""
    if desired <= 1 or global_batch % dp:
        return 1
    per_dp = global_batch // dp
    a = min(desired, per_dp)
    while per_dp % a:
        a -= 1
    return max(a, 1)


def make_train_step(model: Model, optimizer: Optimizer, *,
                    grad_accum: int = 1, clip_norm: float = 1.0,
                    accum_dtype=jnp.bfloat16):
    """``accum_dtype=bfloat16`` keeps the microbatch gradient accumulator at
    2 bytes/param (sharded) — at 405B scale the fp32 accumulator alone is
    6.3 GB/chip; bf16 accumulation over <=16 microbatches costs ~0.5 ulp."""
    loss_fn = lambda p, b: model.loss(p, b)

    def train_step(params, opt_state, batch):
        if grad_accum == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape(
                    grad_accum, x.shape[0] // grad_accum, *x.shape[1:]
                ),
                batch,
            )

            def acc(carry, mb):
                gsum, lsum = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                gsum = jax.tree.map(
                    lambda a, b: (a.astype(jnp.float32)
                                  + b.astype(jnp.float32)).astype(a.dtype),
                    gsum, g,
                )
                return (gsum, lsum + l), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params
            )
            (gsum, lsum), _ = lax.scan(acc, (zeros, jnp.float32(0.0)), micro)
            grads = jax.tree.map(
                lambda g: g.astype(jnp.float32) / grad_accum, gsum
            )
            loss = lsum / grad_accum
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def make_prefill_step(model: Model, max_len: int):
    def prefill_step(params, batch):
        return model.prefill(params, batch, max_len)

    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, cache, token, pos):
        return model.decode_step(params, cache, token, pos)

    return decode_step
