"""Pure-SSM language model (mamba2-130m family): embedding + L Mamba-2
blocks (scan-over-layers) + norm + LM head.  Attention-free: decode state is
O(1) in sequence length, so the long_500k cell runs at constant memory."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig
from .layers import rms_norm
from .params import ParamSpec
from .ssm import mamba_block, mamba_decode_block, ssm_layer_schema
from .transformer import embed, stack_schema, unembed


def schema(cfg: ModelConfig) -> dict:
    dt = cfg.param_dtype
    s = {
        "embedding": ParamSpec((cfg.padded_vocab, cfg.d_model),
                               ("vocab", "fsdp"), "normal", dt),
        "layers": stack_schema(ssm_layer_schema(cfg), cfg.num_layers),
        "final_norm": ParamSpec((cfg.d_model,), (None,), "ones", dt),
    }
    if not cfg.tie_embeddings:
        s["lm_head"] = ParamSpec((cfg.d_model, cfg.padded_vocab),
                                 ("fsdp", "vocab"), "scaled", dt)
    return s


def _layer_fwd(cfg: ModelConfig, p, x, initial_state=None):
    h, (conv_tail, state) = mamba_block(
        cfg, p, rms_norm(x, p["norm"]), initial_state=initial_state
    )
    return x + h, conv_tail, state


def forward(cfg: ModelConfig, params, tokens, *, collect_state: bool = False):
    x = embed(cfg, params, tokens)
    body = partial(_layer_fwd, cfg)
    if cfg.remat:
        body = jax.checkpoint(body)

    def scan_fn(x, lp):
        x, conv_tail, state = body(lp, x)
        return x, (conv_tail, state) if collect_state else None

    x, tails = lax.scan(scan_fn, x, params["layers"])
    x = rms_norm(x, params["final_norm"])
    return x, tails


def init_cache_schema(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    del max_len  # state size is constant in sequence length
    L = cfg.num_layers
    w = cfg.ssm_conv_width - 1
    bc_dim = 2 * cfg.ssm_groups * cfg.ssm_state
    dt = cfg.activation_dtype
    return {
        "conv_x": jax.ShapeDtypeStruct((L, batch, w, cfg.d_inner), dt),
        "conv_bc": jax.ShapeDtypeStruct((L, batch, w, bc_dim), dt),
        "state": jax.ShapeDtypeStruct(
            (L, batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim),
            jnp.float32,
        ),
    }


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    sh = init_cache_schema(cfg, batch, max_len)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), sh)


def decode_step(cfg: ModelConfig, params, cache, token, pos):
    del pos  # recurrent state is position-free
    x = embed(cfg, params, token[:, None])[:, 0]

    def scan_fn(x, xs):
        lp, cx, cbc, state = xs
        h, (ncx, ncbc), new_state = mamba_decode_block(
            cfg, lp, rms_norm(x, lp["norm"]), (cx, cbc), state
        )
        return x + h, (ncx, ncbc, new_state)

    x, (ncx, ncbc, nstate) = lax.scan(
        scan_fn, x,
        (params["layers"], cache["conv_x"], cache["conv_bc"], cache["state"]),
    )
    x = rms_norm(x, params["final_norm"])
    logits = unembed(cfg, params, x[:, None])[:, 0]
    return logits, {"conv_x": ncx.astype(cache["conv_x"].dtype),
                    "conv_bc": ncbc.astype(cache["conv_bc"].dtype),
                    "state": nstate}


def prefill(cfg: ModelConfig, params, tokens, max_len: int):
    x, ((cx, cbc), states) = forward(cfg, params, tokens, collect_state=True)
    W = cfg.ssm_conv_width - 1
    pad = W - cx.shape[2]
    if pad > 0:
        cx = jnp.pad(cx, ((0, 0), (0, 0), (pad, 0), (0, 0)))
        cbc = jnp.pad(cbc, ((0, 0), (0, 0), (pad, 0), (0, 0)))
    logits = unembed(cfg, params, x[:, -1:])[:, 0]
    cache = {"conv_x": cx.astype(cfg.activation_dtype),
             "conv_bc": cbc.astype(cfg.activation_dtype),
             "state": states}
    return logits, cache
