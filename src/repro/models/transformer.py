"""Decoder-only transformer stack (dense / MoE / VLM-stub), scan-over-layers.

Layers are stacked along a leading "layers" axis and executed with
``lax.scan`` so the HLO stays one while-loop regardless of depth (126-layer
llama3-405b compiles as fast as the 4-layer whisper).  Remat wraps the layer
body; the KV cache is carried through scan xs/ys as stacked arrays.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig
from .layers import (
    INVALID_POS,
    attention,
    attn_out,
    attn_qkv,
    decode_attention_block,
    glu_mlp,
    moe_block,
    rms_norm,
    self_attention_block,
)
from .params import ParamSpec
from ..sharding import shard as _shard


# ---------------------------------------------------------------------------
# schema
# ---------------------------------------------------------------------------


def attn_schema(cfg: ModelConfig, dt: str) -> dict:
    d, Hq, Hkv, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    p = {
        "wq": ParamSpec((d, Hq, Dh), ("fsdp", "heads", None), "scaled", dt),
        "wk": ParamSpec((d, Hkv, Dh), ("fsdp", "kv_heads", None), "scaled", dt),
        "wv": ParamSpec((d, Hkv, Dh), ("fsdp", "kv_heads", None), "scaled", dt),
        "wo": ParamSpec((Hq, Dh, d), ("heads", None, "fsdp"), "scaled", dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = ParamSpec((Dh,), (None,), "ones", dt)
        p["k_norm"] = ParamSpec((Dh,), (None,), "ones", dt)
    return p


def mlp_schema(cfg: ModelConfig, dt: str) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_gate": ParamSpec((d, f), ("fsdp", "mlp"), "scaled", dt),
        "w_up": ParamSpec((d, f), ("fsdp", "mlp"), "scaled", dt),
        "w_down": ParamSpec((f, d), ("mlp", "fsdp"), "scaled", dt),
    }


def moe_schema(cfg: ModelConfig, dt: str) -> dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    if cfg.expert_sharding == "ep":
        ax3 = ("expert", "fsdp", None)
        ax3d = ("expert", None, "fsdp")
    else:
        ax3 = (None, "fsdp", "mlp")
        ax3d = (None, "mlp", "fsdp")
    return {
        "router": ParamSpec((d, E), ("fsdp", None), "scaled", dt),
        "w_gate": ParamSpec((E, d, f), ax3, "scaled", dt),
        "w_up": ParamSpec((E, d, f), ax3, "scaled", dt),
        "w_down": ParamSpec((E, f, d), ax3d, "scaled", dt),
    }


def layer_schema(cfg: ModelConfig) -> dict:
    dt = cfg.param_dtype
    p = {
        "attn_norm": ParamSpec((cfg.d_model,), (None,), "ones", dt),
        "attn": attn_schema(cfg, dt),
        "mlp_norm": ParamSpec((cfg.d_model,), (None,), "ones", dt),
    }
    p["moe" if cfg.is_moe else "mlp"] = (
        moe_schema(cfg, dt) if cfg.is_moe else mlp_schema(cfg, dt)
    )
    return p


def stack_schema(tree, n: int):
    """Prepend a stacked 'layers' axis to every ParamSpec in the tree."""
    return jax.tree.map(
        lambda ps: ParamSpec((n, *ps.shape), ("layers", *ps.axes), ps.init,
                             ps.dtype),
        tree,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def schema(cfg: ModelConfig) -> dict:
    dt = cfg.param_dtype
    d, V = cfg.d_model, cfg.padded_vocab
    s = {
        "embedding": ParamSpec((V, d), ("vocab", "fsdp"), "normal", dt),
        "layers": stack_schema(layer_schema(cfg), cfg.num_layers),
        "final_norm": ParamSpec((d,), (None,), "ones", dt),
    }
    if not cfg.tie_embeddings:
        s["lm_head"] = ParamSpec((d, V), ("fsdp", "vocab"), "scaled", dt)
    if cfg.family == "vlm":
        # stub projection for precomputed patch embeddings
        s["patch_proj"] = ParamSpec((d, d), ("fsdp", None), "scaled", dt)
    return s


# ---------------------------------------------------------------------------
# forward (training / prefill)
# ---------------------------------------------------------------------------


def _layer_fwd(cfg: ModelConfig, p, x, positions):
    # residual stream: batch over DP/FSDP axes, sequence over the model axis
    # (sequence parallelism; attention/MLP re-shard to heads/mlp internally).
    # The constraint is applied to the layer OUTPUT as well: that tensor is
    # the scan carry saved for remat/backward — leaving it unconstrained
    # lets XLA keep it replicated over "model" (16x the activation memory).
    x = _shard(x, ("batch", "seq", None))
    h, kv = self_attention_block(
        cfg, p["attn"], rms_norm(x, p["attn_norm"]), positions
    )
    x = x + h
    if cfg.is_moe:
        h, aux = moe_block(cfg, p["moe"], rms_norm(x, p["mlp_norm"]))
    else:
        h, aux = glu_mlp(p["mlp"], rms_norm(x, p["mlp_norm"])), 0.0
    return _shard(x + h, ("batch", "seq", None)), kv, aux


def embed(cfg: ModelConfig, params, tokens):
    e = jnp.take(params["embedding"], tokens, axis=0)
    return _shard(e.astype(cfg.activation_dtype), ("batch", None, None))


def unembed(cfg: ModelConfig, params, x):
    w = params["embedding"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, w)
    if cfg.padded_vocab != cfg.vocab_size:  # mask vocab-padding logits
        pad = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad, jnp.asarray(-1e30, logits.dtype), logits)
    return _shard(logits, ("batch", None, "vocab"))


def forward(cfg: ModelConfig, params, tokens, *, patches=None,
            collect_kv: bool = False):
    """Returns (hidden [B,S,d], stacked (k,v) or None, aux_loss)."""
    x = embed(cfg, params, tokens)
    B, S = tokens.shape
    if cfg.family == "vlm" and patches is not None:
        pe = jnp.einsum("bpd,de->bpe", patches.astype(x.dtype),
                        params["patch_proj"])
        x = jnp.concatenate([pe, x], axis=1)
        S = x.shape[1]
    positions = _shard(
        jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S)),
        ("batch", None),
    )  # replicated over "model": avoids per-chunk position re-shards

    body = partial(_layer_fwd, cfg)
    if cfg.remat:
        body = jax.checkpoint(body)

    def scan_fn(carry, lp):
        x = carry
        x, kv, aux = body(lp, x, positions)
        if collect_kv:
            # stacked-cache layout: batch over DP, seq over model (matches
            # cache_shardings); without this the scan ys replicate over
            # "model" — 16x the cache footprint at prefill_32k
            ys = (_shard(kv[0], ("batch", "seq", None, None)),
                  _shard(kv[1], ("batch", "seq", None, None)))
        else:
            ys = None
        return x, (ys, aux)

    G = cfg.scan_remat_groups
    if G and cfg.num_layers % G == 0 and not collect_kv:
        # two-level scan: outer over G groups (checkpointed), inner over
        # L/G layers (each layer checkpointed) -> O(G + L/G) live carries
        grouped = jax.tree.map(
            lambda a: a.reshape(G, cfg.num_layers // G, *a.shape[1:]),
            params["layers"],
        )

        @jax.checkpoint
        def group_fn(x, gp):
            x, (_, auxs) = lax.scan(scan_fn, x, gp)
            return x, auxs

        x, auxs = lax.scan(group_fn, x, grouped)
        kvs = None
    else:
        x, (kvs, auxs) = lax.scan(scan_fn, x, params["layers"])
    x = rms_norm(x, params["final_norm"])
    return x, kvs, jnp.sum(jnp.asarray(auxs)) if cfg.is_moe else 0.0


# ---------------------------------------------------------------------------
# decode (single new token against a stacked KV cache)
# ---------------------------------------------------------------------------


def init_cache_schema(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """Abstract KV-cache layout (ShapeDtypeStruct) for dry-runs/allocation."""
    W = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    L, Hkv, Dh = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
    dt = cfg.activation_dtype
    return {
        "k": jax.ShapeDtypeStruct((L, batch, W, Hkv, Dh), dt),
        "v": jax.ShapeDtypeStruct((L, batch, W, Hkv, Dh), dt),
        "pos": jax.ShapeDtypeStruct((batch, W), jnp.int32),
    }


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    sh = init_cache_schema(cfg, batch, max_len)
    return {
        "k": jnp.zeros(sh["k"].shape, sh["k"].dtype),
        "v": jnp.zeros(sh["v"].shape, sh["v"].dtype),
        "pos": jnp.full(sh["pos"].shape, INVALID_POS, jnp.int32),
    }


def decode_step(cfg: ModelConfig, params, cache, token, pos):
    """token: [B] int32, pos: [B] absolute position.  Returns
    (logits [B,V], new_cache).

    Layers run in a ``fori_loop`` carrying the whole stacked cache and
    writing only the new token's slot (``at[i, b, slot].set``) — with buffer
    donation the cache updates in place; a scan with per-layer cache xs/ys
    would materialize a second (and on some backends third) copy of the
    multi-GB cache."""
    x = embed(cfg, params, token[:, None])
    B = token.shape[0]
    W = cache["k"].shape[2]
    slot = (pos % W) if cfg.sliding_window is not None else jnp.minimum(
        pos, W - 1)
    bidx = jnp.arange(B)
    # every layer writes the same slot: update the shared pos table once
    cpos = cache["pos"].at[bidx, slot].set(pos)

    def body(i, carry):
        x, ck, cv = carry
        lp = jax.tree.map(
            lambda a: lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
            params["layers"],
        )
        h = rms_norm(x, lp["attn_norm"])
        q, k_t, v_t = attn_qkv(cfg, lp["attn"], h, pos[:, None])
        ck = ck.at[i, bidx, slot].set(k_t[:, 0])
        cv = cv.at[i, bidx, slot].set(v_t[:, 0])
        o = attention(
            q, ck[i], cv[i], pos[:, None], cpos,
            causal=True, window=cfg.sliding_window,
            chunk=min(cfg.attn_chunk, W),
        )
        x = x + attn_out(cfg, lp["attn"], o)
        if cfg.is_moe:
            h, _ = moe_block(cfg, lp["moe"], rms_norm(x, lp["mlp_norm"]))
        else:
            h = glu_mlp(lp["mlp"], rms_norm(x, lp["mlp_norm"]))
        return x + h, ck, cv

    x, nk, nv = lax.fori_loop(
        0, cfg.num_layers, body, (x, cache["k"], cache["v"])
    )
    x = rms_norm(x, params["final_norm"])
    logits = unembed(cfg, params, x)[:, 0]
    return logits, {"k": nk, "v": nv, "pos": cpos}


def prefill(cfg: ModelConfig, params, tokens, max_len: int, *, patches=None):
    """Full-sequence prefill; returns (last-position logits [B,V], cache)."""
    x, kvs, _ = forward(cfg, params, tokens, patches=patches, collect_kv=True)
    k, v = kvs  # [L, B, S, Hkv, Dh]
    B, S = x.shape[0], x.shape[1]
    W = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    cache_spec = ("layers", "batch", "seq", None, None)
    if S >= W:
        # keep the last W positions; for a rolling (SWA) cache place them at
        # slot = pos % W; without a window the slots are the identity, so no
        # scatter at all (a scatter would materialize an unsharded
        # [L, B, W, Hkv, Dh] zeros tensor — 540 GB at llama3-405b/32k)
        k_t, v_t, p_t = k[:, :, S - W:], v[:, :, S - W:], positions[:, S - W:]
        if cfg.sliding_window:
            # rolling cache: slots (pos % W) form a rotation of arange(W)
            # (positions are uniform across the batch), so the cache build is
            # a circular roll — identity when W divides S — instead of a
            # batch-indexed scatter (which would gather/replicate the
            # sharded operands)
            shift = S % W
            if shift:
                ck = jnp.roll(k_t, shift, axis=2)
                cv = jnp.roll(v_t, shift, axis=2)
                cpos = jnp.roll(p_t, shift, axis=1)
            else:
                ck, cv, cpos = k_t, v_t, p_t
            ck = _shard(ck, cache_spec)
            cv = _shard(cv, cache_spec)
        else:
            ck, cv, cpos = _shard(k_t, cache_spec), _shard(v_t, cache_spec), p_t
        cache = {"k": ck, "v": cv, "pos": cpos}
    else:
        pad = W - S
        cache = {
            "k": jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
            "v": jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
            "pos": jnp.pad(positions, ((0, 0), (0, pad)),
                           constant_values=INVALID_POS),
        }
    logits = unembed(cfg, params, x[:, -1:])[:, 0]
    return logits, cache
