"""Model facade: one API over all assigned architecture families.

* ``Model.abstract_params()``     — ShapeDtypeStruct tree (dry-run, no alloc)
* ``Model.init_params(key)``      — random init (smoke tests / training)
* ``Model.logical_axes()``        — logical sharding axes per parameter
* ``Model.loss(params, batch)``   — next-token xent (+ MoE aux)
* ``Model.prefill / decode_step`` — serving entry points with KV/SSM caches
* ``Model.input_specs(shape)``    — abstract batch for a named input shape
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from . import encdec, mamba_lm, transformer, zamba
from .config import DENSE, ENCDEC, HYBRID, MOE, SSM, VLM, ModelConfig
from .params import abstract, initialize, logical_axes
from ..sharding import shard as _shard

_FAMILY_MODULES = {
    DENSE: transformer,
    MOE: transformer,
    VLM: transformer,
    SSM: mamba_lm,
    HYBRID: zamba,
    ENCDEC: encdec,
}


def chunked_softmax_xent(h, w, labels, *, chunk: int = 512,
                         label_mask=None, valid_vocab: int | None = None):
    """Cross entropy over huge vocabularies without materializing the full
    [B, S, V] fp32 logits: scan over sequence chunks, rematerializing the
    chunk logits in the backward pass.  ``valid_vocab`` masks vocab-padding
    logits out of the logsumexp."""
    B, S, d = h.shape
    V = w.shape[-1]
    vocab_pad = (
        (jnp.arange(V) >= valid_vocab)
        if valid_vocab is not None and valid_vocab < V
        else None
    )
    chunk = min(chunk, S)
    while S % chunk:  # largest divisor of S <= requested chunk
        chunk -= 1
    nc = S // chunk
    hc = h.reshape(B, nc, chunk, d)
    lc = labels.reshape(B, nc, chunk)
    mc = (
        jnp.ones((B, nc, chunk), jnp.float32)
        if label_mask is None
        else label_mask.reshape(B, nc, chunk).astype(jnp.float32)
    )

    @jax.checkpoint
    def step(carry, xs):
        h_i, l_i, m_i = xs
        # astype (not preferred_element_type): keeps the h cotangent bf16
        logits = jnp.einsum("bsd,dv->bsv", h_i.astype(jnp.float32), w)
        logits = _shard(logits, ("batch", None, "vocab"))
        if vocab_pad is not None:
            logits = jnp.where(vocab_pad, -1e30, logits)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, l_i[..., None].astype(jnp.int32), axis=-1
        )[..., 0]
        nll = (lse - gold) * m_i
        return (carry[0] + nll.sum(), carry[1] + m_i.sum()), None

    (tot, cnt), _ = lax.scan(
        step,
        (jnp.float32(0.0), jnp.float32(0.0)),
        (jnp.moveaxis(hc, 1, 0), jnp.moveaxis(lc, 1, 0), jnp.moveaxis(mc, 1, 0)),
    )
    return tot / jnp.maximum(cnt, 1.0)


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # -- params ---------------------------------------------------------------
    def schema(self):
        return _FAMILY_MODULES[self.cfg.family].schema(self.cfg)

    def abstract_params(self):
        return abstract(self.schema())

    def init_params(self, key):
        return initialize(self.schema(), key)

    def logical_axes(self):
        return logical_axes(self.schema())

    # -- training -------------------------------------------------------------
    def hidden(self, params, batch):
        cfg = self.cfg
        if cfg.family == ENCDEC:
            h, _, _ = encdec.forward(cfg, params, batch["tokens"],
                                     batch["frames"])
            return h, 0.0
        if cfg.family == VLM:
            h, _, aux = transformer.forward(
                cfg, params, batch["tokens"], patches=batch["patches"]
            )
            return h, aux
        mod = _FAMILY_MODULES[cfg.family]
        out = mod.forward(cfg, params, batch["tokens"])
        if cfg.family in (SSM, HYBRID):
            return out[0], 0.0
        return out[0], out[2]

    def loss(self, params, batch):
        cfg = self.cfg
        h, aux = self.hidden(params, batch)
        labels = batch["labels"]
        mask = (labels >= 0).astype(jnp.float32)
        labels = jnp.maximum(labels, 0)
        if cfg.family == VLM:
            # hidden includes patch positions at the front; loss on text only
            h = h[:, cfg.num_patches:]
        w = (
            params["embedding"].T
            if cfg.tie_embeddings
            else params["lm_head"]
        )
        xent = chunked_softmax_xent(
            h, w, labels, label_mask=mask, valid_vocab=cfg.vocab_size
        )
        return xent + 0.01 * aux

    def logits(self, params, batch):
        h, _ = self.hidden(params, batch)
        return transformer.unembed(self.cfg, params, h)

    # -- serving ----------------------------------------------------------------
    def init_cache_schema(self, batch: int, max_len: int, **kw):
        return _FAMILY_MODULES[self.cfg.family].init_cache_schema(
            self.cfg, batch, max_len, **kw
        )

    def init_cache(self, batch: int, max_len: int, **kw):
        return _FAMILY_MODULES[self.cfg.family].init_cache(
            self.cfg, batch, max_len, **kw
        )

    def prefill(self, params, batch, max_len: int):
        cfg = self.cfg
        if cfg.family == ENCDEC:
            return encdec.prefill(cfg, params, batch["tokens"],
                                  batch["frames"], max_len)
        if cfg.family == VLM:
            return transformer.prefill(cfg, params, batch["tokens"], max_len,
                                       patches=batch["patches"])
        return _FAMILY_MODULES[cfg.family].prefill(
            cfg, params, batch["tokens"], max_len
        )

    def decode_step(self, params, cache, token, pos):
        return _FAMILY_MODULES[self.cfg.family].decode_step(
            self.cfg, params, cache, token, pos
        )

    # -- abstract inputs (dry-run) -------------------------------------------------
    def input_specs(self, *, seq_len: int, batch: int, mode: str):
        """Abstract batch (ShapeDtypeStruct) for train / prefill / decode.

        [vlm]/[audio] frontends are stubs: specs carry precomputed patch /
        frame embeddings, per the assignment."""
        cfg = self.cfg
        i32 = jnp.int32
        tok = lambda s: jax.ShapeDtypeStruct((batch, s), i32)
        if mode == "train":
            specs = {"tokens": tok(seq_len), "labels": tok(seq_len)}
            if cfg.family == VLM:
                text = seq_len - cfg.num_patches
                specs = {
                    "tokens": tok(text), "labels": tok(text),
                    "patches": jax.ShapeDtypeStruct(
                        (batch, cfg.num_patches, cfg.d_model),
                        cfg.activation_dtype),
                }
            if cfg.family == ENCDEC:
                specs["frames"] = jax.ShapeDtypeStruct(
                    (batch, min(seq_len, cfg.max_source_positions),
                     cfg.d_model), cfg.activation_dtype)
            return specs
        if mode == "prefill":
            return self.input_specs(seq_len=seq_len, batch=batch, mode="train")
        if mode == "decode":
            specs = {
                "cache": self.init_cache_schema(batch, seq_len),
                "token": jax.ShapeDtypeStruct((batch,), i32),
                "pos": jax.ShapeDtypeStruct((batch,), i32),
            }
            return specs
        raise ValueError(f"unknown mode {mode!r}")


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
