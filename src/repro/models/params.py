"""Parameter schema: one definition drives abstract shapes (dry-run),
random initialization (smoke tests / training) and logical sharding axes
(launch/partition.py maps logical axes -> mesh axes)."""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp

# Logical axis names (mapped to mesh axes by launch/partition.py):
#   "embed"   — d_model dimension
#   "heads"   — attention head dimension (TP)
#   "kv_heads"— kv head dimension
#   "mlp"     — FFN hidden dimension (TP)
#   "vocab"   — vocabulary dimension
#   "expert"  — MoE expert dimension (EP)
#   "layers"  — stacked-layer leading axis (never sharded)
#   None      — replicated


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"       # normal | zeros | ones | scaled(fan-in)
    dtype: str = "bfloat16"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def abstract(tree):
    return jax.tree.map(
        lambda ps: jax.ShapeDtypeStruct(ps.shape, jnp.dtype(ps.dtype)), tree,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def logical_axes(tree):
    return jax.tree.map(
        lambda ps: ps.axes, tree, is_leaf=lambda x: isinstance(x, ParamSpec)
    )


def initialize(tree, key):
    leaves, treedef = jax.tree.flatten(
        tree, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    keys = jax.random.split(key, len(leaves))
    out = []
    for ps, k in zip(leaves, keys):
        dt = jnp.dtype(ps.dtype)
        if ps.init == "zeros":
            out.append(jnp.zeros(ps.shape, dt))
        elif ps.init == "ones":
            out.append(jnp.ones(ps.shape, dt))
        elif ps.init == "scaled":
            fan_in = ps.shape[-2] if len(ps.shape) >= 2 else ps.shape[-1]
            std = 1.0 / math.sqrt(max(fan_in, 1))
            out.append((jax.random.normal(k, ps.shape, jnp.float32) * std).astype(dt))
        else:
            out.append((jax.random.normal(k, ps.shape, jnp.float32) * 0.02).astype(dt))
    return jax.tree.unflatten(treedef, out)
