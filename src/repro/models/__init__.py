"""Pure-JAX model zoo for the assigned architecture pool."""

from .config import (  # noqa: F401
    DENSE,
    ENCDEC,
    HYBRID,
    MOE,
    SSM,
    VLM,
    ModelConfig,
)
from .model import Model, build_model, chunked_softmax_xent  # noqa: F401
