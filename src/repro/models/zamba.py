"""Zamba2-style hybrid (arXiv:2411.15242): a Mamba-2 backbone with a single
*shared* full-attention transformer block applied after every
``cfg.attn_every`` backbone layers (same weights at every application; each
application keeps its own KV cache).

Simplifications vs. the released model (recorded in DESIGN.md): the shared
block consumes the running residual stream directly (the paper concatenates
the block input with the original embedding and down-projects), and LoRA
adapters on the shared block are omitted.  At 500k decode the shared
attention uses a rolling window so memory stays bounded (the SSM carries the
long-range state)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig
from .layers import (
    INVALID_POS,
    decode_attention_block,
    glu_mlp,
    rms_norm,
    self_attention_block,
)
from .params import ParamSpec
from .ssm import mamba_block, mamba_decode_block, ssm_layer_schema
from .transformer import attn_schema, embed, mlp_schema, stack_schema, unembed
from ..sharding import shard as _shard


def _blocks(cfg: ModelConfig) -> tuple[int, int, int]:
    """(n_blocks, layers_per_block, tail_layers)."""
    k = max(cfg.attn_every, 1)
    return cfg.num_layers // k, k, cfg.num_layers % k


def n_attn_apps(cfg: ModelConfig) -> int:
    return _blocks(cfg)[0]


def schema(cfg: ModelConfig) -> dict:
    dt = cfg.param_dtype
    n_blocks, per_block, tail = _blocks(cfg)
    s = {
        "embedding": ParamSpec((cfg.padded_vocab, cfg.d_model),
                               ("vocab", "fsdp"), "normal", dt),
        # stacked [n_blocks, per_block, ...] mamba layers + tail [tail, ...]
        "blocks": stack_schema(
            stack_schema(ssm_layer_schema(cfg), per_block), n_blocks
        ),
        # ONE shared attention+MLP block (weights reused at every application)
        "shared": {
            "attn_norm": ParamSpec((cfg.d_model,), (None,), "ones", dt),
            "attn": attn_schema(cfg, dt),
            "mlp_norm": ParamSpec((cfg.d_model,), (None,), "ones", dt),
            "mlp": mlp_schema(cfg, dt),
        },
        "final_norm": ParamSpec((cfg.d_model,), (None,), "ones", dt),
    }
    if tail:
        s["tail"] = stack_schema(ssm_layer_schema(cfg), tail)
    if not cfg.tie_embeddings:
        s["lm_head"] = ParamSpec((cfg.d_model, cfg.padded_vocab),
                                 ("fsdp", "vocab"), "scaled", dt)
    return s


def _mamba_layer(cfg, p, x):
    h, (conv_tail, state) = mamba_block(cfg, p, rms_norm(x, p["norm"]))
    return x + h, (conv_tail, state)


def _shared_attn(cfg, p, x, positions):
    h, kv = self_attention_block(
        cfg, p["attn"], rms_norm(x, p["attn_norm"]), positions
    )
    x = x + h
    x = x + glu_mlp(p["mlp"], rms_norm(x, p["mlp_norm"]))
    return x, kv


def forward(cfg: ModelConfig, params, tokens, *, collect_state: bool = False):
    x = embed(cfg, params, tokens)
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    mamba = partial(_mamba_layer, cfg)
    shared = partial(_shared_attn, cfg, params["shared"])
    if cfg.remat:
        mamba = jax.checkpoint(mamba)
        shared = jax.checkpoint(shared)

    def block_fn(x, blk_params):
        def inner(x, lp):
            x, tails = mamba(lp, x)
            return x, tails if collect_state else None

        x, tails = lax.scan(inner, x, blk_params)
        x, kv = shared(x, positions)
        if collect_state:
            kv = (_shard(kv[0], ("batch", "seq", None, None)),
                  _shard(kv[1], ("batch", "seq", None, None)))
        return x, (tails, kv if collect_state else None)

    if cfg.remat and not collect_state:
        # block-level checkpoint on top of the per-layer one: liveness is
        # O(n_blocks + layers_per_block) carries instead of O(num_layers)
        block_fn = jax.checkpoint(block_fn)
    x, (ssm_tails, attn_kvs) = lax.scan(block_fn, x, params["blocks"])
    tail_tails = None
    if "tail" in params:
        def inner(x, lp):
            x, tails = mamba(lp, x)
            return x, tails if collect_state else None

        x, tail_tails = lax.scan(inner, x, params["tail"])
    x = rms_norm(x, params["final_norm"])
    return x, (ssm_tails, attn_kvs, tail_tails)


def init_cache_schema(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    n_blocks, per_block, tail = _blocks(cfg)
    w = cfg.ssm_conv_width - 1
    bc_dim = 2 * cfg.ssm_groups * cfg.ssm_state
    W = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    dt = cfg.activation_dtype
    sh = {
        "conv_x": jax.ShapeDtypeStruct(
            (n_blocks, per_block, batch, w, cfg.d_inner), dt),
        "conv_bc": jax.ShapeDtypeStruct(
            (n_blocks, per_block, batch, w, bc_dim), dt),
        "state": jax.ShapeDtypeStruct(
            (n_blocks, per_block, batch, cfg.ssm_heads, cfg.ssm_state,
             cfg.ssm_head_dim), jnp.float32,
        ),
        "attn_k": jax.ShapeDtypeStruct(
            (n_blocks, batch, W, cfg.num_kv_heads, cfg.head_dim), dt
        ),
        "attn_v": jax.ShapeDtypeStruct(
            (n_blocks, batch, W, cfg.num_kv_heads, cfg.head_dim), dt
        ),
        "attn_pos": jax.ShapeDtypeStruct((batch, W), jnp.int32),
    }
    if tail:
        sh["tail_conv_x"] = jax.ShapeDtypeStruct(
            (tail, batch, w, cfg.d_inner), dt)
        sh["tail_conv_bc"] = jax.ShapeDtypeStruct(
            (tail, batch, w, bc_dim), dt)
        sh["tail_state"] = jax.ShapeDtypeStruct(
            (tail, batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim),
            jnp.float32,
        )
    return sh


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    sh = init_cache_schema(cfg, batch, max_len)
    out = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), sh)
    out["attn_pos"] = jnp.full(sh["attn_pos"].shape, INVALID_POS, jnp.int32)
    return out


def decode_step(cfg: ModelConfig, params, cache, token, pos):
    x = embed(cfg, params, token[:, None])[:, 0]

    def block_fn(carry, xs):
        x, cpos = carry
        blk_p, cx, cbc, state, ck, cv = xs

        def inner(x, ys):
            lp, cx_, cbc_, st_ = ys
            h, (ncx, ncbc), nstate = mamba_decode_block(
                cfg, lp, rms_norm(x, lp["norm"]), (cx_, cbc_), st_
            )
            return x + h, (ncx, ncbc, nstate)

        x, (ncx, ncbc, nstate) = lax.scan(inner, x, (blk_p, cx, cbc, state))
        sp = params["shared"]
        h, nk, nv, npos = decode_attention_block(
            cfg, sp["attn"], rms_norm(x, sp["attn_norm"])[:, None], pos,
            ck, cv, cpos,
        )
        x = x + h[:, 0]
        x = x + glu_mlp(sp["mlp"], rms_norm(x, sp["mlp_norm"])[:, None])[:, 0]
        return (x, npos), (ncx, ncbc, nstate, nk, nv)

    # all shared-attn applications write the same slots -> one pos table
    (x, npos), (ncx, ncbc, nstate, nk, nv) = lax.scan(
        block_fn,
        (x, cache["attn_pos"]),
        (params["blocks"], cache["conv_x"], cache["conv_bc"], cache["state"],
         cache["attn_k"], cache["attn_v"]),
    )
    new_cache = dict(cache)
    new_cache.update(
        conv_x=ncx.astype(cache["conv_x"].dtype),
        conv_bc=ncbc.astype(cache["conv_bc"].dtype), state=nstate,
        attn_k=nk, attn_v=nv, attn_pos=npos,
    )
    if "tail" in params:
        def inner(x, ys):
            lp, cx_, cbc_, st_ = ys
            h, (ncx_, ncbc_), nstate_ = mamba_decode_block(
                cfg, lp, rms_norm(x, lp["norm"]), (cx_, cbc_), st_
            )
            return x + h, (ncx_, ncbc_, nstate_)

        x, (tcx, tcbc, tstate) = lax.scan(
            inner, x,
            (params["tail"], cache["tail_conv_x"], cache["tail_conv_bc"],
             cache["tail_state"]),
        )
        new_cache.update(
            tail_conv_x=tcx.astype(cache["tail_conv_x"].dtype),
            tail_conv_bc=tcbc.astype(cache["tail_conv_bc"].dtype),
            tail_state=tstate,
        )
    x = rms_norm(x, params["final_norm"])
    logits = unembed(cfg, params, x[:, None])[:, 0]
    return logits, new_cache


def prefill(cfg: ModelConfig, params, tokens, max_len: int):
    x, (ssm_tails, attn_kvs, tail_tails) = forward(
        cfg, params, tokens, collect_state=True
    )
    B, S = tokens.shape
    W = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    (conv_x_t, conv_bc_t), states = ssm_tails
    k, v = attn_kvs  # [n_blocks, B, S, Hkv, Dh]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    cache_spec = ("layers", "batch", "seq", None, None)
    if S >= W:
        k_t, v_t, p_t = k[:, :, S - W:], v[:, :, S - W:], positions[:, S - W:]
        if cfg.sliding_window:
            # rolling cache: slots (pos % W) form a rotation of arange(W)
            # (positions are uniform across the batch), so the cache build is
            # a circular roll — identity when W divides S — instead of a
            # batch-indexed scatter (which would gather/replicate the
            # sharded operands)
            shift = S % W
            if shift:
                ck = jnp.roll(k_t, shift, axis=2)
                cv = jnp.roll(v_t, shift, axis=2)
                cpos = jnp.roll(p_t, shift, axis=1)
            else:
                ck, cv, cpos = k_t, v_t, p_t
            ck = _shard(ck, cache_spec)
            cv = _shard(cv, cache_spec)
        else:
            ck, cv, cpos = (_shard(k_t, cache_spec), _shard(v_t, cache_spec),
                            p_t)
    else:
        pad = W - S
        ck = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        cv = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        cpos = jnp.pad(positions, ((0, 0), (0, pad)),
                       constant_values=INVALID_POS)
    Wc = cfg.ssm_conv_width - 1
    pad_c = Wc - conv_x_t.shape[3]
    if pad_c > 0:
        pads = ((0, 0), (0, 0), (0, 0), (pad_c, 0), (0, 0))
        conv_x_t = jnp.pad(conv_x_t, pads)
        conv_bc_t = jnp.pad(conv_bc_t, pads)
    cache = {
        "conv_x": conv_x_t.astype(cfg.activation_dtype),
        "conv_bc": conv_bc_t.astype(cfg.activation_dtype),
        "state": states,
        "attn_k": ck, "attn_v": cv, "attn_pos": cpos,
    }
    if tail_tails is not None:
        (tcx, tcbc), tstate = tail_tails
        if pad_c > 0:
            pads3 = ((0, 0), (0, 0), (pad_c, 0), (0, 0))
            tcx = jnp.pad(tcx, pads3)
            tcbc = jnp.pad(tcbc, pads3)
        cache["tail_conv_x"] = tcx.astype(cfg.activation_dtype)
        cache["tail_conv_bc"] = tcbc.astype(cfg.activation_dtype)
        cache["tail_state"] = tstate
    logits = unembed(cfg, params, x[:, -1:])[:, 0]
    return logits, cache
