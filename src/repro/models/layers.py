"""Core layers: RMSNorm, RoPE, chunked online-softmax attention (the XLA
lowering of flash attention — no O(S^2) materialization), GLU MLP, and the
sort-based MoE block.

All ops are pure jnp/lax so every (arch x shape x mesh) cell lowers on any
backend; the Pallas kernels in repro.kernels implement the same math for TPU
(`attn_impl="pallas"`) and are validated against these references.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig
from ..sharding import shard as _shard

INVALID_POS = jnp.int32(2**30)  # kv slot not yet written (masked everywhere)


# ---------------------------------------------------------------------------
# norms / rope
# ---------------------------------------------------------------------------


def rms_norm(x, weight, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * lax.rsqrt(var + eps)).astype(dt) * weight


def rope(x, positions, theta: float):
    """Rotary embedding.  x: [..., S, H, D]; positions: [..., S]."""
    d = x.shape[-1]
    half = d // 2
    freq = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    angles = positions[..., :, None].astype(jnp.float32) * freq  # [..., S, half]
    cos = jnp.cos(angles)[..., :, None, :]  # [..., S, 1, half]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (chunked online softmax; causal / bidirectional; GQA; SWA)
# ---------------------------------------------------------------------------


def attention(
    q,                    # [B, Sq, Hq, D]
    k,                    # [B, Skv, Hkv, D]
    v,                    # [B, Skv, Hkv, D]
    q_positions,          # [B, Sq] int32 absolute positions
    kv_positions,         # [B, Skv] int32 absolute positions (INVALID_POS = hole)
    *,
    causal: bool = True,
    window: int | None = None,
    chunk: int = 1024,
    softmax_scale: float | None = None,
):
    """Blocked attention with online softmax over KV chunks.

    Memory per step is O(Sq * chunk) instead of O(Sq * Skv); this is the
    XLA-level equivalent of the flash-attention tiling the Pallas kernel
    implements on TPU (kernels/flash_attention).
    """
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(D)
    if Sq == 1:
        # decode fast path: one-pass softmax over the whole (sharded) cache —
        # scores are [B, Hq, 1, Skv], tiny, and the reduction over a
        # sequence-sharded cache lowers to the flash-decoding split-K
        # pattern (per-shard partial max/sum + cross-shard combine).
        return _attention_onepass(
            q, k, v, q_positions, kv_positions,
            causal=causal, window=window, scale=scale,
        )
    nchunks = -(-Skv // chunk)
    pad = nchunks * chunk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(
            kv_positions, ((0, 0), (0, pad)), constant_values=INVALID_POS
        )

    if G > 1:
        # expand KV heads to the full head count so every tensor in the scan
        # shards cleanly on the "heads" axis (TP > kv_heads replicates KV —
        # the standard layout; avoids SPMD involuntary remat on the grouped
        # [B,S,Hkv,G,D] form).
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    # KV must be full-sequence for the attention contraction; with
    # row-parallel attention (attn_seq) they replicate across "model"
    k = _shard(k, ("batch", None, "heads", None))
    v = _shard(v, ("batch", None, "heads", None))

    # q-chunking: long queries run as a sequential scan over q blocks so the
    # live score block is [B, q_chunk, H, chunk] instead of [B, Sq, H, chunk]
    if Sq > chunk and Sq % chunk == 0:
        nq = Sq // chunk
        qs = jnp.moveaxis(q.reshape(B, nq, chunk, Hq, D), 1, 0)
        qps = jnp.moveaxis(q_positions.reshape(B, nq, chunk), 1, 0)

        def qstep(_, xs):
            q_i, qp_i = xs
            o = attention(
                q_i, k, v, qp_i, kv_positions,
                causal=causal, window=window, chunk=chunk,
                softmax_scale=softmax_scale,
            )
            return None, o

        _, outs = lax.scan(qstep, None, (qs, qps))
        return jnp.moveaxis(outs, 0, 1).reshape(B, Sq, Hq, D)

    kc = k.reshape(B, nchunks, chunk, Hq, D)
    vc = v.reshape(B, nchunks, chunk, Hq, D)
    pc = kv_positions.reshape(B, nchunks, chunk)

    neg = jnp.float32(-1e30)
    m0 = jnp.full((B, Sq, Hq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Sq, Hq), jnp.float32)
    acc0 = jnp.zeros((B, Sq, Hq, D), jnp.float32)

    def step(carry, xs):
        m, l, acc = carry
        k_i, v_i, p_i = xs  # [B, chunk, Hq, D], [B, chunk]
        # NOTE dtype discipline: the f32 lift happens via astype, NOT via
        # preferred_element_type — the transpose of astype casts the
        # cotangent back to bf16, whereas preferred_element_type=f32 makes
        # the backward dots emit f32 residual-stream cotangents (2x memory
        # and fp32 collectives through the whole backward chain).
        s = jnp.einsum("bqhd,bkhd->bqhk", q, k_i).astype(jnp.float32) * scale
        kvp = p_i[:, None, None, :]                          # [B,1,1,chunk]
        qp = q_positions[:, :, None, None]                   # [B,Sq,1,1]
        mask = kvp >= INVALID_POS
        if causal:
            mask |= kvp > qp
        if window is not None:
            mask |= kvp <= qp - window
        s = jnp.where(mask, neg, s)
        m_new = jnp.maximum(m, s.max(axis=-1))
        # zero fully-masked entries (s == m_new == -1e30 -> exp(0) = 1)
        p = jnp.where(mask, 0.0, jnp.exp(s - m_new[..., None]))
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bqhk,bkhd->bqhd", p.astype(v_i.dtype), v_i)
        acc_new = acc * corr[..., None] + pv.astype(jnp.float32)
        m_new = _shard(m_new, ("batch", "attn_seq", "heads"))
        l_new = _shard(l_new, ("batch", "attn_seq", "heads"))
        acc_new = _shard(acc_new, ("batch", "attn_seq", "heads", None))
        return (m_new, l_new, acc_new), None

    xs = (
        jnp.moveaxis(kc, 1, 0),
        jnp.moveaxis(vc, 1, 0),
        jnp.moveaxis(pc, 1, 0),
    )
    # flash semantics in the backward too: recompute the chunk scores instead
    # of storing [nchunks, B, Sq, H, chunk] scan residuals (which would defeat
    # the online-softmax tiling and blow past HBM at prefill_32k)
    step = jax.checkpoint(step)
    (m, l, acc), _ = lax.scan(step, (m0, l0, acc0), xs)
    l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows -> zero output
    out = acc / l[..., None]
    return out.astype(q.dtype)


def _attention_onepass(q, k, v, q_positions, kv_positions, *, causal, window,
                       scale):
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qg, k).astype(jnp.float32) * scale
    kvp = kv_positions[:, None, None, None, :]
    qp = q_positions[:, :, None, None, None]
    mask = kvp >= INVALID_POS
    if causal:
        mask = mask | (kvp > qp)
    if window is not None:
        mask = mask | (kvp <= qp - window)
    s = jnp.where(mask, jnp.float32(-1e30), s)
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.where(mask, 0.0, jnp.exp(s - m))
    l = jnp.sum(e, axis=-1, keepdims=True)
    p = e / jnp.where(l == 0.0, 1.0, l)
    out = jnp.einsum("bqhgk,bkhd->bqhgd", p.astype(v.dtype), v)
    return out.reshape(B, Sq, Hq, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# attention block (qkv projections + rope + cache handling)
# ---------------------------------------------------------------------------


def attn_qkv(cfg: ModelConfig, p, x, positions):
    """Project to q, k, v (+ optional per-head qk RMS norm) and apply RoPE."""
    B, S, _ = x.shape
    Hq, Hkv, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = _shard(q, ("batch", "attn_seq", "heads", None))
    k = _shard(k, ("batch", "attn_seq", "kv_heads", None))
    return q, k, v


def attn_out(cfg: ModelConfig, p, o):
    o = _shard(o, ("batch", "attn_seq", "heads", None))
    # 2D dot formulation: GSPMD pattern-matches partial-contraction ->
    # reduce-scatter reliably on plain [M,K]@[K,N] dots, but falls back to
    # all-reduce + slice on the 3D 'bshk,hkd' einsum with transposed
    # layouts (observed: 120x full-residual ARs per dbrx step).
    B, S, H, K = o.shape
    y = jnp.einsum("tk,kd->td", o.reshape(B * S, H * K),
                   p["wo"].reshape(H * K, -1)).reshape(B, S, -1)
    # seq-sharded output: residual traffic halves (RS instead of AR); with
    # row-parallel attention ("attn_seq") the rows are already seq-sharded
    # so the constraint is a no-op.
    return _shard(y, ("batch", "seq", None))


def self_attention_block(cfg: ModelConfig, p, x, positions, *, causal=True):
    """Full-sequence self attention (training / prefill)."""
    q, k, v = attn_qkv(cfg, p, x, positions)
    o = attention(
        q, k, v, positions, positions,
        causal=causal, window=cfg.sliding_window, chunk=cfg.attn_chunk,
    )
    return attn_out(cfg, p, o), (k, v)


def decode_attention_block(cfg: ModelConfig, p, x, pos, cache_k, cache_v,
                           cache_pos):
    """Single-token decode against a (possibly rolling) KV cache.

    x: [B, 1, d]; pos: [B] absolute position of the new token;
    cache_k/v: [B, W, Hkv, D]; cache_pos: [B, W] absolute positions per slot
    (INVALID_POS for unwritten slots).  Returns (y, new_k, new_v, new_pos).
    """
    B = x.shape[0]
    W = cache_k.shape[1]
    q, k, v = attn_qkv(cfg, p, x, pos[:, None])
    slot = (pos % W) if cfg.sliding_window is not None else jnp.minimum(pos, W - 1)
    bidx = jnp.arange(B)
    new_k = cache_k.at[bidx, slot].set(k[:, 0])
    new_v = cache_v.at[bidx, slot].set(v[:, 0])
    new_pos = cache_pos.at[bidx, slot].set(pos)
    o = attention(
        q, new_k, new_v, pos[:, None], new_pos,
        causal=True, window=cfg.sliding_window,
        chunk=min(cfg.attn_chunk, W),
    )
    return attn_out(cfg, p, o), new_k, new_v, new_pos


# ---------------------------------------------------------------------------
# MLP / MoE
# ---------------------------------------------------------------------------


def glu_mlp(p, x):
    h = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    h = jax.nn.silu(h) * u
    h = _shard(h, ("batch", None, "mlp"))
    # seq-sharded output -> reduce-scatter over the mlp contraction
    return _shard(jnp.einsum("bsf,fd->bsd", h, p["w_down"]),
                  ("batch", "seq", None))


def moe_block(cfg: ModelConfig, p, x, *, capacity_factor: float | None = None):
    if capacity_factor is None:
        capacity_factor = cfg.moe_capacity_factor
    """Token-choice top-k MoE.

    Distributed path (active when sharding rules are installed): the
    sort-based dispatch runs **shard-local** under shard_map — a global
    ``argsort`` would force GSPMD to all-gather every token onto every
    device.  Each shard routes only its own (batch x seq)-local tokens,
    all-gathers the FSDP-sharded expert weights for the layer (exactly what
    GSPMD does for dense FSDP layers), computes with the model-axis f-shard,
    and psums the w_down contraction over "model".

    Single-device path (tests/smoke): plain global implementation.
    """
    from ..sharding import _mesh, _rules, resolve_spec  # local import: cycle

    rules, mesh = _rules(), _mesh()
    if rules is None or mesh is None or cfg.expert_sharding != "tp":
        return _moe_block_dense(cfg, p, x, capacity_factor=capacity_factor)

    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    dp = resolve_spec(("fsdp",), rules, mesh)[0]      # ("pod","data") subset
    tp = resolve_spec(("mlp",), rules, mesh)[0]       # "model" or None
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    tp_size = sizes.get(tp, 1) if isinstance(tp, str) else 1

    def _ax_size(ax):
        if ax is None:
            return 1
        names = (ax,) if isinstance(ax, str) else tuple(ax)
        n = 1
        for a in names:
            n *= sizes.get(a, 1)
        return n

    if x.shape[0] % _ax_size(dp):
        dp_x = None  # batch too small to split (long_500k: B=1)
    else:
        dp_x = dp
    # tokens enter model-REPLICATED (every model rank routes the same
    # tokens for its f-shard of every expert; a seq-sharded in_spec would
    # psum partial outputs of *different* token sets — wrong math); the
    # output leaves via psum_scatter along seq when divisible, which both
    # returns to the residual stream's seq-sharded layout and halves the
    # combine traffic vs a full psum.
    scatter_seq = (
        isinstance(tp, str) and x.shape[1] % tp_size == 0 and tp_size > 1
    )
    xspec_in = P(dp_x, None, None)
    xspec_out = P(dp_x, tp if scatter_seq else None, None)

    def local_fn(x_l, router, wg, wu, wd):
        # gather the FSDP (dp) shards of the weights for this layer
        if dp is not None:
            router = jax.lax.all_gather(router, dp, axis=0, tiled=True)
            wg = jax.lax.all_gather(wg, dp, axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, dp, axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, dp, axis=2, tiled=True)
        from ..sharding import suspend_sharding_rules

        with suspend_sharding_rules():
            y, aux = _moe_block_dense(
                cfg,
                {"router": router, "w_gate": wg, "w_up": wu, "w_down": wd},
                x_l, capacity_factor=capacity_factor,
                f_partial=tp is not None,
            )
        if tp is not None:
            if scatter_seq:
                y = jax.lax.psum_scatter(y, tp, scatter_dimension=1,
                                         tiled=True)
            else:
                y = jax.lax.psum(y, tp)
            aux = jax.lax.pmean(aux, tp)
        if dp is not None:
            aux = jax.lax.pmean(aux, dp)
        return y, aux

    e_ax = None  # experts replicated in "tp" mode
    fn = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(
            xspec_in,
            P(dp, None),                  # router [d, E]
            P(e_ax, dp, tp),              # w_gate [E, d, f]
            P(e_ax, dp, tp),              # w_up
            P(e_ax, tp, dp),              # w_down [E, f, d]
        ),
        out_specs=(xspec_out, P()),
        check_rep=False,
    )
    return fn(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])


def _moe_block_dense(cfg: ModelConfig, p, x, *, capacity_factor: float = 1.25,
                     f_partial: bool = False):
    """Reference/local MoE: top-k routing + sort-based capacity dispatch.
    With ``f_partial`` the FFN hidden dim is a model-axis shard and the
    caller psums the output."""
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    T = B * S
    flat = x.reshape(T, d)
    logits = jnp.einsum("td,de->te", flat, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = lax.top_k(probs, K)                      # [T, K]
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    # capacity per expert, MXU-aligned
    C = int(capacity_factor * T * K / E)
    C = max(128, -(-C // 128) * 128)

    slot_expert = top_i.reshape(-1)                          # [T*K]
    order = jnp.argsort(slot_expert, stable=True)
    sorted_expert = slot_expert[order]
    token_of_slot = order // K
    sorted_x = jnp.take(flat, token_of_slot, axis=0)         # [T*K, d]

    # position of each slot within its expert's run
    counts = jnp.bincount(sorted_expert, length=E)
    starts = jnp.cumsum(counts) - counts
    pos_in_expert = jnp.arange(T * K) - jnp.take(starts, sorted_expert)
    keep = pos_in_expert < C
    pos_c = jnp.where(keep, pos_in_expert, 0)

    # dispatch by GATHER, not scatter: sorted_x is expert-contiguous, so
    # buf[e, c] = sorted_x[starts[e] + c] (masked past counts[e]) — a small
    # [E, C] index gather instead of a [T*K, d]-wide scatter into zeros
    slot_idx = starts[:, None] + jnp.arange(C, dtype=starts.dtype)[None, :]
    slot_valid = (
        jnp.arange(C)[None, :] < jnp.minimum(counts, C)[:, None]
    )
    slot_idx = jnp.minimum(slot_idx, T * K - 1)
    buf = jnp.where(
        slot_valid[..., None], jnp.take(sorted_x, slot_idx, axis=0), 0.0
    ).astype(x.dtype)
    if cfg.expert_sharding == "ep":
        buf = _shard(buf, ("expert", None, None))
    else:
        buf = _shard(buf, (None, "batch", None))

    h = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    h = jax.nn.silu(h) * u
    if cfg.expert_sharding == "ep":
        h = _shard(h, ("expert", None, None))
    else:
        h = _shard(h, (None, "batch", "mlp"))
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"])

    gathered = out_buf[sorted_expert, pos_c]                 # [T*K, d]
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    # un-sort via inverse-permutation GATHER: a zeros+scatter here costs a
    # zero-init + read-modify-write + a [T*K, d]-wide index broadcast; the
    # inverse permutation itself is a tiny u32 scatter
    inv = jnp.zeros_like(order).at[order].set(
        jnp.arange(order.shape[0], dtype=order.dtype))
    unsorted = jnp.take(gathered, inv, axis=0)
    per_k = unsorted.reshape(T, K, d)
    y = jnp.sum(per_k * top_w[..., None].astype(x.dtype), axis=1)

    # router aux loss (load-balancing, Switch-style) for training metrics
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jax.nn.one_hot(top_i[:, 0], E, dtype=jnp.float32), axis=0
    )
    aux = E * jnp.sum(me * ce)
    return y.reshape(B, S, d), aux
