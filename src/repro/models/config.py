"""Model configuration for the assigned architecture pool."""
from __future__ import annotations

from dataclasses import dataclass, field, replace

import jax.numpy as jnp

DENSE = "dense"
MOE = "moe"
SSM = "ssm"
HYBRID = "hybrid"
ENCDEC = "encdec"
VLM = "vlm"


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0                  # 0 -> d_model // num_heads

    # attention
    qk_norm: bool = False
    sliding_window: int | None = None
    rope_theta: float = 1e4
    attn_chunk: int = 1024           # online-softmax KV chunk (XLA path)
    attn_impl: str = "xla"           # "xla" | "pallas"

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    expert_sharding: str = "tp"      # "tp" (shard d_ff) | "ep" (shard experts)
    moe_capacity_factor: float = 1.25

    # SSM (Mamba-2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 256             # SSD chunk length
    ssm_groups: int = 1

    # hybrid (zamba2-style): a shared full-attention block applied every
    # ``attn_every`` backbone layers (weights shared across applications)
    attn_every: int = 0

    # encoder-decoder (whisper-style); frontend is a stub that accepts
    # precomputed frame embeddings
    encoder_layers: int = 0
    max_source_positions: int = 1500

    # VLM stub: precomputed patch embeddings prepended to the token sequence
    num_patches: int = 0

    #: embedding/lm_head tables padded up to a multiple of this so the vocab
    #: dim shards across the model axis (whisper's 51865 etc.); pad logits
    #: are masked to -inf in unembed/xent.
    vocab_pad_multiple: int = 256

    # numerics / scale
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    tie_embeddings: bool = False
    remat: bool = True
    scan_layers: bool = True
    #: >0: two-level scan-over-layers ([groups, layers/group]) with an extra
    #: checkpoint around each group — activation liveness drops from
    #: O(L) layer carries to O(groups + L/groups), which lets the very deep
    #: models train WITHOUT microbatch gradient accumulation (and therefore
    #: without re-gathering FSDP params once per microbatch).
    scan_remat_groups: int = 0

    # optimizer selection (configs pick adafactor for the very large models
    # so optimizer state fits the per-chip HBM budget at 256 chips)
    optimizer: str = "adamw"         # "adamw" | "adafactor"

    @property
    def head_dim(self) -> int:
        if self.d_head:
            return self.d_head
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return -(-self.vocab_size // m) * m

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def params_dtype(self):
        return jnp.dtype(self.param_dtype)

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)

    # parameter count (embedding included once) — used for roofline 6*N*D
    def param_count(self) -> int:
        d, f, V, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        Hq, Hkv = self.num_heads, self.num_kv_heads
        Dh = self.head_dim if Hq else 0
        attn = d * Hq * Dh + 2 * d * Hkv * Dh + Hq * Dh * d
        if self.qk_norm:
            attn += 2 * Dh
        mlp = 3 * d * f
        norms = 2 * d
        if self.family in (SSM, HYBRID):
            di, ds, nh = self.d_inner, self.ssm_state, self.ssm_heads
            g = self.ssm_groups
            conv_dim = di + 2 * g * ds
            ssm = (
                d * (2 * di + 2 * g * ds + nh)   # in_proj
                + conv_dim * self.ssm_conv_width  # conv1d
                + 3 * nh                          # A_log, D, dt_bias
                + di                              # gated norm
                + di * d                          # out_proj
                + d                               # pre-norm
            )
            if self.family == SSM:
                core = L * ssm
            else:
                n_apps = max(1, L // max(self.attn_every, 1))
                core = L * ssm + (attn + mlp + norms)  # one shared attn block
                _ = n_apps  # applications reuse the same weights
        elif self.is_moe:
            moe = d * self.num_experts + self.num_experts * 3 * d * f
            core = L * (attn + moe + norms)
        else:
            core = L * (attn + mlp + norms)
        emb = V * d
        head = 0 if self.tie_embeddings else V * d
        if self.family == ENCDEC:
            enc = self.encoder_layers * (attn + mlp + norms)
            cross = L * (attn + d)  # cross-attention + its norm
            core = L * (attn + mlp + norms) + enc + cross
        return core + emb + head + d  # final norm

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k of E experts)."""
        if not self.is_moe:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        full = self.param_count()
        all_experts = self.num_layers * self.num_experts * 3 * d * f
        active = self.num_layers * self.experts_per_token * 3 * d * f
        return full - all_experts + active
