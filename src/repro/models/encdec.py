"""Whisper-style encoder-decoder (arXiv:2212.04356) transformer backbone.

The conv audio frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings [B, T_frames, d_model] (what the
stride-2 conv stem would produce).  Recorded simplifications (DESIGN.md):
RoPE replaces Whisper's learned absolute positions; the MLPs are SwiGLU
(shared layer code) instead of GELU."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig
from .layers import (
    INVALID_POS,
    attention,
    attn_out,
    attn_qkv,
    decode_attention_block,
    glu_mlp,
    rms_norm,
    rope,
    self_attention_block,
)
from .params import ParamSpec
from .transformer import attn_schema, embed, mlp_schema, stack_schema, unembed
from ..sharding import shard as _shard


def schema(cfg: ModelConfig) -> dict:
    dt = cfg.param_dtype
    enc_layer = {
        "attn_norm": ParamSpec((cfg.d_model,), (None,), "ones", dt),
        "attn": attn_schema(cfg, dt),
        "mlp_norm": ParamSpec((cfg.d_model,), (None,), "ones", dt),
        "mlp": mlp_schema(cfg, dt),
    }
    dec_layer = {
        "attn_norm": ParamSpec((cfg.d_model,), (None,), "ones", dt),
        "attn": attn_schema(cfg, dt),
        "cross_norm": ParamSpec((cfg.d_model,), (None,), "ones", dt),
        "cross": attn_schema(cfg, dt),
        "mlp_norm": ParamSpec((cfg.d_model,), (None,), "ones", dt),
        "mlp": mlp_schema(cfg, dt),
    }
    return {
        "embedding": ParamSpec((cfg.padded_vocab, cfg.d_model),
                               ("vocab", "fsdp"), "normal", dt),
        "frame_proj": ParamSpec((cfg.d_model, cfg.d_model),
                                ("fsdp", None), "scaled", dt),
        "encoder": stack_schema(enc_layer, cfg.encoder_layers),
        "enc_norm": ParamSpec((cfg.d_model,), (None,), "ones", dt),
        "decoder": stack_schema(dec_layer, cfg.num_layers),
        "final_norm": ParamSpec((cfg.d_model,), (None,), "ones", dt),
        "lm_head": ParamSpec((cfg.d_model, cfg.padded_vocab),
                             ("fsdp", "vocab"), "scaled", dt),
    }


def _cross_attention(cfg, p, x, enc_k, enc_v, enc_positions):
    """q from decoder stream; k/v precomputed from the encoder output."""
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q_pos = jnp.zeros((B, S), jnp.int32)  # no rope across modalities
    o = attention(q, enc_k, enc_v, q_pos, enc_positions,
                  causal=False, chunk=cfg.attn_chunk)
    return attn_out(cfg, p, o)


def _cross_kv(cfg, p, enc_out):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
    return k, v


def encode(cfg: ModelConfig, params, frames):
    """frames: [B, T, d] stub embeddings -> encoder output [B, T, d]."""
    x = jnp.einsum("btd,de->bte", frames.astype(cfg.activation_dtype),
                   params["frame_proj"])
    x = _shard(x, ("batch", None, None))
    B, T = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))

    def body(p, x):
        h, _ = self_attention_block(
            cfg, p["attn"], rms_norm(x, p["attn_norm"]), positions,
            causal=False,
        )
        x = x + h
        return x + glu_mlp(p["mlp"], rms_norm(x, p["mlp_norm"]))

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = lax.scan(lambda c, p: (body(p, c), None), x, params["encoder"])
    return rms_norm(x, params["enc_norm"])


def _decoder_layer(cfg, p, x, positions, enc_k, enc_v, enc_positions):
    h, kv = self_attention_block(
        cfg, p["attn"], rms_norm(x, p["attn_norm"]), positions
    )
    x = x + h
    x = x + _cross_attention(
        cfg, p["cross"], rms_norm(x, p["cross_norm"]), enc_k, enc_v,
        enc_positions,
    )
    return x + glu_mlp(p["mlp"], rms_norm(x, p["mlp_norm"])), kv


def forward(cfg: ModelConfig, params, tokens, frames, *,
            collect_kv: bool = False):
    enc_out = encode(cfg, params, frames)
    B, T = enc_out.shape[:2]
    enc_positions = jnp.broadcast_to(
        jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    x = embed(cfg, params, tokens)
    S = tokens.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    body = partial(_decoder_layer, cfg)
    if cfg.remat:
        body = jax.checkpoint(body)

    def scan_fn(x, lp):
        # cross k/v are recomputed per layer from enc_out (cheap at tiny d)
        ck, cv = _cross_kv(cfg, lp["cross"], enc_out)
        x, kv = body(lp, x, positions, ck, cv, enc_positions)
        return x, kv if collect_kv else None

    x, kvs = lax.scan(scan_fn, x, params["decoder"])
    x = rms_norm(x, params["final_norm"])
    return x, kvs, enc_out


def init_cache_schema(cfg: ModelConfig, batch: int, max_len: int,
                      enc_len: int | None = None) -> dict:
    T = enc_len or cfg.max_source_positions
    L, Hkv, Dh = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
    dt = cfg.activation_dtype
    return {
        "k": jax.ShapeDtypeStruct((L, batch, max_len, Hkv, Dh), dt),
        "v": jax.ShapeDtypeStruct((L, batch, max_len, Hkv, Dh), dt),
        "pos": jax.ShapeDtypeStruct((batch, max_len), jnp.int32),
        "cross_k": jax.ShapeDtypeStruct((L, batch, T, Hkv, Dh), dt),
        "cross_v": jax.ShapeDtypeStruct((L, batch, T, Hkv, Dh), dt),
    }


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               enc_len: int | None = None) -> dict:
    sh = init_cache_schema(cfg, batch, max_len, enc_len)
    out = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), sh)
    out["pos"] = jnp.full(sh["pos"].shape, INVALID_POS, jnp.int32)
    return out


def decode_step(cfg: ModelConfig, params, cache, token, pos):
    x = embed(cfg, params, token[:, None])
    B = token.shape[0]
    T = cache["cross_k"].shape[2]
    enc_positions = jnp.broadcast_to(
        jnp.arange(T, dtype=jnp.int32)[None], (B, T))

    def scan_fn(carry, xs):
        x, cpos = carry
        lp, ck, cv, xk, xv = xs
        h, nk, nv, npos = decode_attention_block(
            cfg, lp["attn"], rms_norm(x, lp["attn_norm"]), pos, ck, cv, cpos
        )
        x = x + h
        x = x + _cross_attention(
            cfg, lp["cross"], rms_norm(x, lp["cross_norm"]), xk, xv,
            enc_positions,
        )
        x = x + glu_mlp(lp["mlp"], rms_norm(x, lp["mlp_norm"]))
        return (x, npos), (nk, nv)

    (x, npos), (nk, nv) = lax.scan(
        scan_fn, (x, cache["pos"]),
        (params["decoder"], cache["k"], cache["v"], cache["cross_k"],
         cache["cross_v"]),
    )
    x = rms_norm(x, params["final_norm"])
    logits = unembed(cfg, params, x)[:, 0]
    new_cache = dict(cache)
    new_cache.update(k=nk, v=nv, pos=npos)
    return logits, new_cache


def prefill(cfg: ModelConfig, params, tokens, frames, max_len: int):
    x, kvs, enc_out = forward(cfg, params, tokens, frames, collect_kv=True)
    k, v = kvs
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    pad = max_len - S
    cache = {
        "k": jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
        "v": jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
        "pos": jnp.pad(positions, ((0, 0), (0, pad)),
                       constant_values=INVALID_POS),
    }
    # per-layer cross k/v from the encoder output
    cks, cvs = [], []
    L = cfg.num_layers
    cross = params["decoder"]["cross"]
    ck = jax.vmap(lambda w: jnp.einsum("bsd,dhk->bshk", enc_out, w))(
        cross["wk"])
    cv = jax.vmap(lambda w: jnp.einsum("bsd,dhk->bshk", enc_out, w))(
        cross["wv"])
    cache["cross_k"], cache["cross_v"] = ck, cv
    logits = unembed(cfg, params, x[:, -1:])[:, 0]
    return logits, cache
