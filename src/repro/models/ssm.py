"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) in pure JAX.

The chunked SSD algorithm: within a chunk of length Q the recurrence is
evaluated as a masked quadratic form (duality with attention); across chunks
a short ``lax.scan`` carries the [H, N, P] state.  Decode is the O(1)
recurrent update.  The Pallas kernel (kernels/ssd_scan) implements the same
chunk math with explicit VMEM tiling and is validated against this module.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig
from .layers import rms_norm
from .params import ParamSpec
from ..sharding import shard as _shard


# ---------------------------------------------------------------------------
# schema
# ---------------------------------------------------------------------------


def ssm_layer_schema(cfg: ModelConfig) -> dict:
    dt = cfg.param_dtype
    d, di, ds = cfg.d_model, cfg.d_inner, cfg.ssm_state
    nh, g, w = cfg.ssm_heads, cfg.ssm_groups, cfg.ssm_conv_width
    bc_dim = 2 * g * ds
    # separate z / x / BC / dt projections: the fused [d, 2*di+2*g*ds+nh]
    # projection would make every split a slice across a model-sharded dim
    # (shard-boundary crossing -> collective-permute storms); split along
    # the natural boundaries instead: z/x shard on "mlp", the small BC and
    # dt replicate.
    return {
        "norm": ParamSpec((d,), (None,), "ones", dt),
        "in_proj_z": ParamSpec((d, di), ("fsdp", "mlp"), "scaled", dt),
        "in_proj_x": ParamSpec((d, di), ("fsdp", "mlp"), "scaled", dt),
        "in_proj_bc": ParamSpec((d, bc_dim), ("fsdp", None), "scaled", dt),
        "in_proj_dt": ParamSpec((d, nh), ("fsdp", None), "scaled", dt),
        "conv_w_x": ParamSpec((w, di), (None, "mlp"), "scaled", dt),
        "conv_b_x": ParamSpec((di,), ("mlp",), "zeros", dt),
        "conv_w_bc": ParamSpec((w, bc_dim), (None, None), "scaled", dt),
        "conv_b_bc": ParamSpec((bc_dim,), (None,), "zeros", dt),
        "A_log": ParamSpec((nh,), ("heads",), "zeros", "float32"),
        "D": ParamSpec((nh,), ("heads",), "ones", "float32"),
        "dt_bias": ParamSpec((nh,), ("heads",), "zeros", "float32"),
        "gate_norm": ParamSpec((di,), ("mlp",), "ones", dt),
        "out_proj": ParamSpec((di, d), ("mlp", "fsdp"), "scaled", dt),
    }


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------


def _segsum(a):
    """a: [..., Q, H] within-chunk log-decays -> [..., Q, Q, H] lower-
    triangular cumulative sums L[i, j] = sum_{j < t <= i} a_t (i >= j).

    Note L[i, i] = 0 (the diagonal contributes x_i itself) and entries above
    the diagonal are -inf (causal)."""
    Q = a.shape[-2]
    cum = jnp.cumsum(a, axis=-2)
    seg = cum[..., :, None, :] - cum[..., None, :, :]
    i = jnp.arange(Q)[:, None]
    j = jnp.arange(Q)[None, :]
    mask = (i >= j)[..., None]
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, *, chunk: int, initial_state=None):
    """Chunked SSD scan.

    x: [B, S, H, P] head inputs; dt: [B, S, H] (softplus-ed step sizes);
    A: [H] (negative); B, C: [B, S, G, N] (G groups broadcast over heads).
    Returns (y [B, S, H, P], final_state [B, H, N, P]).
    """
    Bb, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    Q = min(chunk, S)
    assert S % Q == 0, f"seq {S} not divisible by chunk {Q}"
    nc = S // Q

    f32 = jnp.float32
    hg = H // G  # heads per group
    a = (dt * A[None, None, :]).astype(f32)                  # [B,S,H] (<= 0)

    # one chunk at a time (sequential scan carrying the [B,G,hg,N,P] state):
    # the vectorized-over-chunks form materializes [B,nc,Q,Q,H] decay/score
    # tensors — gigabytes at prefill_32k — whereas per-chunk the working set
    # is [B,Q,Q,H].  B/C stay in their [.., G, N] group form (no head-repeat
    # materialization); the chunk step is checkpointed so the backward
    # recomputes its intermediates (same trade as flash attention).
    xs = (
        jnp.moveaxis(x.reshape(Bb, nc, Q, G, hg, P), 1, 0),
        jnp.moveaxis(dt.astype(f32).reshape(Bb, nc, Q, G, hg), 1, 0),
        jnp.moveaxis(a.reshape(Bb, nc, Q, G, hg), 1, 0),
        jnp.moveaxis(B.astype(f32).reshape(Bb, nc, Q, G, N), 1, 0),
        jnp.moveaxis(C.astype(f32).reshape(Bb, nc, Q, G, N), 1, 0),
    )
    s0 = (
        jnp.zeros((Bb, G, hg, N, P), f32)
        if initial_state is None
        else initial_state.reshape(Bb, G, hg, N, P).astype(f32)
    )

    def step(h, xs_i):
        xc, dtc, ac, Bc, Cc = xs_i
        # xc [B,Q,G,hg,P]; dtc/ac [B,Q,G,hg]; Bc/Cc [B,Q,G,N]
        xdt = xc.astype(f32) * dtc[..., None]
        cum = jnp.cumsum(ac, axis=1)                         # [B,Q,G,hg]
        total = cum[:, -1]                                   # [B,G,hg]
        seg = cum[:, :, None] - cum[:, None]                 # [B,Q,Q,G,hg]
        qi = jnp.arange(seg.shape[1])
        causal = (qi[:, None] >= qi[None, :])[None, :, :, None, None]
        L = jnp.exp(jnp.where(causal, seg, -jnp.inf))
        CB = jnp.einsum("bqgn,bkgn->bqkg", Cc, Bc)           # [B,Q,Q,G]
        y_intra = jnp.einsum(
            "bqkg,bqkgh,bkghp->bqghp", CB, L, xdt
        )
        y_inter = jnp.einsum(
            "bqgn,bghnp,bqgh->bqghp", Cc, h, jnp.exp(cum)
        )
        decay_to_end = jnp.exp(total[:, None] - cum)         # [B,Q,G,hg]
        st = jnp.einsum("bqgh,bqgn,bqghp->bghnp", decay_to_end, Bc, xdt)
        h_new = h * jnp.exp(total)[..., None, None] + st
        return h_new, (y_intra + y_inter).astype(x.dtype)

    final, ys = lax.scan(jax.checkpoint(step), s0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(Bb, S, H, P)
    return y, final.reshape(Bb, H, N, P)


def ssd_step(state, x_t, dt_t, A, B_t, C_t):
    """O(1) decode update.  state: [B,H,N,P]; x_t: [B,H,P]; dt_t: [B,H];
    B_t, C_t: [B,G,N].  Returns (y [B,H,P], new_state)."""
    H = x_t.shape[1]
    G = B_t.shape[1]
    rep = H // G
    f32 = jnp.float32
    Bh = jnp.repeat(B_t, rep, axis=1).astype(f32)            # [B,H,N]
    Ch = jnp.repeat(C_t, rep, axis=1).astype(f32)
    da = jnp.exp((dt_t * A[None, :]).astype(f32))            # [B,H]
    upd = jnp.einsum("bhn,bhp->bhnp", Bh, (x_t * dt_t[..., None]).astype(f32))
    new_state = state * da[..., None, None] + upd
    y = jnp.einsum("bhn,bhnp->bhp", Ch, new_state)
    return y.astype(x_t.dtype), new_state


# ---------------------------------------------------------------------------
# conv1d (causal depthwise)
# ---------------------------------------------------------------------------


def causal_conv1d(x, w, b):
    """x: [B, S, C]; w: [W, C] depthwise; left-padded causal conv."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = lax.conv_general_dilated(
        xp.astype(jnp.float32),
        w[:, None, :].astype(jnp.float32),     # [W, 1, C] HIO
        window_strides=(1,),
        padding="VALID",
        dimension_numbers=("NHC", "HIO", "NHC"),
        feature_group_count=w.shape[1],
    )
    return (out + b).astype(x.dtype)


def conv1d_step(conv_cache, x_t, w, b):
    """conv_cache: [B, W-1, C]; x_t: [B, C].  Returns (y [B, C], new_cache)."""
    W = w.shape[0]
    full = jnp.concatenate([conv_cache, x_t[:, None]], axis=1)  # [B, W, C]
    y = jnp.einsum("bwc,wc->bc", full.astype(jnp.float32),
                   w.astype(jnp.float32)) + b
    return y.astype(x_t.dtype), full[:, 1:]


# ---------------------------------------------------------------------------
# the Mamba-2 block
# ---------------------------------------------------------------------------


def mamba_block(cfg: ModelConfig, p, x, *, initial_state=None):
    """Full-sequence Mamba-2 block.  x: [B, S, d] (pre-normed by caller).
    Returns (y, ((conv_tail_x, conv_tail_bc) [B, W-1, .], final_state))."""
    Bb, S, _ = x.shape
    di, ds, g, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_groups, cfg.ssm_heads
    hd = cfg.ssm_head_dim
    z = _shard(jnp.einsum("bsd,dp->bsp", x, p["in_proj_z"]),
               ("batch", None, "mlp"))
    xi = _shard(jnp.einsum("bsd,dp->bsp", x, p["in_proj_x"]),
                ("batch", None, "mlp"))
    bc = jnp.einsum("bsd,dp->bsp", x, p["in_proj_bc"])
    dt = jnp.einsum("bsd,dp->bsp", x, p["in_proj_dt"])
    t0 = max(S - (cfg.ssm_conv_width - 1), 0)
    conv_tail = (xi[:, t0:], bc[:, t0:])
    xi = jax.nn.silu(causal_conv1d(xi, p["conv_w_x"], p["conv_b_x"]))
    bc = jax.nn.silu(causal_conv1d(bc, p["conv_w_bc"], p["conv_b_bc"]))
    x_ssm = xi.reshape(Bb, S, nh, hd)
    Bmat = bc[..., : g * ds].reshape(Bb, S, g, ds)
    Cmat = bc[..., g * ds :].reshape(Bb, S, g, ds)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, final_state = ssd_chunked(
        x_ssm, dt, A, Bmat, Cmat, chunk=cfg.ssm_chunk,
        initial_state=initial_state,
    )
    y = y + p["D"][None, None, :, None].astype(y.dtype) * x_ssm
    y = y.reshape(Bb, S, di)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"])
    out = jnp.einsum("bsd,dp->bsp", y, p["out_proj"])
    return _shard(out, ("batch", None, None)), (conv_tail, final_state)


def mamba_decode_block(cfg: ModelConfig, p, x_t, conv_cache, state):
    """Single-token decode.  x_t: [B, d]; conv_cache: (x [B,W-1,di],
    bc [B,W-1,2*g*ds]); state: [B, H, N, P]."""
    di, ds, g, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_groups, cfg.ssm_heads
    hd = cfg.ssm_head_dim
    conv_x, conv_bc = conv_cache
    z = jnp.einsum("bd,dp->bp", x_t, p["in_proj_z"])
    xi = jnp.einsum("bd,dp->bp", x_t, p["in_proj_x"])
    bc = jnp.einsum("bd,dp->bp", x_t, p["in_proj_bc"])
    dt = jnp.einsum("bd,dp->bp", x_t, p["in_proj_dt"])
    xi, new_conv_x = conv1d_step(conv_x, xi, p["conv_w_x"], p["conv_b_x"])
    bc, new_conv_bc = conv1d_step(conv_bc, bc, p["conv_w_bc"], p["conv_b_bc"])
    xi = jax.nn.silu(xi)
    bc = jax.nn.silu(bc)
    x_ssm = xi.reshape(-1, nh, hd)
    Bmat = bc[..., : g * ds].reshape(-1, g, ds)
    Cmat = bc[..., g * ds :].reshape(-1, g, ds)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, new_state = ssd_step(state, x_ssm, dt, A, Bmat, Cmat)
    y = y + p["D"][None, :, None].astype(y.dtype) * x_ssm
    y = y.reshape(-1, di)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"])
    return (jnp.einsum("bd,dp->bp", y, p["out_proj"]),
            (new_conv_x, new_conv_bc), new_state)
