"""Sharded, async, elastic checkpointing.

The log-based rollback-recovery analogue for the training plane (paper
§3.6): model/optimizer state is materialized periodically; the data
pipeline stores its replay offset; a restart restores the latest complete
step and replays.

* layout: ``<dir>/step_<n>/<flat.leaf.path>.npy`` + ``manifest.json``
  (tree structure, dtypes, step, data-pipeline state, mesh shape);
* **async**: ``save()`` snapshots to host (device_get) and hands the disk
  write to a background thread — the train loop continues;
* **elastic**: arrays are stored unsharded (global view), so a restore may
  target a *different* mesh: ``restore(..., shardings=...)`` device_puts
  each leaf with the new sharding.  This is what lets a 512-chip job resume
  on 448 chips after losing a pod slice.

The same plane also carries the streaming runtime's keyed-state handoff
(``pack_keyed_state``/``unpack_keyed_state``): when elastic rescaling moves
key ranges between subtasks (core/routing.py), the moved entries travel as
one serialized blob with a small manifest — the in-memory analogue of a
checkpoint step dir.  These helpers are pure stdlib; jax is imported lazily
so the streaming core can use them without pulling in the accelerator stack.
"""
from __future__ import annotations

import json
import pickle
import shutil
import threading
from pathlib import Path

# Back-compat re-export: the keyed-state handoff codec moved to the
# stdlib-only state_codec module so the streaming rescale hot path never
# pays this module's numpy import.
from .state_codec import (  # noqa: F401
    KEYED_STATE_VERSION,
    pack_keyed_state,
    unpack_keyed_state,
)


def _flatten(tree):
    import jax

    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path
        )
        out[key] = leaf
    return out, treedef


class Checkpointer:
    def __init__(self, directory: str | Path, keep: int = 3,
                 checkpoint_interval_ms: float | None = None) -> None:
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        #: periodic-checkpoint cadence for the streaming backends; None
        #: keeps the historical behaviour (checkpoints only at explicit
        #: rescale/recovery points).  Both executors poll ``due(now_ms)``
        #: from their control tick.
        self.interval_ms = checkpoint_interval_ms
        self._next_due_ms: float | None = None
        self._stream_lock = threading.Lock()
        self._thread: threading.Thread | None = None
        # a crash mid-save leaves a step_<n>.tmp staging dir behind; it holds
        # no complete checkpoint, so it is safe (and required) to discard
        for p in self.dir.glob("step_*.tmp"):
            if p.is_dir():
                shutil.rmtree(p, ignore_errors=True)
        for p in self.dir.glob("stream_*.tmp"):
            p.unlink(missing_ok=True)

    # -- streaming checkpoints (stdlib-only; both stream backends) ------------
    # The streaming runtime's periodic snapshot is a single pickled payload
    # (source offsets + per-stage packed keyed state, built by
    # RuntimeRewirer._stream_checkpoint_payload).  Kept deliberately apart
    # from the jax ``save``/``restore`` path: taking one must never import
    # the accelerator stack, and a training step dir must never be confused
    # with a stream snapshot.  Retention is keep-last-k, same as steps.

    def due(self, now_ms: float) -> bool:
        """True when the periodic cadence says a stream checkpoint should be
        taken at ``now_ms`` (first one lands one full interval in, so a
        freshly started job is never checkpointed empty)."""
        if self.interval_ms is None:
            return False
        if self._next_due_ms is None:
            self._next_due_ms = now_ms + self.interval_ms
            return False
        return now_ms >= self._next_due_ms

    def save_stream(self, at_ms: float, payload: dict) -> Path:
        """Persist one streaming snapshot atomically (tmp + rename) and GC
        to the last ``keep`` snapshots.  Synchronous on purpose: payloads
        are small (packed keyed state + offsets) and the recovery path must
        never race a half-written latest snapshot."""
        with self._stream_lock:
            n = (max(self.stream_ids()) + 1) if self.stream_ids() else 1
            tmp = self.dir / f"stream_{n:08d}.tmp"
            final = self.dir / f"stream_{n:08d}.pkl"
            tmp.write_bytes(pickle.dumps({"at_ms": at_ms, **payload}))
            tmp.rename(final)
            self._next_due_ms = at_ms + (self.interval_ms or 0.0)
            for old in self.stream_ids()[: -self.keep]:
                (self.dir / f"stream_{old:08d}.pkl").unlink(missing_ok=True)
            return final

    def stream_ids(self) -> list[int]:
        out = []
        for p in self.dir.glob("stream_*.pkl"):
            suffix = p.name[len("stream_"):-len(".pkl")]
            if suffix.isdigit():
                out.append(int(suffix))
        return sorted(out)

    def latest_stream(self) -> dict | None:
        """The most recent complete streaming snapshot, or None."""
        ids = self.stream_ids()
        if not ids:
            return None
        raw = (self.dir / f"stream_{ids[-1]:08d}.pkl").read_bytes()
        return pickle.loads(raw)

    # -- save -----------------------------------------------------------------
    def save(self, step: int, state: dict, extra: dict | None = None,
             blocking: bool = False) -> None:
        """state: pytree (params/opt_state/...); extra: JSON-serializable
        (e.g. data-pipeline replay offset)."""
        import jax
        import numpy as np

        flat, _ = _flatten(state)

        def to_host(v):
            a = np.asarray(jax.device_get(v))
            # np.save round-trips only native numeric kinds; extension
            # dtypes (ml_dtypes bfloat16/f8, kind 'V') are widened to f32
            # and cast back on restore from the leaf dtype
            if a.dtype.kind not in "fiub?" or a.dtype.name == "bfloat16":
                a = a.astype(np.float32)
            return a

        host = {k: to_host(v) for k, v in flat.items()}
        self.wait()

        def write() -> None:
            tmp = self.dir / f"step_{step}.tmp"
            final = self.dir / f"step_{step}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            for k, v in host.items():
                np.save(tmp / (k.replace("/", ".") + ".npy"), v)
            manifest = {
                "step": step,
                "keys": list(host.keys()),
                "dtypes": {k: str(v.dtype) for k, v in host.items()},
                "extra": extra or {},
            }
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)  # atomic completion marker
            self._gc()

        self._thread = threading.Thread(target=write, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(self.steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            suffix = p.name.split("_", 1)[1]
            # skip in-flight/stale staging dirs ("10.tmp") and any other
            # non-numeric suffix — only committed step dirs count
            if not suffix.isdigit():
                continue
            if p.is_dir() and (p / "manifest.json").exists():
                out.append(int(suffix))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.steps()
        return steps[-1] if steps else None

    def restore(self, state_like, step: int | None = None,
                shardings=None) -> tuple[dict, int, dict]:
        """Restore into the structure of ``state_like`` (a pytree of arrays
        or ShapeDtypeStructs).  ``shardings``: matching pytree of
        NamedShardings for elastic placement on the *current* mesh."""
        import jax
        import numpy as np

        self.wait()  # an async save may still be staging the latest step
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self.dir / f"step_{step}"
        manifest = json.loads((d / "manifest.json").read_text())
        flat, treedef = _flatten(state_like)
        sflat = None
        if shardings is not None:
            sflat, _ = _flatten(shardings)
        out = {}
        for k, leaf in flat.items():
            arr = np.load(d / (k.replace("/", ".") + ".npy"))
            arr = jax.numpy.asarray(arr).astype(leaf.dtype)
            if sflat is not None and k in sflat:
                out[k] = jax.device_put(arr, sflat[k])
            else:
                out[k] = arr
        leaves = [out[k] for k in flat.keys()]
        return (
            jax.tree_util.tree_unflatten(treedef, leaves),
            manifest["step"],
            manifest.get("extra", {}),
        )
