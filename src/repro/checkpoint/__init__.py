"""Checkpointing.

``pack_keyed_state``/``unpack_keyed_state`` live in the stdlib-only
``state_codec`` module so the streaming runtime's rescale hot path can use
them without importing numpy; ``Checkpointer`` (the training-plane
array checkpointer) is resolved lazily for the same reason (PEP 562).
"""

from .state_codec import (  # noqa: F401
    pack_keyed_state,
    unpack_keyed_state,
)

__all__ = ["Checkpointer", "pack_keyed_state", "unpack_keyed_state"]


def __getattr__(name: str):
    if name == "Checkpointer":
        from .checkpointer import Checkpointer
        return Checkpointer
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
