"""Checkpointing."""

from .checkpointer import (  # noqa: F401
    Checkpointer,
    pack_keyed_state,
    unpack_keyed_state,
)
