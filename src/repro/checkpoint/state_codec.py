"""Keyed-state handoff codec (streaming runtime <-> checkpoint plane).

When elastic rescaling moves key ranges between subtasks (core/routing.py),
the moved entries travel as one serialized blob with a small manifest — the
in-memory analogue of a checkpoint step dir.  Pure stdlib on purpose: the
live re-wiring layer (core/elastic.py) runs this on the rescale hot path,
and importing it must NOT pull in the accelerator stack (numpy/jax) — the
pre-PR-4 placement inside checkpointer.py stalled the FIRST live rescale of
every run by ~0.3 s of lazy numpy import.  checkpointer.py re-exports these
helpers for back-compat.
"""
from __future__ import annotations

import pickle

#: keyed-state handoff blob format version (manifest field).
KEYED_STATE_VERSION = 1


def pack_keyed_state(entries: dict, meta: dict | None = None) -> bytes:
    """Serialize per-key state entries for a migration handoff.  The blob is
    self-describing (version + key manifest + optional meta such as the
    source subtask and moved ranges) so a receiver can validate it."""
    payload = {
        "version": KEYED_STATE_VERSION,
        "meta": dict(meta or {}),
        "keys": list(entries.keys()),
        "entries": dict(entries),
    }
    return pickle.dumps(payload)


def unpack_keyed_state(blob: bytes) -> dict:
    """Deserialize a ``pack_keyed_state`` blob back into its entries."""
    payload = pickle.loads(blob)
    version = payload.get("version")
    if version != KEYED_STATE_VERSION:
        raise ValueError(f"unsupported keyed-state blob version {version!r}")
    entries = payload["entries"]
    if set(payload["keys"]) != set(entries.keys()):
        raise ValueError("keyed-state blob manifest does not match entries")
    return entries
