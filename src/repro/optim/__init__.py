"""Optimizers (raw JAX — no optax in this environment)."""

from .adamw import adamw  # noqa: F401
from .adafactor import adafactor  # noqa: F401
from .base import Optimizer, apply_updates, global_norm, clip_by_global_norm  # noqa: F401
from .schedules import cosine_schedule, linear_warmup  # noqa: F401
from .compression import compress_int8, decompress_int8, topk_sparsify  # noqa: F401


def build_optimizer(name: str, lr, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(lr, **kw)
    if name == "adafactor":
        return adafactor(lr, **kw)
    raise ValueError(f"unknown optimizer {name!r}")
