"""Adafactor (Shazeer & Stern, arXiv:1804.04235): factored second moments.

For an [n, m] matrix the second-moment estimate is stored as a row vector
[n] + column vector [m] instead of [n, m] — optimizer state is O(n+m).
This is what lets llama3-405b / dbrx-132b fit the 16 GB/chip HBM budget on
256 chips (see configs).  First moment is optional (disabled by default,
like the paper's recommended setting)."""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from .base import Optimizer


def adafactor(
    lr: float | Callable[[jnp.ndarray], jnp.ndarray],
    decay: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
    min_dim_size_to_factor: int = 128,
) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: jnp.float32(lr))

    def factored(shape) -> bool:
        return (
            len(shape) >= 2
            and shape[-1] >= min_dim_size_to_factor
            and shape[-2] >= min_dim_size_to_factor
        )

    def init(params):
        def z(p):
            if factored(p.shape):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {"v": jax.tree.map(z, params), "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, step=None):
        step = state["step"] + 1 if step is None else step
        t = step.astype(jnp.float32)
        beta = 1.0 - t ** (-decay)

        def upd(g, s, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if "vr" in s:
                vr = beta * s["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * s["vc"] + (1 - beta) * g2.mean(axis=-2)
                denom = (
                    vr[..., :, None]
                    / vr.mean(axis=-1, keepdims=True)[..., :, None]
                ) * vc[..., None, :]
                u = g * jax.lax.rsqrt(denom + eps)
                ns = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(v + eps)
                ns = {"v": v}
            # update clipping (RMS-based, per the paper)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            u = -lr_fn(step) * (u + weight_decay * p.astype(jnp.float32))
            return u, ns

        gl, treedef = jax.tree.flatten(grads)
        sl = treedef.flatten_up_to(state["v"])
        pl = treedef.flatten_up_to(params)
        outs = [upd(g, s, p) for g, s, p in zip(gl, sl, pl)]
        return (
            jax.tree.unflatten(treedef, [u for u, _ in outs]),
            {"v": jax.tree.unflatten(treedef, [s for _, s in outs]),
             "step": step},
        )

    return Optimizer(init, update)
