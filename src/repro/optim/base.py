"""Minimal functional optimizer interface (optax-style, self-contained)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    """init(params) -> state;  update(grads, state, params, step) ->
    (updates, new_state).  Updates are *added* to params."""

    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jnp.ndarray], tuple[Any, Any]]


def apply_updates(params, updates):
    return jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) + u.astype(jnp.float32)).astype(
            p.dtype
        ),
        params,
        updates,
    )


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.asarray(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype),
                        tree), norm
