"""Gradient compression for the DP/FSDP all-reduce traffic.

Two standard schemes, usable as drop-ins around the gradient collective
(launch/train.py wires them behind ``--grad-compression``):

* int8 quantization with per-tensor scale (4x traffic reduction vs fp32,
  2x vs bf16) — unbiased stochastic rounding omitted for determinism;
* top-k sparsification with error feedback (Deep Gradient Compression,
  arXiv:1712.01887 style): only the k largest-magnitude entries are
  exchanged, the residual is carried into the next step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_int8(x):
    """x -> (int8 values, fp32 scale)."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q, scale, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def topk_sparsify(x, frac: float, error: jnp.ndarray | None = None):
    """Keep the top ``frac`` fraction of entries (by magnitude); returns
    (sparse_dense_tensor, new_error).  Error feedback accumulates what was
    dropped so the compression is unbiased over time."""
    x32 = x.astype(jnp.float32)
    if error is not None:
        x32 = x32 + error
    flat = x32.reshape(-1)
    k = max(1, int(flat.size * frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    mask = jnp.abs(x32) >= thresh
    kept = jnp.where(mask, x32, 0.0)
    new_error = x32 - kept
    return kept.astype(x.dtype), new_error
