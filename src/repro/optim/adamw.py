"""AdamW with configurable state dtype (bf16 moments shrink the FSDP
optimizer-state footprint by 3x vs fp32 — relevant at 100B+ scale)."""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from .base import Optimizer


def adamw(
    lr: float | Callable[[jnp.ndarray], jnp.ndarray],
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    state_dtype: str | None = "float32",
) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: jnp.float32(lr))
    sd = jnp.dtype(state_dtype) if state_dtype else None

    def init(params):
        def z(p):
            dt = sd or p.dtype
            return {"m": jnp.zeros(p.shape, dt), "v": jnp.zeros(p.shape, dt)}

        return {
            "mu": jax.tree.map(z, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, step=None):
        step = state["step"] + 1 if step is None else step

        def upd(g, s, p):
            g = g.astype(jnp.float32)
            m = s["m"].astype(jnp.float32) * b1 + g * (1 - b1)
            v = s["v"].astype(jnp.float32) * b2 + jnp.square(g) * (1 - b2)
            mh = m / (1 - b1 ** step.astype(jnp.float32))
            vh = v / (1 - b2 ** step.astype(jnp.float32))
            u = -lr_fn(step) * (
                mh / (jnp.sqrt(vh) + eps)
                + weight_decay * p.astype(jnp.float32)
            )
            return u, {"m": m.astype(s["m"].dtype), "v": v.astype(s["v"].dtype)}

        flat_u, flat_s = [], []
        gl, treedef = jax.tree.flatten(grads)
        sl = treedef.flatten_up_to(state["mu"])
        pl = treedef.flatten_up_to(params)
        for g, s, p in zip(gl, sl, pl):
            u, ns = upd(g, s, p)
            flat_u.append(u)
            flat_s.append(ns)
        return (
            jax.tree.unflatten(treedef, flat_u),
            {"mu": jax.tree.unflatten(treedef, flat_s), "step": step},
        )

    return Optimizer(init, update)
