"""Logical-axis sharding: models annotate activations/params with *logical*
axis names; the launcher installs a rule set mapping logical names to mesh
axes.  Outside a mesh/rules context the annotations are no-ops, so the same
model code runs in single-device smoke tests and 512-chip dry-runs.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

# Default logical->mesh rules for the production mesh
# ("pod", "data", "model") / ("data", "model").
DEFAULT_RULES: dict[str, tuple[str, ...] | str | None] = {
    "batch": ("pod", "data"),   # batch dim: DP/FSDP axes
    "fsdp": ("pod", "data"),    # param dim sharded for FSDP
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "vocab": "model",
    "expert": "model",
    "seq": "model",             # sequence sharding (activations)
    "attn_seq": None,           # row-parallel attention (heads indivisible)
    "layers": None,
}


def _rules() -> dict | None:
    return getattr(_state, "rules", None)


def _mesh() -> Mesh | None:
    m = getattr(_state, "mesh", None)
    if m is False:  # suspended (shard_map-local tracing)
        return None
    if m is not None:
        return m
    # fall back to ambient mesh from `with mesh:` context
    env = jax.interpreters.pxla.thread_resources.env
    phys = getattr(env, "physical_mesh", None)
    if phys is not None and not phys.empty:
        return phys
    return None


@contextmanager
def suspend_sharding_rules():
    """Disable logical sharding constraints while tracing shard_map-local
    code (with_sharding_constraint does not apply to per-shard arrays)."""
    old_rules = getattr(_state, "rules", None)
    old_mesh = getattr(_state, "mesh", None)
    _state.rules = None
    _state.mesh = False  # sentinel: also blocks the ambient-mesh fallback
    try:
        yield
    finally:
        _state.rules = old_rules
        _state.mesh = old_mesh


@contextmanager
def use_sharding_rules(rules: dict, mesh: Mesh | None = None):
    old_rules = getattr(_state, "rules", None)
    old_mesh = getattr(_state, "mesh", None)
    _state.rules = rules
    _state.mesh = mesh
    try:
        yield
    finally:
        _state.rules = old_rules
        _state.mesh = old_mesh


def resolve_spec(axes: tuple[str | None, ...], rules: dict | None = None,
                 mesh: Mesh | None = None) -> P:
    """Map logical axis names to a PartitionSpec under ``rules``, dropping
    mesh axes that do not exist in the current mesh."""
    rules = rules if rules is not None else (_rules() or {})
    mesh = mesh if mesh is not None else _mesh()
    mesh_axes = set(mesh.axis_names) if mesh is not None else set()
    out = []
    for ax in axes:
        m = rules.get(ax) if ax is not None else None
        if m is None:
            out.append(None)
            continue
        if isinstance(m, str):
            out.append(m if m in mesh_axes else None)
        else:  # tuple of mesh axes
            kept = tuple(a for a in m if a in mesh_axes)
            out.append(kept if kept else None)
    return P(*out)


def shard(x, axes: tuple[str | None, ...]):
    """with_sharding_constraint by logical axes; no-op without rules/mesh.

    Divisibility-safe: a dim that does not divide its mapped mesh axes is
    left unsharded (e.g. the seq axis of a single decode token)."""
    rules = _rules()
    mesh = _mesh()
    if rules is None or mesh is None:
        return x
    spec = resolve_spec(axes, rules, mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    safe = []
    for dim, entry in zip(x.shape, spec):
        if entry is None:
            safe.append(None)
            continue
        names = (entry,) if isinstance(entry, str) else tuple(entry)
        n = 1
        for a in names:
            n *= sizes.get(a, 1)
        safe.append(entry if (n and dim % n == 0) else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*safe)))


def named_sharding(mesh: Mesh, axes: tuple[str | None, ...],
                   rules: dict | None = None) -> NamedSharding:
    return NamedSharding(mesh, resolve_spec(axes, rules or DEFAULT_RULES, mesh))
