"""Fault tolerance, straggler mitigation, elastic scaling.

Paper §3.6 establishes how the QoS optimizations coexist with log-based
rollback-recovery; this module is the training-plane counterpart:

* ``HeartbeatMonitor``  — per-worker liveness with timeout-based failure
  detection (the master-side machinery that decides a restart is needed);
  lives in ``core/liveness.py`` since PR 9 so the streaming backends share
  the exact same detector — re-exported here for back-compat,
* ``StragglerDetector`` — reuses the paper's latency-measurement machinery:
  a worker whose recent step/stage latency is a large multiple of the fleet
  median is flagged; mitigation hook = evict + re-dispatch,
* ``ElasticPolicy``     — picks the next mesh after losing devices (shrink
  the DP axis, never the model axis, so parameter layouts survive),
* ``TrainingSupervisor``— restart loop: on failure, restore the latest
  checkpoint (elastic re-shard via Checkpointer) and resume; the data
  pipeline replays from the recorded offset.
"""
from __future__ import annotations

import statistics
from dataclasses import dataclass

from ..core.liveness import HeartbeatMonitor  # noqa: F401  (back-compat)


class StragglerDetector:
    """Flag workers whose recent latency is > factor x fleet median.

    The measurement feed is the same per-element latency data the QoS
    reporters collect (§3.3) — stragglers are just a different consumer of
    the same telemetry."""

    def __init__(self, factor: float = 3.0, min_samples: int = 5) -> None:
        self.factor = factor
        self.min_samples = min_samples
        self._lat: dict[int, list[float]] = {}

    def record(self, worker: int, latency_ms: float) -> None:
        self._lat.setdefault(worker, []).append(latency_ms)
        if len(self._lat[worker]) > 50:
            self._lat[worker] = self._lat[worker][-50:]

    def stragglers(self) -> list[int]:
        recent = {
            w: statistics.median(xs[-self.min_samples:])
            for w, xs in self._lat.items()
            if len(xs) >= self.min_samples
        }
        if len(recent) < 2:
            return []
        med = statistics.median(recent.values())
        return [w for w, v in recent.items() if v > self.factor * med]


@dataclass
class ElasticPolicy:
    """Next mesh shape after device loss: shrink the data axis (batch
    re-balances; parameter TP layout on "model" is preserved)."""

    model_axis: int = 16

    def next_shape(self, devices_left: int) -> tuple[int, int] | None:
        data = devices_left // self.model_axis
        if data < 1:
            return None
        return (data, self.model_axis)


@dataclass
class RestartEvent:
    at_step: int
    reason: str
    devices_left: int | None = None


class TrainingSupervisor:
    """Wraps a step function with checkpoint/restart + failure injection
    hooks (tests inject failures; real deployments wire the heartbeat
    monitor)."""

    def __init__(self, checkpointer, save_every: int = 50,
                 max_restarts: int = 10) -> None:
        self.ckpt = checkpointer
        self.save_every = save_every
        self.max_restarts = max_restarts
        self.events: list[RestartEvent] = []

    def run(self, state: dict, step_fn, num_steps: int,
            data_state_fn=None,
            fail_at: dict[int, str] | None = None,
            on_restore=None) -> tuple[dict, int]:
        """state: pytree; step_fn(state, step) -> state; returns final
        (state, completed_steps).  ``fail_at``: step -> reason (test
        injection)."""
        fail_at = dict(fail_at or {})
        step = 0
        restarts = 0
        while step < num_steps:
            try:
                if step in fail_at:
                    reason = fail_at.pop(step)
                    raise RuntimeError(f"injected failure: {reason}")
                state = step_fn(state, step)
                step += 1
                if step % self.save_every == 0 or step == num_steps:
                    extra = {"data": data_state_fn()} if data_state_fn else {}
                    self.ckpt.save(step, state, extra=extra)
            except RuntimeError as e:
                restarts += 1
                self.events.append(RestartEvent(step, str(e)))
                if restarts > self.max_restarts:
                    raise
                latest = self.ckpt.latest_step()
                if latest is None:
                    step = 0  # restart from scratch
                    continue
                state, step, extra = self.ckpt.restore(state)
                if on_restore is not None:
                    on_restore(extra)
        self.ckpt.wait()
        return state, step
