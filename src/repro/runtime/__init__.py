"""Distributed runtime: failure detection, stragglers, elastic restarts."""

from .fault_tolerance import (  # noqa: F401
    ElasticPolicy,
    HeartbeatMonitor,
    StragglerDetector,
    TrainingSupervisor,
)
