"""Mamba-2 SSD chunk scan as a Pallas TPU kernel.

TPU-native adaptation of the SSD algorithm (arXiv:2405.21060 §6): the GPU
implementation leans on warp-level parallel prefix sums; on TPU we instead
tile so that each grid step processes one (batch, head, chunk) cell entirely
in VMEM, with the [N, P] inter-chunk state carried in VMEM scratch across the
sequentially-executed chunk grid dimension.  The intra-chunk quadratic form
(duality with attention) maps onto the MXU as three [Q,*] matmuls.

Grid: (B, H, n_chunks) — chunks innermost (sequential).  Block shapes:
x [Q, P], dt/a [Q], B/C [Q, N] (the kernel reads the group's B/C row via the
index_map h -> h // (H/G), so grouped B/C are never materialized per head).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref,
                h_scr, *, n_chunks):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)     # [Q, P]
    dt = dt_ref[0, :, 0]                          # [Q]
    a = a_ref[0, :, 0]                            # [Q]
    B = b_ref[0, :, 0, :].astype(jnp.float32)     # [Q, N]
    C = c_ref[0, :, 0, :].astype(jnp.float32)     # [Q, N]
    Q = x.shape[0]

    xdt = x * dt[:, None]
    cum = jnp.cumsum(a)                           # [Q]
    total = cum[-1]
    seg = cum[:, None] - cum[None, :]             # [Q, Q]
    qi = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    ki = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(qi >= ki, jnp.exp(seg), 0.0)

    CB = jax.lax.dot_general(                     # [Q, Q]
        C, B, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    y_intra = jax.lax.dot_general(                # [Q, P]
        CB * L, xdt, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    h = h_scr[...]                                # [N, P]
    y_inter = jax.lax.dot_general(                # [Q, P]
        C, h, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ) * jnp.exp(cum)[:, None]
    y_ref[0, :, 0, :] = (y_intra + y_inter).astype(y_ref.dtype)

    decay_to_end = jnp.exp(total - cum)           # [Q]
    st = jax.lax.dot_general(                     # [N, P]
        B * decay_to_end[:, None], xdt, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    h_scr[...] = h * jnp.exp(total) + st

    @pl.when(ci == n_chunks - 1)
    def _final():
        state_ref[0, 0, :, :] = h_scr[...]


def ssd_scan(x, dt, a, B, C, *, chunk: int = 128, interpret: bool = False):
    """x: [Bb, S, H, P]; dt, a: [Bb, S, H] (a = dt*A, <= 0);
    B, C: [Bb, S, G, N].  Returns (y [Bb,S,H,P], state [Bb,H,N,P])."""
    Bb, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    n_chunks = S // Q
    grid = (Bb, H, n_chunks)

    kernel = functools.partial(_ssd_kernel, n_chunks=n_chunks)
    y, state = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda b, h, ci: (b, ci, h, 0)),
            pl.BlockSpec((1, Q, 1), lambda b, h, ci: (b, ci, h)),
            pl.BlockSpec((1, Q, 1), lambda b, h, ci: (b, ci, h)),
            pl.BlockSpec((1, Q, 1, N), lambda b, h, ci: (b, ci, h // rep, 0)),
            pl.BlockSpec((1, Q, 1, N), lambda b, h, ci: (b, ci, h // rep, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda b, h, ci: (b, ci, h, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b, h, ci: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb, S, H, P), x.dtype),
            jax.ShapeDtypeStruct((Bb, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, B, C)
    return y, state
