"""jit'd wrapper for the SSD Pallas kernel (interpret mode on CPU)."""
from __future__ import annotations

from functools import partial

import jax

from .ssd_scan import ssd_scan


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_op(x, dt, a, B, C, *, chunk: int = 128, interpret: bool = True):
    return ssd_scan(x, dt, a, B, C, chunk=chunk, interpret=interpret)
