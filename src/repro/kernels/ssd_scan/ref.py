"""Pure-jnp oracle for the SSD kernel: the naive O(S) recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def ssd_ref(x, dt, a, B, C):
    """Sequential state-space recurrence, one token at a time.

    x: [Bb, S, H, P]; dt, a: [Bb, S, H]; B, C: [Bb, S, G, N].
    h_t = exp(a_t) h_{t-1} + dt_t * B_t x_t^T;  y_t = C_t . h_t
    """
    Bb, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    Bh = jnp.repeat(B, rep, axis=2).astype(jnp.float32)
    Ch = jnp.repeat(C, rep, axis=2).astype(jnp.float32)
    xdt = (x.astype(jnp.float32) * dt[..., None])

    def step(h, xs):
        x_t, a_t, B_t, C_t = xs   # [Bb,H,P], [Bb,H], [Bb,H,N] x2
        h = h * jnp.exp(a_t)[..., None, None] + jnp.einsum(
            "bhn,bhp->bhnp", B_t, x_t
        )
        y = jnp.einsum("bhn,bhnp->bhp", C_t, h)
        return h, y

    h0 = jnp.zeros((Bb, H, N, P), jnp.float32)
    h, ys = lax.scan(
        step,
        h0,
        (
            jnp.moveaxis(xdt, 1, 0),
            jnp.moveaxis(a.astype(jnp.float32), 1, 0),
            jnp.moveaxis(Bh, 1, 0),
            jnp.moveaxis(Ch, 1, 0),
        ),
    )
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h
