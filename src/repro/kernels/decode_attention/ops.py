"""jit'd wrapper for the flash-decoding kernel (interpret mode on CPU)."""
from functools import partial

import jax
import jax.numpy as jnp

from .decode_attention import INVALID_POS, flash_decode


@partial(jax.jit, static_argnames=("window", "block_k", "interpret"))
def flash_decode_op(q, k, v, q_positions, kv_positions, *,
                    window=None, block_k: int = 512, interpret: bool = True):
    B, W = kv_positions.shape
    bk = min(block_k, W)
    pad = (-W) % bk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, ((0, 0), (0, pad)),
                               constant_values=INVALID_POS)
    return flash_decode(q, k, v, q_positions, kv_positions,
                        window=window, block_k=bk, interpret=interpret)
