"""Pure-jnp oracle for flash_decode (single-token attention over a cache)."""
from __future__ import annotations

import jax.numpy as jnp

from ..flash_attention.ref import attention_ref


def decode_ref(q, k, v, q_positions, kv_positions, *, window=None,
               softmax_scale=None):
    """q: [B, Hq, D] -> [B, Hq, D] via the prefill oracle at Sq=1."""
    out = attention_ref(
        q[:, None], k, v, q_positions[:, None], kv_positions,
        causal=True, window=window, softmax_scale=softmax_scale,
    )
    return out[:, 0]
