"""Flash-decoding (split-KV) as a Pallas TPU kernel.

Single-token decode against a long (possibly rolling) KV cache.  The GPU
flash-decoding trick is splitting the KV axis across SMs and combining
partials; the TPU adaptation tiles the KV axis across the sequential grid
dimension with the online-softmax state in VMEM scratch, and — unlike the
prefill kernel — puts **heads** (not query rows) on the MXU rows: with one
query token, the score matmul per block is [Hq, D] x [D, bk] -> [Hq, bk],
which keeps the systolic array full for Hq >= 8.

Grid: (B, W/bk).  GQA is handled in-kernel by reshaping q to
[Hkv, G, D] against the block's [bk, Hkv, D] keys.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

INVALID_POS = 2**30
NEG_INF = float(-1e30)
DEFAULT_BLOCK_K = 512


def _decode_kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, scale, window, n_kv):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                   # [Hq, D]
    k = k_ref[0]                                   # [bk, Hkv, D]
    v = v_ref[0]                                   # [bk, Hkv, D]
    qp = qpos_ref[0]                               # scalar in (1,)
    kp = kpos_ref[0, :]                            # [bk]
    Hq, D = q.shape
    bk, Hkv, _ = k.shape
    G = Hq // Hkv

    qg = q.reshape(Hkv, G, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    # scores per kv-head group: [Hkv, G, bk]
    s = jax.lax.dot_general(
        qg, kf, (((2,), (2,)), ((0,), (1,))),
        preferred_element_type=jnp.float32,
    ) * scale
    mask = kp[None, None, :] >= INVALID_POS
    mask |= kp[None, None, :] > qp
    if window is not None:
        mask |= kp[None, None, :] <= qp - window
    s = jnp.where(mask, NEG_INF, s)

    m_prev = m_scr[...].reshape(Hkv, G)
    l_prev = l_scr[...].reshape(Hkv, G)
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.where(mask, 0.0, jnp.exp(s - m_new[..., None]))
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=-1)
    pv = jax.lax.dot_general(                       # [Hkv, G, D]
        p, v.astype(jnp.float32), (((2,), (0,)), ((0,), (1,))),
    )
    acc = acc_scr[...].reshape(Hkv, G, D)
    acc_scr[...] = (acc * corr[..., None] + pv).reshape(Hq, D)
    m_scr[...] = m_new.reshape(Hq)
    l_scr[...] = l_new.reshape(Hq)

    @pl.when(ki == n_kv - 1)
    def _out():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_decode(q, k, v, q_positions, kv_positions, *,
                 window: int | None = None,
                 softmax_scale: float | None = None,
                 block_k: int = DEFAULT_BLOCK_K,
                 interpret: bool = False):
    """q: [B, Hq, D]; k, v: [B, W, Hkv, D]; q_positions: [B];
    kv_positions: [B, W].  Returns [B, Hq, D]."""
    B, Hq, D = q.shape
    _, W, Hkv, _ = k.shape
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(D)
    bk = min(block_k, W)
    assert W % bk == 0, (W, bk)
    n_kv = W // bk
    kernel = functools.partial(_decode_kernel, scale=scale, window=window,
                               n_kv=n_kv)
    return pl.pallas_call(
        kernel,
        grid=(B, n_kv),
        in_specs=[
            pl.BlockSpec((1,), lambda b, ki: (b,)),            # q pos
            pl.BlockSpec((1, bk), lambda b, ki: (b, ki)),      # kv pos
            pl.BlockSpec((1, Hq, D), lambda b, ki: (b, 0, 0)),
            pl.BlockSpec((1, bk, Hkv, D), lambda b, ki: (b, ki, 0, 0)),
            pl.BlockSpec((1, bk, Hkv, D), lambda b, ki: (b, ki, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, Hq, D), lambda b, ki: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((Hq,), jnp.float32),
            pltpu.VMEM((Hq,), jnp.float32),
            pltpu.VMEM((Hq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q_positions, kv_positions, q, k, v)
