"""Flash attention as a Pallas TPU kernel (pl.pallas_call + BlockSpec).

TPU-native adaptation of the FlashAttention tiling (arXiv:2205.14135):

* grid (B, Hq, Sq/bq, Skv/bk) — the KV dimension is innermost, executed
  sequentially on TPU, so the online-softmax running state (m, l, acc) lives
  in VMEM scratch across KV steps;
* q/k/v blocks are staged HBM->VMEM by BlockSpec; block sizes default to
  (bq, bk) = (128, 128) with d_head 64/128 — MXU-aligned (128x128 systolic
  tiles);
* GQA without materializing repeated KV: the k/v BlockSpec index_map sends
  query-head h to kv-head h // (Hq/Hkv);
* causal + sliding-window + hole masking via absolute position tensors
  (positions >= INVALID_POS mark unwritten cache slots).

Validated in interpret mode against ref.py (pure jnp); on-TPU this is the
`attn_impl="pallas"` lowering of models/layers.attention.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
INVALID_POS = 2**30
NEG_INF = float(-1e30)


def _flash_kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, scale, causal, window, n_kv):
    kv_idx = pl.program_id(3)

    @pl.when(kv_idx == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :]                       # [bq, d]
    k = k_ref[0, :, 0, :]                       # [bk, d]
    v = v_ref[0, :, 0, :]                       # [bk, d]
    qp = qpos_ref[0, :]                         # [bq]
    kp = kpos_ref[0, :]                         # [bk]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                                   # [bq, bk]

    mask = kp[None, :] >= INVALID_POS
    if causal:
        mask |= kp[None, :] > qp[:, None]
    if window is not None:
        mask |= kp[None, :] <= qp[:, None] - window
    s = jnp.where(mask, NEG_INF, s)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    # fully-masked rows: s == m_new == NEG_INF would give exp(0) = 1 for
    # every masked entry; zero them explicitly
    p = jnp.where(mask, 0.0, jnp.exp(s - m_new[:, None]))
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=-1)
    pv = jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    acc_scr[...] = acc_scr[...] * corr[:, None] + pv
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(kv_idx == n_kv - 1)
    def _out():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)         # fully-masked rows -> 0
        o_ref[0, :, 0, :] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(
    q, k, v, q_positions, kv_positions, *,
    causal: bool = True,
    window: int | None = None,
    softmax_scale: float | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
):
    """q: [B, Sq, Hq, D]; k, v: [B, Skv, Hkv, D]; positions int32.

    Sq/Skv must be multiples of block_q/block_k (ops.py pads)."""
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(D)
    bq, bk = min(block_q, Sq), min(block_k, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, (Sq, bq, Skv, bk)
    n_kv = Skv // bk
    grid = (B, Hq, Sq // bq, n_kv)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window, n_kv=n_kv
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq), lambda b, h, qi, ki: (b, qi)),          # qpos
            pl.BlockSpec((1, bk), lambda b, h, qi, ki: (b, ki)),          # kpos
            pl.BlockSpec((1, bq, 1, D), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, bk, 1, D),
                         lambda b, h, qi, ki: (b, ki, h // G, 0)),        # GQA
            pl.BlockSpec((1, bk, 1, D),
                         lambda b, h, qi, ki: (b, ki, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, D), lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, Hq, D), q.dtype),
        # VMEM scratch for the online-softmax running state; persists across
        # the sequentially-executed KV grid dimension
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q_positions, kv_positions, q, k, v)
