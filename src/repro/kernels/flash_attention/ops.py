"""jit'd wrapper: pads sequence dims to block multiples (holes are masked
via INVALID_POS), dispatches the Pallas kernel, and unpads.

On this CPU container the kernel executes in interpret mode (the Pallas
interpreter runs the kernel body in Python); on TPU pass interpret=False.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .flash_attention import INVALID_POS, flash_attention


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "interpret"))
def flash_attention_op(q, k, v, q_positions, kv_positions, *,
                       causal: bool = True, window: int | None = None,
                       block_q: int = 128, block_k: int = 128,
                       interpret: bool = True):
    B, Sq, Hq, D = q.shape
    _, Skv, _, _ = k.shape
    bq, bk = min(block_q, max(Sq, 8)), min(block_k, max(Skv, 8))
    pq = (-Sq) % bq
    pk = (-Skv) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, ((0, 0), (0, pq)),
                              constant_values=0)
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, ((0, 0), (0, pk)),
                               constant_values=INVALID_POS)
    out = flash_attention(
        q, k, v, q_positions, kv_positions,
        causal=causal, window=window, block_q=bq, block_k=bk,
        interpret=interpret,
    )
    return out[:, :Sq]
