"""Pure-jnp oracle for the flash-attention kernel: materializes the full
score matrix (O(Sq*Skv) memory) with identical masking semantics."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

INVALID_POS = 2**30


def attention_ref(q, k, v, q_positions, kv_positions, *, causal=True,
                  window=None, softmax_scale=None):
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(D)
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    kvp = kv_positions[:, None, None, :]
    qp = q_positions[:, None, :, None]
    mask = kvp >= INVALID_POS
    if causal:
        mask = mask | (kvp > qp)
    if window is not None:
        mask = mask | (kvp <= qp - window)
    s = jnp.where(mask, -jnp.inf, s)
    m = jnp.max(s, axis=-1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)  # fully-masked rows
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    l = jnp.where(l == 0.0, 1.0, l)
    o = jnp.einsum("bhqk,bkhd->bqhd", p / l, v.astype(jnp.float32))
    return o.astype(q.dtype)
