"""jit'd wrapper for the RMSNorm Pallas kernel."""
from functools import partial

import jax

from .rmsnorm import rmsnorm


@partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm_op(x, w, *, eps: float = 1e-6, block_rows: int = 256,
               interpret: bool = True):
    return rmsnorm(x, w, eps=eps, block_rows=block_rows, interpret=interpret)
