"""Fused RMSNorm row kernel (pl.pallas_call + BlockSpec).

Row-blocked: each grid step normalizes a [rows, d] tile held in VMEM —
one HBM read + one write per element instead of the separate
square/mean/rsqrt/mul kernels the unfused lowering would emit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 256


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)            # [rows, d]
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(x, w, *, eps: float = 1e-6, block_rows: int = DEFAULT_BLOCK_ROWS,
            interpret: bool = False):
    """x: [..., d]; w: [d]."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    n = x2.shape[0] // br
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, w)
    return out[:rows].reshape(orig_shape)
