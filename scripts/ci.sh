#!/usr/bin/env bash
# CI canary: the fast test suite plus the seconds-level smoke benchmarks
# (benchmarks/run.py --smoke), which exercise both execution backends end to
# end — including the elastic_burst and keyed_burst rescaling scenarios, the
# placement_burst worker-pool scenario (packed vs spread policies: acquire
# on saturated scale-out, release on scale-in, both backends), and the
# scale module's n=20 Fig. 8 arm (constraints on/off latency factor).
#
# The scale smoke arm runs the n=20 grid in BOTH event cores (exact +
# event_mode="batched") AND both event schedulers (calendar + heap,
# core/eventq.py), asserting cross-mode equivalence (item conservation, QoS
# outcomes, latency within 1%) and bit-exact cross-scheduler equivalence —
# the strict decision-level contracts live in tests/test_sim_modes.py.
#
# Perf canary: the keyed_burst_sim row reports the exact event core's
# events/sec; dropping below EVENTS_PER_SEC_FLOOR FAILS CI (the floor sits
# ~4x under the calendar core's quiet-machine steady state, so only a real
# event-core regression — not shared-machine throttle — can cross it).
# The batched-core column (scale_n20_m20_on_batched) stays warn-only.
#
#   scripts/ci.sh            # fast tests + smoke benchmarks
#   CI_FULL=1 scripts/ci.sh  # additionally run the slow-marked tests
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# HARD events/sec floor for the perf canary: the calendar-queue event core
# measures ~350k ev/s warm on a quiet machine through this harness (the
# pre-overhaul core: ~40k); 80k leaves >4x margin for shared-machine
# throttle while still catching any real event-core regression.
EVENTS_PER_SEC_FLOOR="${EVENTS_PER_SEC_FLOOR:-80000}"
# batched-core column (scale n=20 smoke, constraints-on arm): ~150k+ ev/s
# wall on a quiet machine; same halving for shared-machine throttle.
BATCHED_EVENTS_PER_SEC_FLOOR="${BATCHED_EVENTS_PER_SEC_FLOOR:-75000}"

# -- lint + pre-flight graph validation (repro.analysis) ---------------------
# AST rules over src/repro plus graph_check over the canonical topologies;
# ERROR diagnostics exit non-zero and fail CI, WARNs only print.
echo "== lint + graph validator =="
python scripts/lint.py

# -- type-checking arm (scoped: routing, placement, analysis) ----------------
# The container does not ship mypy and CI must not install packages, so the
# arm self-skips with a notice when unavailable; run it locally with any
# environment that has mypy on the path.
echo "== mypy (scoped) =="
if python -c "import mypy" 2>/dev/null; then
  python -m mypy --config-file mypy.ini
else
  echo "SKIP: mypy not installed (scoped config in mypy.ini)"
fi

echo "== pytest (fast) =="
python -m pytest -x -q -m "not slow"

if [[ "${CI_FULL:-0}" == "1" ]]; then
  echo "== pytest (slow) =="
  python -m pytest -x -q -m "slow"
fi

echo "== smoke benchmarks =="
SMOKE_OUT="$(mktemp)"
python -m benchmarks.run --smoke | tee "$SMOKE_OUT"

# -- events/sec floor (simulator hot path; HARD gate) ------------------------
EPS="$(grep -o 'events_per_sec=[0-9]*' "$SMOKE_OUT" | head -1 | cut -d= -f2 || true)"
if [[ -z "${EPS:-}" ]]; then
  echo "FAIL: keyed_burst_sim events_per_sec not found in smoke output"
  rm -f "$SMOKE_OUT"
  exit 1
fi
if [[ "$EPS" -lt "$EVENTS_PER_SEC_FLOOR" ]]; then
  echo "FAIL: keyed_burst_sim events/sec=$EPS below floor" \
       "$EVENTS_PER_SEC_FLOOR — event-core regression (the floor already" \
       "allows >4x shared-machine throttle; override EVENTS_PER_SEC_FLOOR" \
       "only for a known-slow box)"
  rm -f "$SMOKE_OUT"
  exit 1
fi
echo "perf floor OK: keyed_burst_sim events/sec=$EPS" \
     "(floor $EVENTS_PER_SEC_FLOOR)"

# -- batched column of the canary (opt-in event core, scale smoke arm) -------
EPS_B="$(grep 'scale_n20_m20_on_batched,' "$SMOKE_OUT" \
         | grep -o 'events_per_sec=[0-9]*' | head -1 | cut -d= -f2 || true)"
if [[ -n "${EPS_B:-}" ]]; then
  if [[ "$EPS_B" -lt "$BATCHED_EVENTS_PER_SEC_FLOOR" ]]; then
    echo "WARN: batched-core events/sec=$EPS_B below canary floor" \
         "$BATCHED_EVENTS_PER_SEC_FLOOR (scale_n20_m20_on_batched)"
  else
    echo "perf canary OK: batched-core events/sec=$EPS_B" \
         "(floor $BATCHED_EVENTS_PER_SEC_FLOOR)"
  fi
else
  echo "WARN: scale_n20_m20_on_batched events_per_sec not found in smoke output"
fi
rm -f "$SMOKE_OUT"

# -- lockset race detector over the threaded-engine smoke scenarios ----------
# REPRO_RACE_CHECK=1 instruments StateStore / OutputBuffer / KeyRouter.commit
# (analysis/race.py) and the keyed_burst + placement_burst scenarios — the
# ones that rescale stateful stages and elastic pools across threads — must
# come back with zero race reports.  Runs in its own process: the flag is
# read once at import, and the canary smoke run above must stay
# uninstrumented.
echo "== race detector (keyed_burst + placement_burst) =="
REPRO_RACE_CHECK=1 python - <<'PY'
from repro.analysis.race import CHECKER, RACE_CHECK
assert RACE_CHECK and CHECKER is not None
from benchmarks.qos_scaling import run_keyed_burst, run_placement_burst
run_keyed_burst(smoke=True)
run_placement_burst(smoke=True)
CHECKER.assert_clean()
print("race check clean: keyed_burst + placement_burst")
PY

# -- runtime invariant sanitizer over the golden scenarios -------------------
# REPRO_SANITIZE=1 instruments the output buffers, the simulator event core
# and the keyed-state migration path (analysis/sanitize.py): channel
# conservation, event-time monotonicity, post-migration key-ownership
# exclusivity and buffer fill accounting.  The three golden simulations plus
# the threaded keyed_burst scenario must come back with zero reports.  Own
# process for the same read-once-flag reason as the race arm; the canary
# smoke run above stays uninstrumented, so its events/sec floor is
# unaffected.
echo "== invariant sanitizer (goldens + keyed_burst) =="
REPRO_SANITIZE=1 python - <<'PY'
import sys
sys.path.insert(0, "tests")
from repro.analysis.sanitize import CHECKER, SANITIZE
assert SANITIZE and CHECKER is not None
from test_sim_determinism import SIMS, DURATIONS_MS
for name, build in SIMS.items():
    build().run(DURATIONS_MS[name])
from benchmarks.qos_scaling import run_keyed_burst
run_keyed_burst(smoke=True)
CHECKER.assert_clean()
print("sanitizer clean: media + scale + chain goldens, keyed_burst")
PY

# -- proactive QoS smoke under both dynamic checkers -------------------------
# The predictive path (docs/predictive.md): estimator feed on the control
# tick -> forecast-driven ScaleRequest/BufferSizeUpdate before the SLO
# trips, on BOTH backends (proactive_burst: flash-crowd + diurnal traces,
# reactive vs proactive).  The scenario itself asserts the simulator's
# proactive arm strictly beats the reactive baseline; each checker arm must
# additionally come back with zero reports — proactive rescales must not
# race the engine's shared state nor corrupt channel/state invariants.
# Own process per arm: read-once flags.
echo "== proactive QoS smoke (race detector, both backends) =="
REPRO_RACE_CHECK=1 python - <<'PY'
from repro.analysis.race import CHECKER, RACE_CHECK
assert RACE_CHECK and CHECKER is not None
from benchmarks.qos_scaling import run_proactive_burst
run_proactive_burst(smoke=True)
CHECKER.assert_clean()
print("race check clean: proactive_burst (sim + engine)")
PY

echo "== proactive QoS smoke (invariant sanitizer, both backends) =="
REPRO_SANITIZE=1 python - <<'PY'
from repro.analysis.sanitize import CHECKER, SANITIZE
assert SANITIZE and CHECKER is not None
from benchmarks.qos_scaling import run_proactive_burst
run_proactive_burst(smoke=True)
CHECKER.assert_clean()
print("sanitizer clean: proactive_burst (sim + engine)")
PY

# -- crash-recovery smoke under both dynamic checkers ------------------------
# The robustness path (docs/robustness.md): fault injection -> heartbeat
# detection -> respawn on a replacement -> checkpoint state restore -> offset
# replay, on BOTH backends.  Each arm asserts the exact per-key conservation
# ledger (inside run_crash_recovery_*) AND zero reports from the instrumented
# checker — recovery must not race the engine's shared state (lockset
# detector) nor leave a key in two stores / corrupt buffer accounting
# (sanitizer NS-S005, NS-S001/4).  Own process per arm: read-once flags.
echo "== crash recovery smoke (race detector, both backends) =="
REPRO_RACE_CHECK=1 python - <<'PY'
from repro.analysis.race import CHECKER, RACE_CHECK
assert RACE_CHECK and CHECKER is not None
from benchmarks.faults import run_crash_recovery_engine, run_crash_recovery_sim
run_crash_recovery_sim(smoke=True)
run_crash_recovery_engine(smoke=True)
CHECKER.assert_clean()
print("race check clean: crash recovery (sim + engine)")
PY

echo "== crash recovery smoke (invariant sanitizer, both backends) =="
REPRO_SANITIZE=1 python - <<'PY'
from repro.analysis.sanitize import CHECKER, SANITIZE
assert SANITIZE and CHECKER is not None
from benchmarks.faults import run_crash_recovery_engine, run_crash_recovery_sim
run_crash_recovery_sim(smoke=True)
run_crash_recovery_engine(smoke=True)
CHECKER.assert_clean()
print("sanitizer clean: crash recovery (sim + engine)")
PY

echo "CI OK"
