#!/usr/bin/env bash
# CI canary: the fast test suite plus the seconds-level smoke benchmarks
# (benchmarks/run.py --smoke), which exercise both execution backends end to
# end — including the elastic_burst and keyed_burst rescaling scenarios and
# the placement_burst worker-pool scenario (packed vs spread policies:
# acquire on saturated scale-out, release on scale-in, both backends).
#
#   scripts/ci.sh            # fast tests + smoke benchmarks
#   CI_FULL=1 scripts/ci.sh  # additionally run the slow-marked tests
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== pytest (fast) =="
python -m pytest -x -q -m "not slow"

if [[ "${CI_FULL:-0}" == "1" ]]; then
  echo "== pytest (slow) =="
  python -m pytest -x -q -m "slow"
fi

echo "== smoke benchmarks =="
python -m benchmarks.run --smoke

echo "CI OK"
