"""Regenerate tests/golden/sim_decisions.json from the determinism-contract
scenarios (tests/test_sim_determinism.py).  Only run this for an intentional
semantic change to the simulator or the QoS control plane — never to paper
over an unintended trace divergence.

    PYTHONPATH=src python scripts/gen_sim_golden.py
"""
import json
import sys
from pathlib import Path

sys.path.insert(0, "src")
sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "tests"))

from test_sim_determinism import GOLDEN, TRACES  # noqa: E402


def main() -> None:
    out = {}
    for name, fn in TRACES.items():
        out[name] = fn()
        print(f"{name}: events={out[name]['events']} "
              f"history={len(out[name]['history'])} "
              f"chains={out[name]['chained_groups']} "
              f"scales={len(out[name]['scale_log'])}")
    GOLDEN.parent.mkdir(parents=True, exist_ok=True)
    GOLDEN.write_text(json.dumps(out, indent=1, sort_keys=True))
    print(f"wrote {GOLDEN}")


if __name__ == "__main__":
    main()
