"""Regenerate the simulator determinism goldens from the contract scenarios
(tests/test_sim_determinism.py):

* tests/golden/sim_decisions.json          — exact event core
* tests/golden/sim_decisions_batched.json  — batched event core
  (``event_mode="batched"``; its own bit-exact contract, plus the
  cross-mode equivalence checks in tests/test_sim_modes.py)

Only run this for an intentional semantic change to the simulator or the
QoS control plane — never to paper over an unintended trace divergence.

    PYTHONPATH=src python scripts/gen_sim_golden.py [--batched-only]
"""
import json
import sys
from pathlib import Path

sys.path.insert(0, "src")
sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "tests"))

from test_sim_determinism import GOLDEN, GOLDEN_BATCHED, TRACES  # noqa: E402


def _generate(event_mode: str, path: Path) -> None:
    out = {}
    for name, fn in TRACES.items():
        out[name] = fn(event_mode=event_mode)
        print(f"[{event_mode}] {name}: events={out[name]['events']} "
              f"history={len(out[name]['history'])} "
              f"chains={out[name]['chained_groups']} "
              f"scales={len(out[name]['scale_log'])}")
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(out, indent=1, sort_keys=True))
    print(f"wrote {path}")


def main() -> None:
    if "--batched-only" not in sys.argv:
        _generate("exact", GOLDEN)
    _generate("batched", GOLDEN_BATCHED)


if __name__ == "__main__":
    main()
