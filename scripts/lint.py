#!/usr/bin/env python
"""Repo lint + canonical-topology graph validation (CI gate).

Two passes, both reporting structured diagnostics from repro.analysis:

1. AST lint (analysis/lint.py) over ``src/repro`` — the repo's own
   hot-path discipline: no wall clock in the simulator, stdlib-only
   state codec, no ``key %`` routing outside core/routing.py,
   ``__slots__`` in hot modules, no heavyweight module-level imports in
   lazy zones.
2. Pre-flight graph validation (analysis/graph_check.py) over every
   canonical topology builder — the paper's media job plus the benchmark
   jobs — which must come back with zero ERRORs (the same no-false-
   positives contract tests/test_analysis_graph_check.py pins).

Exit status 1 iff any ERROR diagnostic was produced; WARNs only print.

    PYTHONPATH=src python scripts/lint.py                # both passes
    PYTHONPATH=src python scripts/lint.py --rules        # rule catalog
    PYTHONPATH=src python scripts/lint.py --format json  # machine output

``--format json`` emits one JSON object per line — ``{"id", "severity",
"file", "line", "message"}`` — for editor/CI integration; the exit-status
contract is unchanged.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))
sys.path.insert(0, str(ROOT))

from repro.analysis import ERROR, REGISTRY  # noqa: E402
from repro.analysis.lint import lint_tree  # noqa: E402


def dump_rules() -> int:
    for rule_id in sorted(REGISTRY):
        r = REGISTRY[rule_id]
        print(f"{r.id}  {r.severity:5s}  {r.title}")
    return 0


def _as_json_line(d) -> str:
    """One diagnostic as a single JSON line.  Locations are either
    ``path:lineno`` (AST lint) or a human scope like ``job 'media'``
    (graph pass) — the latter maps to file=location, line=0."""
    file, _, tail = d.location.rpartition(":")
    if file and tail.isdigit():
        line = int(tail)
    else:
        file, line = d.location, 0
    return json.dumps({"id": d.rule, "severity": d.severity, "file": file,
                       "line": line, "message": d.message})


def graph_pass() -> list:
    """Validate every canonical topology (paper media job + benchmark
    jobs) against the pre-flight rules."""
    from repro.analysis.graph_check import check_job
    from repro.configs.nephele_media import MediaJobParams, build_media_job

    from benchmarks.qos_scaling import _burst_job, _keyed_job

    diags = []
    cases = {
        "media(default)": build_media_job(MediaJobParams()),
        "media(m=4,n=2)": build_media_job(
            MediaJobParams(parallelism=4, num_workers=2)),
        "elastic_burst": _burst_job(),
        "keyed_burst": _keyed_job(),
    }
    for name, (jg, jcs) in cases.items():
        for d in check_job(jg, jcs):
            diags.append((name, d))
    return diags


def main(argv: list[str]) -> int:
    if "--rules" in argv:
        return dump_rules()
    as_json = False
    if "--format" in argv:
        fmt = argv[argv.index("--format") + 1:][:1]
        if fmt != ["json"]:
            print(f"unknown --format {fmt[0] if fmt else '(missing)'!r} "
                  f"(only 'json')", file=sys.stderr)
            return 2
        as_json = True
    diags = lint_tree(ROOT)
    for d in diags:
        print(_as_json_line(d) if as_json else d.format())
    graph_diags = graph_pass()
    for name, d in graph_diags:
        print(_as_json_line(d) if as_json
              else f"[graph:{name}] {d.format()}")
    diags += [d for _, d in graph_diags]
    errors = sum(1 for d in diags if d.severity == ERROR)
    warns = len(diags) - errors
    if not as_json:
        print(f"lint: {errors} error(s), {warns} warning(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
