#!/usr/bin/env python
"""Repo lint + canonical-topology graph validation (CI gate).

Two passes, both reporting structured diagnostics from repro.analysis:

1. AST lint (analysis/lint.py) over ``src/repro`` — the repo's own
   hot-path discipline: no wall clock in the simulator, stdlib-only
   state codec, no ``key %`` routing outside core/routing.py,
   ``__slots__`` in hot modules, no heavyweight module-level imports in
   lazy zones.
2. Pre-flight graph validation (analysis/graph_check.py) over every
   canonical topology builder — the paper's media job plus the benchmark
   jobs — which must come back with zero ERRORs (the same no-false-
   positives contract tests/test_analysis_graph_check.py pins).

Exit status 1 iff any ERROR diagnostic was produced; WARNs only print.

    PYTHONPATH=src python scripts/lint.py          # both passes
    PYTHONPATH=src python scripts/lint.py --rules  # dump the rule catalog
"""
from __future__ import annotations

import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))
sys.path.insert(0, str(ROOT))

from repro.analysis import ERROR, REGISTRY  # noqa: E402
from repro.analysis.lint import lint_tree  # noqa: E402


def dump_rules() -> int:
    for rule_id in sorted(REGISTRY):
        r = REGISTRY[rule_id]
        print(f"{r.id}  {r.severity:5s}  {r.title}")
    return 0


def graph_pass() -> list:
    """Validate every canonical topology (paper media job + benchmark
    jobs) against the pre-flight rules."""
    from repro.analysis.graph_check import check_job
    from repro.configs.nephele_media import MediaJobParams, build_media_job

    from benchmarks.qos_scaling import _burst_job, _keyed_job

    diags = []
    cases = {
        "media(default)": build_media_job(MediaJobParams()),
        "media(m=4,n=2)": build_media_job(
            MediaJobParams(parallelism=4, num_workers=2)),
        "elastic_burst": _burst_job(),
        "keyed_burst": _keyed_job(),
    }
    for name, (jg, jcs) in cases.items():
        for d in check_job(jg, jcs):
            print(f"[graph:{name}] {d.format()}")
            diags.append(d)
    return diags


def main(argv: list[str]) -> int:
    if "--rules" in argv:
        return dump_rules()
    diags = lint_tree(ROOT)
    for d in diags:
        print(d.format())
    diags += graph_pass()
    errors = sum(1 for d in diags if d.severity == ERROR)
    warns = len(diags) - errors
    print(f"lint: {errors} error(s), {warns} warning(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
