"""Kernel validation + timing: Pallas kernels (interpret mode on this CPU
container) vs their pure-jnp oracles across a shape sweep.  On-TPU wall
times come from the same harness with interpret=False."""
from __future__ import annotations

import sys
import time

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.kernels.flash_attention.ops import flash_attention_op  # noqa: E402
from repro.kernels.flash_attention.ref import attention_ref  # noqa: E402
from repro.kernels.rmsnorm.ops import rmsnorm_op  # noqa: E402
from repro.kernels.rmsnorm.ref import rmsnorm_ref  # noqa: E402
from repro.kernels.ssd_scan.ops import ssd_scan_op  # noqa: E402
from repro.kernels.ssd_scan.ref import ssd_ref  # noqa: E402


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run(quick: bool = True):
    rows = []
    key = jax.random.PRNGKey(0)

    # flash attention
    B, S, Hq, Hkv, D = (1, 256, 4, 2, 64) if quick else (2, 512, 8, 2, 128)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    out = flash_attention_op(q, k, v, pos, pos, block_q=128, block_k=128)
    ref = attention_ref(q, k, v, pos, pos)
    err = float(np.abs(np.asarray(out) - np.asarray(ref)).max())
    us = _time(lambda: flash_attention_op(q, k, v, pos, pos), reps=2)
    rows.append(("kernel_flash_attention", us,
                 f"max_err={err:.2e};shape=B{B}xS{S}xH{Hq}/{Hkv}xD{D}"))

    # ssd scan
    Bb, S2, H, P, G, N = (1, 128, 4, 32, 1, 32) if quick else (2, 256, 8, 64,
                                                               2, 64)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (Bb, S2, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bb, S2, H)))
    a = -dt * jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (Bb, S2, G, N)) * 0.5
    Cm = jax.random.normal(ks[4], (Bb, S2, G, N)) * 0.5
    y, st = ssd_scan_op(x, dt, a, Bm, Cm, chunk=32)
    yr, sr = ssd_ref(x, dt, a, Bm, Cm)
    err = float(np.abs(np.asarray(y) - np.asarray(yr)).max()
                / (np.abs(np.asarray(yr)).max() + 1e-9))
    us = _time(lambda: ssd_scan_op(x, dt, a, Bm, Cm, chunk=32), reps=2)
    rows.append(("kernel_ssd_scan", us,
                 f"rel_err={err:.2e};shape=B{Bb}xS{S2}xH{H}xP{P}xN{N}"))

    # rmsnorm
    x = jax.random.normal(key, (8, 256, 512), jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(1), (512,), jnp.bfloat16)
    o = rmsnorm_op(x, w)
    r = rmsnorm_ref(x, w)
    err = float(np.abs(np.asarray(o, np.float32)
                       - np.asarray(r, np.float32)).max())
    us = _time(lambda: rmsnorm_op(x, w), reps=3)
    rows.append(("kernel_rmsnorm", us, f"max_err={err:.2e};shape=8x256x512"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.0f},{derived}")
