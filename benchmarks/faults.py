"""Crash-under-load: fault injection + checkpoint recovery on BOTH backends.

The robustness benchmark (docs/robustness.md): a keyed, stateful job runs
under a flash-crowd rate trace (benchmarks/workloads.py); mid-spike a
seeded :class:`FaultPlan` kills the worker owning ``Agg[0]``.  The
heartbeat monitor declares the worker dead, recovery respawns the lost
subtasks on a replacement, restores keyed state from the last periodic
checkpoint, rolls the sources back to the checkpointed offsets and replays.
Reported per backend:

* ``time_to_detect_ms``   — crash -> heartbeat-timeout declaration,
* ``time_to_recover_ms``  — crash -> respawn + state restore + replay done,
* ``time_to_slo_recovery_ms`` — crash -> first control tick where every
  latency constraint is evaluable and satisfied again,

plus the per-key conservation ledger, asserted EXACT on both backends:
``emitted[k] == sunk[k] + dropped[k]`` for every key (emitted counts replay
fires, so duplicates at the sinks are bounded by the recorded replay
window).  Results land in ``BENCH_faults.json``.
"""
from __future__ import annotations

import sys
import tempfile
import time

sys.path.insert(0, "src")

from repro.checkpoint.checkpointer import Checkpointer  # noqa: E402
from repro.core import (  # noqa: E402
    ALL_TO_ALL,
    FaultPlan,
    JobConstraint,
    JobGraph,
    JobSequence,
    JobVertex,
    SimSourceSpec,
    SourceSpec,
    StreamEngine,
    StreamSimulator,
)

from benchmarks.workloads import flash_crowd  # noqa: E402

KEYS = 32


def _crash_job(agg_fn=None, sink_fn=None, agg_cost_ms: float = 1.0):
    """One keyed, stateful job description for BOTH backends (the simulator
    reads sim_cpu_ms; the engine runs the fns)."""
    jg = JobGraph("crash-under-load")
    jg.add_vertex(JobVertex("Src", 2, is_source=True, sim_cpu_ms=0.01))
    jg.add_vertex(JobVertex("Agg", 2, fn=agg_fn, sim_cpu_ms=agg_cost_ms,
                            sim_item_bytes=64, stateful=True))
    jg.add_vertex(JobVertex("Sink", 1, fn=sink_fn, is_sink=True,
                            sim_cpu_ms=0.01, stateful=True))
    jg.add_edge("Src", "Agg", ALL_TO_ALL)
    jg.add_edge("Agg", "Sink", ALL_TO_ALL)
    seq = JobSequence.of(("Src", "Agg"), "Agg", ("Agg", "Sink"))
    return jg, [JobConstraint(seq, 1e9, 2_000.0, name="mon")]


def _check_conservation(name: str, res) -> None:
    em, sk, dr = res.emitted_by_key, res.sink_count_by_key, res.dropped_by_key
    bad = {k: (em.get(k, 0), sk.get(k, 0), dr.get(k, 0))
           for k in set(em) | set(sk) | set(dr)
           if em.get(k, 0) != sk.get(k, 0) + dr.get(k, 0)}
    assert not bad, f"{name}: per-key conservation violated: {bad}"
    assert res.time_to_detect_ms is not None, f"{name}: crash never detected"
    assert res.time_to_recover_ms is not None, f"{name}: never recovered"
    assert res.recovery_events, f"{name}: no RecoveryEvent"


def _derived(res) -> str:
    ev = res.recovery_events[0]
    slo = res.time_to_slo_recovery_ms
    return (
        f"detect_ms={res.time_to_detect_ms:.0f};"
        f"recover_ms={res.time_to_recover_ms:.0f};"
        f"slo_recovery_ms={(-1.0 if slo is None else slo):.0f};"
        f"emitted={sum(res.emitted_by_key.values())};"
        f"sunk={sum(res.sink_count_by_key.values())};"
        f"dropped={sum(res.dropped_by_key.values())};"
        f"replayed={sum(res.replayed_by_key.values())};"
        f"lost_tasks={len(ev.lost_vertices)};"
        f"restored_keys={ev.restored_keys};exact=True"
    )


def _metrics(res) -> dict:
    return {
        "time_to_detect_ms": res.time_to_detect_ms,
        "time_to_recover_ms": res.time_to_recover_ms,
        "time_to_slo_recovery_ms": res.time_to_slo_recovery_ms,
        "emitted": sum(res.emitted_by_key.values()),
        "sunk": sum(res.sink_count_by_key.values()),
        "dropped": sum(res.dropped_by_key.values()),
        "replayed": sum(res.replayed_by_key.values()),
        "recoveries": len(res.recovery_events),
        "fault_log": [f"{f.at_ms:.0f}ms {f.kind}: {f.detail}"
                      for f in res.fault_log],
    }


def run_crash_recovery_sim(smoke: bool = False):
    """Simulator arm: deterministic virtual time — detection latency is an
    exact multiple of the control tick."""
    rate = flash_crowd(base=100.0, spike=3.0, at_ms=6_000.0,
                       ramp_ms=1_000.0, hold_ms=3_000.0, decay_ms=3_000.0,
                       seed=11, stop_ms=22_000.0)
    jg, jcs = _crash_job(agg_cost_ms=1.0)
    plan = FaultPlan(seed=3).kill_owner_of(8_000.0, "Agg", index=0)
    with tempfile.TemporaryDirectory() as ckdir:
        sim = StreamSimulator(
            jg, jcs, num_workers=4,
            sources={"Src": SimSourceSpec(100.0, item_bytes=64, keys=KEYS,
                                          rate_fn=rate)},
            initial_buffer_bytes=256, max_buffer_lifetime_ms=500.0,
            fault_plan=plan,
            checkpointer=Checkpointer(ckdir, keep=3,
                                      checkpoint_interval_ms=2_000.0),
            heartbeat_timeout_ms=1_000.0)
        t0 = time.perf_counter()
        res = sim.run(32_000.0)
        wall = (time.perf_counter() - t0) * 1e6
    _check_conservation("crash_recovery_sim", res)
    return [("crash_recovery_sim", wall, _derived(res))], res


def run_crash_recovery_engine(smoke: bool = False):
    """Engine arm: real threads, a real heartbeat timeout, a task-thread
    abort that drops in-flight state exactly like a process crash."""
    scale = 1.0 if smoke else 1.6
    stop_ms = 6_000.0 * scale
    rate = flash_crowd(base=120.0, spike=2.5, at_ms=1_500.0 * scale,
                       ramp_ms=600.0, hold_ms=1_500.0 * scale,
                       decay_ms=1_500.0, seed=11, stop_ms=stop_ms)

    def agg_fn(p, emit, ctx):
        ctx.state.bump(ctx._current_item.key)
        emit(p)

    def sink_fn(p, emit, ctx):
        ctx.state.bump(ctx._current_item.key)

    jg, jcs = _crash_job(agg_fn=agg_fn, sink_fn=sink_fn)
    plan = FaultPlan(seed=3).kill_owner_of(2_500.0 * scale, "Agg", index=0)
    with tempfile.TemporaryDirectory() as ckdir:
        eng = StreamEngine(
            jg, jcs, num_workers=4,
            sources={"Src": SourceSpec(
                120.0, lambda s: (b"x" * 64, 64),
                key_of=lambda s: s % KEYS, rate_fn=rate)},
            initial_buffer_bytes=512, measurement_interval_ms=400.0,
            enable_chaining=False, max_buffer_lifetime_ms=200.0,
            fault_plan=plan,
            checkpointer=Checkpointer(ckdir, keep=3,
                                      checkpoint_interval_ms=1_000.0),
            heartbeat_timeout_ms=800.0)
        t0 = time.perf_counter()
        res = eng.run(stop_ms + 2_500.0)
        wall = (time.perf_counter() - t0) * 1e6
    _check_conservation("crash_recovery_engine", res)
    return [("crash_recovery_engine", wall, _derived(res))], res


def run(quick: bool = True, smoke: bool = False):
    rows_sim, res_sim = run_crash_recovery_sim(smoke=smoke)
    rows_eng, res_eng = run_crash_recovery_engine(smoke=smoke)
    rows = rows_sim + rows_eng
    from benchmarks.run import BENCH_DIR, write_bench
    if not smoke or not (BENCH_DIR / "BENCH_faults.json").exists():
        write_bench("faults", {
            "smoke": smoke,
            "sim": _metrics(res_sim),
            "engine": _metrics(res_eng),
            "rows": [{"name": n, "us_per_call": round(us, 1), "derived": d}
                     for n, us, d in rows],
        })
    return rows


if __name__ == "__main__":
    for name, us, derived in run(smoke=True):
        print(f"{name},{us:.1f},{derived}")
