"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) cell, from the compiled dry-run:

    compute term    = per_device_FLOPs / peak_FLOPs        (197 TF/s bf16)
    memory term     = per_device_HBM_bytes / HBM_bw        (819 GB/s)
    collective term = per_device_link_bytes / link_bw      (~50 GB/s/link)

plus MODEL_FLOPS = 6*N(_active)*D, the MODEL/HLO flops ratio (remat and
redundancy show up here), the dominant term, and the roofline fraction
(= useful-compute time / dominant-term time).
"""
from __future__ import annotations

import json
import sys
from glob import glob
from pathlib import Path

sys.path.insert(0, "src")

PEAK_FLOPS = 197e12          # TPU v5e bf16 per chip
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s per ICI link


def analyze_record(r: dict) -> dict:
    h = r["hlo_analysis"]
    flops, mem_b, coll_b = h["flops"], h["memory_bytes"], h[
        "collective_link_bytes_total"]
    t_c = flops / PEAK_FLOPS
    t_m = mem_b / HBM_BW
    t_n = coll_b / LINK_BW
    dominant = max((t_c, "compute"), (t_m, "memory"), (t_n, "collective"))
    # model flops: 6*N*D for train (fwd+bwd), 2*N*D for one forward token
    # pass (prefill), 2*N*D_tokens for decode (D = tokens processed)
    n_par = r["active_param_count"]
    dev = r["devices"]
    shape = r["shape"]
    tokens = {
        "train_4k": 4096 * 256, "prefill_32k": 32768 * 32,
        "decode_32k": 128, "long_500k": 1,
    }[shape]
    mult = 6 if r["mode"] == "train" else 2
    model_flops = mult * n_par * tokens / dev
    ratio = model_flops / flops if flops else float("nan")
    frac = (model_flops / PEAK_FLOPS) / dominant[0] if dominant[0] else 0.0
    return {
        "arch": r["arch"], "shape": shape, "mesh": r["mesh"],
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_n,
        "dominant": dominant[1],
        "model_flops_per_dev": model_flops,
        "useful_ratio": ratio,
        "roofline_frac": frac,
    }


def table(art_dir: str = "artifacts/dryrun", mesh: str = "16x16"):
    rows = []
    for f in sorted(glob(f"{art_dir}/*__{mesh}.json")):
        r = json.load(open(f))
        rows.append(analyze_record(r))
    return rows


def run(quick: bool = True):
    out = []
    for row in table():
        name = f"roofline_{row['arch']}_{row['shape']}"
        dom_t = max(row["t_compute_s"], row["t_memory_s"],
                    row["t_collective_s"])
        out.append((
            name,
            dom_t * 1e6,
            f"dom={row['dominant']};tc={row['t_compute_s']:.3f}s;"
            f"tm={row['t_memory_s']:.3f}s;tn={row['t_collective_s']:.3f}s;"
            f"useful={row['useful_ratio']:.2f};frac={row['roofline_frac']:.3f}",
        ))
    return out


def markdown_table(art_dir: str = "artifacts/dryrun", mesh: str = "16x16"):
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant "
        "| MODEL/HLO flops | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for row in table(art_dir, mesh):
        lines.append(
            f"| {row['arch']} | {row['shape']} | {row['t_compute_s']:.3f} "
            f"| {row['t_memory_s']:.3f} | {row['t_collective_s']:.3f} "
            f"| **{row['dominant']}** | {row['useful_ratio']:.2f} "
            f"| {row['roofline_frac']:.3f} |"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table())
