"""QoS-managed model serving (the DESIGN.md §2.2 adaptation): adaptive
batch sizing (= adaptive output buffers) and dynamic prefill->decode
chaining against a latency SLO, with a smoke-scale qwen3 payload."""
from __future__ import annotations

import sys

sys.path.insert(0, "src")

import jax  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.serving import QoSServer, RequestSpec  # noqa: E402


def run(quick: bool = True):
    cfg = get_config("qwen3-1.7b", smoke=True)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    spec = RequestSpec(rate_per_s=30.0, prompt_len=16, gen_len=4,
                       vocab=cfg.vocab_size)
    # warm the jit caches for the power-of-two buckets so compile time does
    # not pollute the latency measurements
    import numpy as np
    import jax.numpy as jnp
    for b in (1, 2, 4, 8, 16, 32, 64, 128):
        batch = {"tokens": jnp.zeros((b, spec.prompt_len), jnp.int32)}
        logits, cache = jax.jit(
            lambda p, bt: model.prefill(p, bt, spec.prompt_len + spec.gen_len + 8)
        )(params, batch)
        tok = jnp.zeros((b,), jnp.int32)
        jax.jit(model.decode_step)(params, cache, tok,
                                   jnp.full((b,), spec.prompt_len, jnp.int32))

    dur = 40_000.0 if quick else 90_000.0
    rows = []
    for name, kw in (
        ("fixed_large", dict(enable_qos=False, initial_buffer_bytes=8192)),
        ("fixed_small", dict(enable_qos=False, initial_buffer_bytes=256)),
        ("adaptive", dict(enable_qos=True, enable_chaining=False,
                          initial_buffer_bytes=8192)),
        ("adaptive_chain", dict(enable_qos=True, enable_chaining=True,
                                initial_buffer_bytes=8192)),
    ):
        srv = QoSServer(model, params, spec, latency_limit_ms=400.0,
                        measurement_interval_ms=500.0, **kw)
        res = srv.run(dur)
        rows.append((
            f"serve_{name}",
            res.settled_mean_ms * 1e3,
            f"settled_mean_ms={res.settled_mean_ms:.0f};"
            f"mean_ms={res.mean_latency_ms:.0f};p90_ms={res.p(0.9):.0f};"
            f"rps={res.throughput_rps:.1f};batch={res.mean_batch:.1f};"
            f"chains={len(res.chained_groups)}",
        ))
    return rows


if __name__ == "__main__":
    for name, us, derived in run(quick="--full" not in sys.argv):
        print(f"{name},{us:.0f},{derived}")
