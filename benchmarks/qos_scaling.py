"""Paper §3.4 at full scale: distributed QoS management setup for the media
job at n=200 workers, m up to 800 — the real Algorithms 1-3 on the real
runtime graph (no simulation).  Reports:

* induced runtime-constraint count (the paper's 512e6 at m=800) — computed
  combinatorially, never materialized,
* ComputeQoSSetup wall time + number of managers + subgraph sizes,
* reporter routing table size.
"""
from __future__ import annotations

import sys
import time

sys.path.insert(0, "src")

from repro.configs.nephele_media import MediaJobParams, build_media_job  # noqa: E402
from repro.core import RuntimeGraph, check_side_conditions  # noqa: E402
from repro.core.setup import compute_qos_setup, compute_reporter_setup  # noqa: E402


def run_one(m: int, n: int):
    p = MediaJobParams(parallelism=m, num_workers=n)
    jg, jcs = build_media_job(p)
    t0 = time.perf_counter()
    rg = RuntimeGraph(jg, n)
    t_expand = time.perf_counter() - t0
    n_seq = jcs[0].num_runtime_sequences(rg)
    t0 = time.perf_counter()
    allocs = compute_qos_setup(jg, jcs, rg)
    t_setup = time.perf_counter() - t0
    t0 = time.perf_counter()
    ra = compute_reporter_setup(allocs, rg)
    t_rep = time.perf_counter() - t0
    if m <= 100:
        check_side_conditions(allocs, jcs, rg)
    sizes = [a.subgraph.size() for a in allocs.values()]
    routes = sum(
        len(els) for w in ra.channel_routes.values() for els in w.values()
    )
    return {
        "managers": len(allocs),
        "sequences": n_seq,
        "channels": len(rg.channels),
        "setup_ms": (t_setup + t_expand) * 1e3,
        "reporter_ms": t_rep * 1e3,
        "max_subgraph": max(v + e for v, e in sizes),
        "routes": routes,
    }


def run(quick: bool = True):
    rows = []
    grid = [(40, 10), (200, 50), (800, 200)] if not quick else [
        (40, 10), (200, 50), (800, 200)]
    for m, n in grid:
        r = run_one(m, n)
        rows.append((
            f"qos_setup_m{m}_n{n}",
            r["setup_ms"] * 1e3,
            f"managers={r['managers']};sequences={r['sequences']:.2e};"
            f"channels={r['channels']};max_subgraph={r['max_subgraph']};"
            f"routes={r['routes']}",
        ))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.0f},{derived}")
