"""Paper §3.4 at full scale: distributed QoS management setup for the media
job at n=200 workers, m up to 800 — the real Algorithms 1-3 on the real
runtime graph (no simulation).  Reports:

* induced runtime-constraint count (the paper's 512e6 at m=800) — computed
  combinatorially, never materialized,
* ComputeQoSSetup wall time + number of managers + subgraph sizes,
* reporter routing table size.

Plus the §6 elastic scenario: the SAME bursty workload on both execution
backends (discrete-event simulator and threaded engine), each driven by an
ElasticController through the shared runtime re-wiring layer — reports peak
parallelism reached during the burst and the parallelism after it subsides.
"""
from __future__ import annotations

import sys
import time

sys.path.insert(0, "src")

from benchmarks.workloads import diurnal, flash_crowd  # noqa: E402
from repro.configs.nephele_media import MediaJobParams, build_media_job  # noqa: E402
from repro.core import (  # noqa: E402
    ALL_TO_ALL,
    ElasticController,
    JobConstraint,
    JobGraph,
    JobSequence,
    JobVertex,
    ProactiveConfig,
    RuntimeGraph,
    SimSourceSpec,
    SourceSpec,
    StreamEngine,
    StreamSimulator,
    ThroughputConstraint,
    WorkerPool,
    check_side_conditions,
    key_ranges_for,
)
from repro.core.setup import compute_qos_setup, compute_reporter_setup  # noqa: E402


def run_one(m: int, n: int):
    p = MediaJobParams(parallelism=m, num_workers=n)
    jg, jcs = build_media_job(p)
    t0 = time.perf_counter()
    # m beyond the default key-range table would fail fast at expansion
    # (unaddressable parallelism): widen the routers with the stock policy
    rg = RuntimeGraph(jg, n, num_key_ranges=key_ranges_for(m))
    t_expand = time.perf_counter() - t0
    n_seq = jcs[0].num_runtime_sequences(rg)
    t0 = time.perf_counter()
    allocs = compute_qos_setup(jg, jcs, rg)
    t_setup = time.perf_counter() - t0
    t0 = time.perf_counter()
    ra = compute_reporter_setup(allocs, rg)
    t_rep = time.perf_counter() - t0
    if m <= 100:
        check_side_conditions(allocs, jcs, rg)
    sizes = [a.subgraph.size() for a in allocs.values()]
    routes = sum(
        len(els) for w in ra.channel_routes.values() for els in w.values()
    )
    return {
        "managers": len(allocs),
        "sequences": n_seq,
        "channels": len(rg.channels),
        "setup_ms": (t_setup + t_expand) * 1e3,
        "reporter_ms": t_rep * 1e3,
        "max_subgraph": max(v + e for v, e in sizes),
        "routes": routes,
    }


# -- §6 elastic burst: identical scenario on both backends -------------------


def _burst_job(work_fn=None, work_cost_ms: float = 4.0):
    """One job description for BOTH backends: the simulator reads
    sim_cpu_ms, the threaded engine runs work_fn."""
    jg = JobGraph("elastic-burst")
    jg.add_vertex(JobVertex("Src", 2, is_source=True, sim_cpu_ms=0.01))
    jg.add_vertex(JobVertex("Work", 2, fn=work_fn, sim_cpu_ms=work_cost_ms,
                            sim_item_bytes=256))
    jg.add_vertex(JobVertex("Sink", 1, is_sink=True, sim_cpu_ms=0.01))
    jg.add_edge("Src", "Work", ALL_TO_ALL)
    jg.add_edge("Work", "Sink", ALL_TO_ALL)
    seq = JobSequence.of(("Src", "Work"), "Work", ("Work", "Sink"))
    return jg, [JobConstraint(seq, 1e9, 2_000.0, name="mon")]


def run_elastic_burst(smoke: bool = False):
    """Bursty traffic against an undersized Work stage; the controller grows
    the stage through the burst and shrinks it after — same ScaleDecision
    path on both backends."""
    rows = []
    # simulator: 45 s of simulated time, burst for the first 20 s
    jg, jcs = _burst_job(work_cost_ms=4.0)
    sim = StreamSimulator(
        jg, jcs, num_workers=2,
        sources={"Src": SimSourceSpec(
            225.0, item_bytes=256, keys=64,
            rate_fn=lambda t: 225.0 if t < 20_000.0 else 10.0)},
        initial_buffer_bytes=2048, enable_qos=False)
    ctl = ElasticController(
        ThroughputConstraint("Work", 500.0, window_ms=4_000.0),
        hi_water=0.7, lo_water=0.25, max_parallelism=8, step=2,
        cooldown_ms=4_000.0)
    sim.attach_elastic(ctl)
    t0 = time.perf_counter()
    res = sim.run(45_000.0)
    wall = (time.perf_counter() - t0) * 1e6
    peak = max([d.to_parallelism for d in ctl.decisions], default=2)
    rows.append((
        "elastic_burst_sim", wall,
        f"peak={peak};final={len(sim.rg.tasks_of('Work'))};"
        f"decisions={len(ctl.decisions)};sinks={len(res.sink_latencies_ms)}",
    ))
    # threaded engine: real seconds — short in smoke mode
    dur_ms, burst_ms = (6_000.0, 3_000.0) if smoke else (12_000.0, 5_000.0)
    window_ms, cooldown_ms = ((1_200.0, 1_200.0) if smoke
                              else (2_000.0, 2_500.0))
    # 2 tasks x 4 ms/item: capacity ~500/s, decisively below the 450/s
    # offered burst + queue noise -> the saturation trigger is robust
    sleep_s = 0.004

    def work(p, emit, ctx):
        time.sleep(sleep_s)
        emit(p)

    jg2, jcs2 = _burst_job(work_fn=work, work_cost_ms=3.0)
    eng = StreamEngine(
        jg2, jcs2, num_workers=2,
        sources={"Src": SourceSpec(
            225.0, lambda s: (b"x" * 64, 64),
            rate_fn=lambda t: 225.0 if t < burst_ms else 10.0)},
        initial_buffer_bytes=2048,  # ~32 items: buffers actually ship
        measurement_interval_ms=400.0,
        enable_qos=False, enable_chaining=False)
    ctl2 = ElasticController(
        ThroughputConstraint("Work", 700.0, window_ms=window_ms),
        hi_water=0.7, lo_water=0.25, max_parallelism=8, step=2,
        cooldown_ms=cooldown_ms)
    eng.attach_elastic(ctl2)
    t0 = time.perf_counter()
    res2 = eng.run(dur_ms)
    wall = (time.perf_counter() - t0) * 1e6
    emitted = sum(ex.emitted for v, ex in eng.executors.items()
                  if v.job_vertex == "Src")
    peak = max([d.to_parallelism for d in ctl2.decisions], default=2)
    rows.append((
        "elastic_burst_engine", wall,
        f"peak={peak};final={len(eng.rg.tasks_of('Work'))};"
        f"decisions={len(ctl2.decisions)};emitted={emitted};"
        f"sinks={res2.items_at_sinks}",
    ))
    return rows


# -- proactive_burst: reactive vs forecast-driven QoS on both backends -------


def _qos_burst_job(limit_ms: float, work_fn=None, work_cost_ms: float = 4.0):
    """Like :func:`_burst_job` but with a REAL latency SLO plus a
    throughput constraint, so the QoS manager's countermeasure ladder
    (reactive) and the forecast path (proactive) are both armed."""
    jg = JobGraph("proactive-burst")
    jg.add_vertex(JobVertex("Src", 2, is_source=True, sim_cpu_ms=0.01))
    jg.add_vertex(JobVertex("Work", 2, fn=work_fn, sim_cpu_ms=work_cost_ms,
                            sim_item_bytes=256))
    jg.add_vertex(JobVertex("Sink", 1, is_sink=True, sim_cpu_ms=0.01))
    jg.add_edge("Src", "Work", ALL_TO_ALL)
    jg.add_edge("Work", "Sink", ALL_TO_ALL)
    seq = JobSequence.of(("Src", "Work"), "Work", ("Work", "Sink"))
    return jg, [JobConstraint(seq, limit_ms, 3_000.0, name="slo"),
                ThroughputConstraint("Work", 300.0, window_ms=3_000.0,
                                     max_parallelism=8)]


def _violation_ms(timeline: dict, limit_ms: float, bucket_ms: float) -> float:
    """SLO-violation milliseconds: total width of latency-timeline buckets
    whose mean sink latency breaches the limit."""
    return sum(bucket_ms for mean in timeline.values() if mean > limit_ms)


def run_proactive_burst(smoke: bool = False):
    """Flash-crowd + diurnal traces, reactive vs proactive, BOTH backends.

    Same offered trace per pair (matched throughput); the derived columns
    record SLO-violation milliseconds (latency-timeline buckets over the
    limit) and peak latency.  The proactive arm must strictly beat the
    reactive baseline on the flash crowd — forecasting the ramp buys the
    scale-out before the SLO trips instead of after."""
    rows = []
    limit = 150.0
    procfg = ProactiveConfig(horizon_ms=3_000.0, estimator="trend")
    violation: dict = {}

    # -- simulator: simulated seconds, bit-deterministic ---------------------
    at_ms = 8_000.0 if smoke else 10_000.0
    sim_traces = {
        "flash": (flash_crowd(150.0, 4.0, at_ms, ramp_ms=3_000.0,
                              hold_ms=8_000.0, decay_ms=5_000.0, seed=7),
                  30_000.0 if smoke else 40_000.0),
        "diurnal": (diurnal(120.0, 560.0, period_ms=20_000.0, seed=3),
                    40_000.0 if smoke else 60_000.0),
    }
    for tname, (trace, dur_ms) in sim_traces.items():
        for mode, pro in (("reactive", None), ("proactive", procfg)):
            jg, jcs = _qos_burst_job(limit)
            # the trace is the TOTAL offered load; each of the 2 source
            # tasks paces at half of it
            per_task = (lambda f: lambda t: f(t) / 2.0)(trace)
            sim = StreamSimulator(
                jg, jcs, num_workers=2,
                sources={"Src": SimSourceSpec(75.0, item_bytes=256,
                                              keys=64, rate_fn=per_task)},
                initial_buffer_bytes=2048, enable_qos=True,
                enable_chaining=False, seed=17, proactive=pro)
            t0 = time.perf_counter()
            res = sim.run(dur_ms)
            wall = (time.perf_counter() - t0) * 1e6
            v = _violation_ms(res.latency_timeline, limit, 1_000.0)
            peak = max(res.sink_latencies_ms, default=0.0)
            thr = len(res.sink_latencies_ms) / (dur_ms / 1e3)
            violation[("sim", tname, mode)] = v
            rows.append((
                f"proactive_burst_sim_{tname}_{mode}", wall,
                f"slo_violation_ms={v:.0f};peak_latency_ms={peak:.1f};"
                f"throughput_per_s={thr:.0f};"
                f"final={len(sim.rg.tasks_of('Work'))};"
                f"rescales={len(res.scale_log)};mode={mode}",
            ))
    assert (violation[("sim", "flash", "proactive")]
            < violation[("sim", "flash", "reactive")]), (
        f"proactive_burst_sim: proactive did not beat reactive on the "
        f"flash crowd ({violation[('sim', 'flash', 'proactive')]} vs "
        f"{violation[('sim', 'flash', 'reactive')]} violation ms)")

    # -- threaded engine: real seconds ---------------------------------------
    sleep_s = 0.004

    def work(p, emit, ctx):
        time.sleep(sleep_s)
        emit(p)

    if smoke:
        eng_at, eng_ramp, eng_hold, eng_decay = (2_500.0, 2_000.0,
                                                 2_000.0, 2_000.0)
        eng_flash_dur, eng_diurnal_dur, eng_period = (10_000.0, 10_000.0,
                                                      8_000.0)
    else:
        eng_at, eng_ramp, eng_hold, eng_decay = (4_000.0, 2_000.0,
                                                 4_000.0, 3_000.0)
        eng_flash_dur, eng_diurnal_dur, eng_period = (16_000.0, 16_000.0,
                                                      8_000.0)
    eng_traces = {
        "flash": (flash_crowd(150.0, 4.0, eng_at, ramp_ms=eng_ramp,
                              hold_ms=eng_hold, decay_ms=eng_decay, seed=7),
                  eng_flash_dur),
        "diurnal": (diurnal(120.0, 560.0, period_ms=eng_period, seed=3),
                    eng_diurnal_dur),
    }
    # short trend window: the engine's ramps are seconds long — a 5 s
    # window dilutes the fitted slope with pre-ramp flat history and the
    # forecast fires too late to beat the backlog
    eng_pro = ProactiveConfig(horizon_ms=2_000.0, estimator="trend",
                              estimator_args={"window_ms": 2_000.0})
    for tname, (trace, dur_ms) in eng_traces.items():
        for mode, pro in (("reactive", None), ("proactive", eng_pro)):
            jg2, jcs2 = _qos_burst_job(limit, work_fn=work,
                                       work_cost_ms=3.0)
            per_task = (lambda f: lambda t: f(t) / 2.0)(trace)
            eng = StreamEngine(
                jg2, jcs2, num_workers=2,
                sources={"Src": SourceSpec(
                    75.0, lambda s: (b"x" * 64, 64), rate_fn=per_task)},
                initial_buffer_bytes=2048, measurement_interval_ms=400.0,
                enable_qos=True, enable_chaining=False,
                latency_bucket_ms=500.0, proactive=pro)
            t0 = time.perf_counter()
            res2 = eng.run(dur_ms)
            wall = (time.perf_counter() - t0) * 1e6
            v = _violation_ms(res2.latency_timeline, limit, 500.0)
            peak = max(res2.sink_latencies_ms, default=0.0)
            thr = res2.items_at_sinks / (dur_ms / 1e3)
            violation[("engine", tname, mode)] = v
            rows.append((
                f"proactive_burst_engine_{tname}_{mode}", wall,
                f"slo_violation_ms={v:.0f};peak_latency_ms={peak:.1f};"
                f"throughput_per_s={thr:.0f};"
                f"final={len(eng.rg.tasks_of('Work'))};"
                f"rescales={len(res2.scale_log)};mode={mode}",
            ))
    if not smoke:
        # real-time arm: only the full-size run asserts the strict win
        # (smoke shapes are too short for a robust latency-bucket margin)
        assert (violation[("engine", "flash", "proactive")]
                < violation[("engine", "flash", "reactive")]), (
            f"proactive_burst_engine: proactive did not beat reactive on "
            f"the flash crowd "
            f"({violation[('engine', 'flash', 'proactive')]} vs "
            f"{violation[('engine', 'flash', 'reactive')]} violation ms)")
    return rows


# -- keyed_burst: stateful windowed aggregate through grow -> shrink ---------


def _keyed_job(agg_fn=None, agg_cost_ms: float = 2.0):
    """Stateful keyed job for BOTH backends: Src -> Agg(stateful) -> Sink
    (also stateful, so the sink holds the ground-truth per-key counts)."""
    jg = JobGraph("keyed-burst")
    jg.add_vertex(JobVertex("Src", 2, is_source=True, sim_cpu_ms=0.01))
    jg.add_vertex(JobVertex("Agg", 2, fn=agg_fn, sim_cpu_ms=agg_cost_ms,
                            sim_item_bytes=64, stateful=True))
    jg.add_vertex(JobVertex("Sink", 1, is_sink=True, sim_cpu_ms=0.01,
                            stateful=True))
    jg.add_edge("Src", "Agg", ALL_TO_ALL)
    jg.add_edge("Agg", "Sink", ALL_TO_ALL)
    seq = JobSequence.of(("Src", "Agg"), "Agg", ("Agg", "Sink"))
    return jg, [JobConstraint(seq, 1e9, 2_000.0, name="mon")]


def _merge_states(backend_tasks, group):
    merged: dict = {}
    for v in group:
        for k, n in backend_tasks(v).state.items():
            merged[k] = merged.get(k, 0) + n
    return merged


def run_keyed_burst(smoke: bool = False):
    """A stateful windowed-aggregate stage rescaled grow -> shrink mid-run on
    both backends; asserts the per-key aggregates are EXACT (state migrated
    with its key ranges, no key lost, duplicated, or split across owners)."""
    rows = []
    keys = 48

    # -- simulator ----------------------------------------------------------
    def _sim_arm(scheduler: str):
        jg, jcs = _keyed_job(agg_cost_ms=2.0)
        sim = StreamSimulator(
            jg, jcs, num_workers=2,
            sources={"Src": SimSourceSpec(
                200.0, item_bytes=64, keys=keys,
                # burst, taper, then silence so the pipeline fully drains
                rate_fn=lambda t: 200.0 if t < 8_000.0 else (
                    50.0 if t < 12_000.0 else 1e-9))},
            initial_buffer_bytes=256, enable_qos=False,
            max_buffer_lifetime_ms=500.0, scheduler=scheduler)
        sim.schedule(3_000.0, lambda: sim.scale_out("Agg", 5))
        sim.schedule(10_000.0, lambda: sim.scale_in("Agg", 2))
        t0 = time.perf_counter()
        res = sim.run(20_000.0)
        return sim, res, (time.perf_counter() - t0) * 1e6

    # warm both arms once (allocator/caches), then measure side by side —
    # same machine, same process, same run (docs/perf.md methodology)
    for sched in ("calendar", "heap"):
        _sim_arm(sched)
    sim, res, wall = _sim_arm("calendar")
    heap_sim, heap_res, heap_wall = _sim_arm("heap")
    assert heap_res.events == res.events, (
        "keyed_burst_sim: schedulers dispatched different event counts "
        f"({res.events} calendar vs {heap_res.events} heap)")
    assert heap_res.sink_latencies_ms == res.sink_latencies_ms, (
        "keyed_burst_sim: schedulers diverged on sink latencies")
    # events/sec over the sim.run wall — the CI perf canary (scripts/ci.sh
    # reads it from this derived column and enforces EVENTS_PER_SEC_FLOOR).
    # PR-4 baseline on the pre-overhaul event core: ~40k events/s through
    # this same harness.
    events_per_sec = res.events / (wall / 1e6)
    heap_events_per_sec = heap_res.events / (heap_wall / 1e6)
    group = sim.rg.tasks_of("Agg")
    agg = _merge_states(lambda v: sim.tasks[v], group)
    truth = dict(sim.tasks[sim.rg.tasks_of("Sink")[0]].state.items())
    router = sim.rg.routers["Agg"]
    single_owner = all(
        router.owner(k) == v.index
        for v in group for k in sim.tasks[v].state.keys())
    assert agg == truth, (
        f"keyed_burst_sim: per-key aggregates not exact "
        f"({sum(agg.values())} vs {sum(truth.values())})")
    assert single_owner, "keyed_burst_sim: key served off its owner"
    rows.append((
        "keyed_burst_sim", wall,
        f"keys={len(agg)};items={sum(agg.values())};exact=True;"
        f"single_owner=True;final={len(group)};"
        f"rescales={len(res.scale_log)};"
        f"events={res.events};events_per_sec={events_per_sec:.0f};"
        f"speedup_vs_heap={events_per_sec / heap_events_per_sec:.2f}x",
    ))
    rows.append((
        "keyed_burst_sim_heap", heap_wall,
        f"events={heap_res.events};"
        f"events_per_sec={heap_events_per_sec:.0f};scheduler=heap",
    ))
    # -- threaded engine ----------------------------------------------------
    def agg_fn(p, emit, ctx):
        ctx.state.bump(ctx._current_item.key)
        time.sleep(0.001)
        emit(p)

    phase_ms = 700.0 if smoke else 1_200.0
    jg2, jcs2 = _keyed_job(agg_fn=agg_fn)
    eng = StreamEngine(
        jg2, jcs2, num_workers=2,
        sources={"Src": SourceSpec(
            120.0, lambda s: (b"x" * 64, 64), key_of=lambda s: s % keys)},
        initial_buffer_bytes=512, measurement_interval_ms=400.0,
        enable_qos=False, enable_chaining=False,
        max_buffer_lifetime_ms=300.0)
    t0 = time.perf_counter()
    eng.start()
    time.sleep(phase_ms / 1e3)
    eng.scale_out("Agg", 4, reason="keyed_burst")
    time.sleep(phase_ms / 1e3)
    eng.scale_in("Agg", 2, reason="keyed_burst")
    time.sleep(phase_ms / 1e3)
    res2 = eng.stop()
    wall = (time.perf_counter() - t0) * 1e6
    expected: dict = {}
    for v, ex in eng.executors.items():
        if v.job_vertex == "Src":
            for s in range(ex.emitted):
                expected[s % keys] = expected.get(s % keys, 0) + 1
    group2 = eng.rg.tasks_of("Agg")
    agg2 = _merge_states(lambda v: eng.executors[v], group2)
    router2 = eng.rg.routers["Agg"]
    single_owner2 = all(
        router2.owner(k) == v.index
        for v in group2 for k in eng.executors[v].state.keys())
    assert agg2 == expected, (
        f"keyed_burst_engine: per-key aggregates not exact "
        f"({sum(agg2.values())} vs {sum(expected.values())})")
    assert single_owner2, "keyed_burst_engine: key served off its owner"
    rows.append((
        "keyed_burst_engine", wall,
        f"keys={len(agg2)};items={sum(agg2.values())};exact=True;"
        f"single_owner=True;sinks={res2.items_at_sinks};"
        f"rescales={len(res2.scale_log)}",
    ))
    return rows


# -- placement_burst: packed vs spread pools under the same bursty load -----


def _remote_fraction(rg) -> float:
    """Share of channels that cross workers — the locality cost the two
    policies trade off (remote channels pay serialize + ship)."""
    if not rg.channels:
        return 0.0
    remote = sum(1 for c in rg.channels
                 if rg.worker(c.src) != rg.worker(c.dst))
    return remote / len(rg.channels)


def run_placement_burst(smoke: bool = False):
    """The same bursty scale-out/in on elastic ``packed`` vs ``spread``
    worker pools, BOTH backends: growing Work past the pool's slot capacity
    must ACQUIRE workers (cloud acquisition), the shrink back must RELEASE
    every one of them (pool returns to its initial size), and the derived
    column reports the locality each policy bought (fraction of remote
    channels at peak)."""
    rows = []
    for policy in ("packed", "spread"):
        # -- simulator ------------------------------------------------------
        jg, jcs = _burst_job(work_cost_ms=4.0)
        pool = WorkerPool(2, policy=policy, slots_per_worker=4,
                          max_workers=8)
        sim = StreamSimulator(
            jg, jcs, sources={"Src": SimSourceSpec(
                150.0, item_bytes=256, keys=64,
                rate_fn=lambda t: 150.0 if t < 6_000.0 else 1e-9)},
            initial_buffer_bytes=2048, enable_qos=False,
            max_buffer_lifetime_ms=500.0, pool=pool)
        peak = {}

        def _grow_and_sample():
            sim.scale_out("Work", 8, reason="placement_burst")
            loads = pool.loads()
            peak["remote"] = _remote_fraction(sim.rg)
            peak["workers"] = len(loads)
            peak["imbalance"] = max(loads.values()) - min(loads.values())

        sim.schedule(2_000.0, _grow_and_sample)
        sim.schedule(7_000.0,
                     lambda: sim.scale_in("Work", 2, reason="burst over"))
        t0 = time.perf_counter()
        sim.run(12_000.0)
        wall = (time.perf_counter() - t0) * 1e6
        st = pool.stats()
        assert st["acquired"] > 0, f"placement_burst_sim_{policy}: " \
            f"scale-out past capacity never acquired a worker"
        assert st["released"] == st["acquired"], \
            f"placement_burst_sim_{policy}: acquired workers not released"
        assert pool.size() == 2, \
            f"placement_burst_sim_{policy}: pool did not return to initial"
        rows.append((
            f"placement_burst_sim_{policy}", wall,
            f"acquired={st['acquired']};released={st['released']};"
            f"final_workers={pool.size()};peak_workers={peak['workers']};"
            f"peak_imbalance={peak['imbalance']};"
            f"peak_remote={peak['remote']:.2f}",
        ))
        # -- threaded engine ------------------------------------------------
        def work(p, emit, ctx):
            time.sleep(0.002)
            emit(p)

        phase_s = 0.5 if smoke else 1.0
        jg2, jcs2 = _burst_job(work_fn=work, work_cost_ms=3.0)
        pool2 = WorkerPool(2, policy=policy, slots_per_worker=4,
                           max_workers=8)
        eng = StreamEngine(
            jg2, jcs2, sources={"Src": SourceSpec(
                100.0, lambda s: (b"x" * 64, 64))},
            initial_buffer_bytes=1024, measurement_interval_ms=400.0,
            enable_qos=False, enable_chaining=False,
            max_buffer_lifetime_ms=300.0, pool=pool2)
        t0 = time.perf_counter()
        eng.start()
        time.sleep(phase_s)
        eng.scale_out("Work", 8, reason="placement_burst")
        peak_remote_eng = _remote_fraction(eng.rg)
        loads2 = pool2.loads()
        peak_imbalance_eng = max(loads2.values()) - min(loads2.values())
        time.sleep(phase_s)
        eng.scale_in("Work", 2, reason="burst over")
        time.sleep(phase_s)
        res = eng.stop()
        wall = (time.perf_counter() - t0) * 1e6
        st2 = pool2.stats()
        emitted = sum(ex.emitted for v, ex in eng.executors.items()
                      if v.job_vertex == "Src")
        assert st2["acquired"] > 0 and st2["released"] == st2["acquired"], \
            f"placement_burst_engine_{policy}: acquire/release mismatch " \
            f"({st2})"
        assert pool2.size() == 2, \
            f"placement_burst_engine_{policy}: pool did not return to initial"
        assert emitted == res.items_at_sinks, \
            f"placement_burst_engine_{policy}: items lost " \
            f"({emitted} emitted vs {res.items_at_sinks} at sinks)"
        rows.append((
            f"placement_burst_engine_{policy}", wall,
            f"acquired={st2['acquired']};released={st2['released']};"
            f"final_workers={pool2.size()};"
            f"peak_imbalance={peak_imbalance_eng};"
            f"peak_remote={peak_remote_eng:.2f};"
            f"sinks={res.items_at_sinks}",
        ))
    return rows


def run(quick: bool = True, smoke: bool = False):
    rows = []
    grid = [(40, 10)] if smoke else [(40, 10), (200, 50), (800, 200)]
    for m, n in grid:
        r = run_one(m, n)
        rows.append((
            f"qos_setup_m{m}_n{n}",
            r["setup_ms"] * 1e3,
            f"managers={r['managers']};sequences={r['sequences']:.2e};"
            f"channels={r['channels']};max_subgraph={r['max_subgraph']};"
            f"routes={r['routes']}",
        ))
    rows.extend(run_elastic_burst(smoke=smoke))
    rows.extend(run_proactive_burst(smoke=smoke))
    rows.extend(run_keyed_burst(smoke=smoke))
    rows.extend(run_placement_burst(smoke=smoke))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.0f},{derived}")
