"""Seeded workload-trace generators shared by the benchmark scenarios.

Every generator returns a PURE function of elapsed time (``rate_fn``:
elapsed_ms -> items/s, the shared contract of ``SimSourceSpec.rate_fn`` and
``SourceSpec.rate_fn``) or of the source sequence number (``key_of``:
seq -> key, engine ``SourceSpec.key_of``), so the same trace drives the
discrete-event simulator and the threaded engine bit-for-bit.  All
randomness is drawn up front from ``random.Random(seed)`` (or derived
deterministically from the seed and the cycle index), never at call time —
a trace is replayable and two backends given the same seed see the same
workload.

Three families, after the usual stream-benchmark suspects:

* :func:`diurnal` — a day/night sinusoid between ``base`` and ``peak`` with
  a small seeded per-cycle amplitude jitter;
* :func:`flash_crowd` — steady ``base`` until ``at_ms``, then a linear ramp
  to ``spike`` x base, a hold, and an exponential decay back (the classic
  crash-under-load backdrop: benchmarks/faults.py kills a worker mid-spike);
* :func:`adversarial_key_skew` — a Zipf-like key chooser where a small
  seeded hot set absorbs most traffic and (optionally) rotates, the worst
  case for key-range routing and recovery-time state restore.
"""
from __future__ import annotations

import math
import random
from typing import Callable

__all__ = ["diurnal", "flash_crowd", "adversarial_key_skew"]


def diurnal(base: float, peak: float, period_ms: float = 20_000.0,
            seed: int = 0, jitter: float = 0.1) -> Callable[[float], float]:
    """Sinusoidal day/night pacing between ``base`` and ``peak`` items/s.

    Each full period gets one seeded amplitude factor in
    ``[1 - jitter, 1 + jitter]`` (derived from ``seed`` and the cycle index,
    so the trace is a pure function of elapsed time).  The factor is
    interpolated linearly across the cycle (this cycle's factor at the
    trough, the next cycle's at the following trough), so the rate is
    continuous at cycle boundaries; the result is clamped to
    ``[base, peak]``, the documented band."""
    if peak < base:
        raise ValueError(f"peak {peak} < base {base}")
    mid = (base + peak) / 2.0
    amp = (peak - base) / 2.0

    def _wobble(cycle: int) -> float:
        return 1.0 + jitter * (
            2.0 * random.Random(seed * 1_000_003 + cycle).random() - 1.0)

    def rate_fn(elapsed_ms: float) -> float:
        cycle = int(elapsed_ms // period_ms)
        frac = (elapsed_ms % period_ms) / period_ms
        wob = _wobble(cycle) + (_wobble(cycle + 1) - _wobble(cycle)) * frac
        phase = 2.0 * math.pi * frac
        # start at the trough: a freshly started job warms up, not slams
        raw = mid - amp * math.cos(phase) * wob
        return min(max(raw, base), peak)

    return rate_fn


def flash_crowd(base: float, spike: float, at_ms: float,
                ramp_ms: float = 2_000.0, hold_ms: float = 4_000.0,
                decay_ms: float = 4_000.0, seed: int = 0,
                stop_ms: float | None = None) -> Callable[[float], float]:
    """Flash-crowd trace: ``base`` items/s, then at ``at_ms`` a linear ramp
    over ``ramp_ms`` to ``spike * base``, held for ``hold_ms``, decaying
    exponentially back to ``base`` over ``decay_ms``.

    ``seed`` jitters the realized spike magnitude by up to +/-10% (seeded
    once, not per call).  ``stop_ms`` optionally silences the source after
    that instant so a bounded benchmark run can fully drain — required for
    the exact per-key conservation checks in benchmarks/faults.py."""
    mag = spike * base * (0.9 + 0.2 * random.Random(seed).random())
    t_ramp_end = at_ms + ramp_ms
    t_hold_end = t_ramp_end + hold_ms

    def rate_fn(elapsed_ms: float) -> float:
        if stop_ms is not None and elapsed_ms >= stop_ms:
            return 0.0
        if elapsed_ms < at_ms:
            return base
        if elapsed_ms < t_ramp_end:
            return base + (mag - base) * (elapsed_ms - at_ms) / ramp_ms
        if elapsed_ms < t_hold_end:
            return mag
        # exponential decay with time constant decay_ms / 3 (~95% settled
        # after decay_ms)
        dt = elapsed_ms - t_hold_end
        return base + (mag - base) * math.exp(-3.0 * dt / decay_ms)

    return rate_fn


def adversarial_key_skew(keys: int, hot_fraction: float = 0.1,
                         hot_weight: float = 0.9, seed: int = 0,
                         rotate_every: int | None = None
                         ) -> Callable[[int], int]:
    """Adversarial key chooser for ``SourceSpec.key_of``: a seeded hot set
    of ``ceil(keys * hot_fraction)`` keys absorbs ``hot_weight`` of all
    traffic; with ``rotate_every`` set, the hot set rotates through the key
    space every that many items — the worst case for key-range routing
    (one owner melts) and for recovery (the restored ranges are the loaded
    ones).  Pure function of ``seq``: the per-item choice is derived from
    ``seed`` and ``seq``, so replay after a crash regenerates the identical
    key sequence (docs/robustness.md replay-window semantics)."""
    if not 0 < hot_fraction <= 1:
        raise ValueError(f"hot_fraction {hot_fraction} outside (0, 1]")
    n_hot = max(1, math.ceil(keys * hot_fraction))
    perm = list(range(keys))
    random.Random(seed).shuffle(perm)

    def key_of(seq: int) -> int:
        r = random.Random(seed * 2_000_003 + seq)
        shift = 0 if rotate_every is None else (seq // rotate_every) * n_hot
        if r.random() < hot_weight:
            return perm[(shift + r.randrange(n_hot)) % keys]
        return perm[(shift + n_hot + r.randrange(keys - n_hot)) % keys]

    return key_of
