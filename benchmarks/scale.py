"""Fig. 6/8 at paper scale: the livestream pipeline (Partitioner -> Decoder
-> Merger -> Overlay -> Encoder -> RTPServer) on n=200 simulated workers,
QoS constraints ON vs OFF.

The paper's headline result: with the 300 ms / 15 s constraint armed, the
QoS manager's adaptive output-buffer sizing cuts workflow latency by more
than an order of magnitude (>=13x here, ~80x at the recorded settings)
while sustaining the same throughput — against the identical job with
static 32 KB buffers (the constraints-off / Fig. 7 configuration).

Run shape (non-smoke): m=200 parallelism on n=200 workers, 800 streams at
25 fps (20k items/s offered), 60 s of simulated time per arm, latencies
averaged after a 60% settle point so the constraints-on arm is measured
converged.  Routing uses 1024 virtual key ranges (m=200 exceeds the
default 128-range table; core/routing.py).  Smoke mode shrinks the cluster
to n=20 for seconds-level CI.

The non-smoke run records the repo's first perf-trajectory artifact,
``BENCH_scale.json`` (wall time, events/sec, mean/max latency, throughput,
latency factor), via the shared bench-writer in benchmarks/run.py.
"""
from __future__ import annotations

import sys
import time
from pathlib import Path

sys.path.insert(0, "src")
# standalone execution (`python benchmarks/scale.py`): make the repo root
# importable so the shared bench-writer (benchmarks.run) resolves
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from repro.configs.nephele_media import (  # noqa: E402
    H264_PACKET_BYTES,
    MediaJobParams,
    build_media_job,
)
from repro.core import SimSourceSpec, StreamSimulator  # noqa: E402

#: constraints-on mean latency must beat constraints-off by at least this
#: factor at matched throughput (the paper's Fig. 7 vs Fig. 8 gap).
LATENCY_FACTOR_FLOOR = 13.0
#: "matched throughput": the constrained arm must deliver at least this
#: share of the unconstrained arm's rate.
THROUGHPUT_MATCH = 0.95


def _run_arm(constraints_on: bool, n: int, m: int, streams: int,
             duration_ms: float, seed: int = 42) -> dict:
    p = MediaJobParams(parallelism=m, num_workers=n, streams=streams,
                      fps=25.0, latency_limit_ms=300.0)
    jg, jcs = build_media_job(p)
    gpp = (p.streams // p.group_size) // p.parallelism
    sim = StreamSimulator(
        jg, jcs, p.num_workers,
        sources={"Partitioner": SimSourceSpec(
            rate_items_per_s=p.fps * p.streams / p.parallelism,
            item_bytes=H264_PACKET_BYTES, keys_per_task=gpp)},
        initial_buffer_bytes=32 * 1024,
        measurement_interval_ms=1_000.0,
        enable_qos=constraints_on, enable_chaining=constraints_on,
        seed=seed,
        # m > 128 needs a wider routing table than the default 128 virtual
        # ranges, or stages past index 127 would never receive a key
        num_key_ranges=1024 if m > 128 else None,
    )
    t0 = time.perf_counter()
    res = sim.run(duration_ms)
    wall_s = time.perf_counter() - t0
    settle = duration_ms * 0.6
    return {
        "constraints": "on" if constraints_on else "off",
        "wall_s": round(wall_s, 3),
        "events": res.events,
        "events_per_sec": round(res.events / wall_s, 1),
        "mean_latency_ms": round(res.mean_latency_ms(settle), 3),
        "max_latency_ms": round(res.max_latency_ms(settle), 3),
        "throughput_items_per_s": round(res.throughput_items_per_s, 1),
        "items_at_sinks": len(res.sink_latencies_ms),
        "total_buffers": res.total_buffers,
        "total_mb": round(res.total_bytes / 1e6, 1),
        "chains": len(res.chained_groups),
        "give_ups": len(res.give_ups),
    }


def run_scale(n: int, m: int, streams: int, duration_ms: float,
              record: bool) -> list[tuple[str, float, str]]:
    off = _run_arm(False, n, m, streams, duration_ms)
    on = _run_arm(True, n, m, streams, duration_ms)
    factor = off["mean_latency_ms"] / max(on["mean_latency_ms"], 1e-9)
    matched = (on["throughput_items_per_s"]
               >= THROUGHPUT_MATCH * off["throughput_items_per_s"])
    floor = LATENCY_FACTOR_FLOOR if record else 5.0
    assert factor >= floor, (
        f"scale n={n}: constraints-on mean latency "
        f"{on['mean_latency_ms']}ms vs off {off['mean_latency_ms']}ms — "
        f"factor {factor:.1f}x below the {floor}x floor")
    assert matched, (
        f"scale n={n}: throughput not matched "
        f"({on['throughput_items_per_s']}/s on vs "
        f"{off['throughput_items_per_s']}/s off)")
    if record:
        from benchmarks.run import write_bench
        write_bench("scale", {
            "scenario": "fig8_livestream",
            "workers": n, "parallelism": m, "streams": streams,
            "fps": 25.0, "duration_ms": duration_ms,
            "latency_limit_ms": 300.0, "window_ms": 15_000.0,
            "latency_factor": round(factor, 1),
            "throughput_matched": matched,
            "arms": [off, on],
        })
    rows = []
    for arm in (off, on):
        derived = (
            f"mean_ms={arm['mean_latency_ms']};max_ms={arm['max_latency_ms']};"
            f"thr={arm['throughput_items_per_s']};events={arm['events']};"
            f"events_per_sec={arm['events_per_sec']}")
        if arm["constraints"] == "on":
            derived += f";factor={factor:.1f}x"
        rows.append((f"scale_n{n}_{arm['constraints']}",
                     arm["wall_s"] * 1e6, derived))
    return rows


def run(quick: bool = True, smoke: bool = False):
    if smoke:
        # seconds-level CI canary: same physics, n=20 cluster, no artifact
        return run_scale(n=20, m=20, streams=80, duration_ms=30_000.0,
                         record=False)
    # the recorded n=200 run (BENCH_scale.json)
    return run_scale(n=200, m=200, streams=800, duration_ms=60_000.0,
                     record=True)


if __name__ == "__main__":
    for name, us, derived in run(quick="--full" not in sys.argv,
                                 smoke="--smoke" in sys.argv):
        print(f"{name},{us:.0f},{derived}")
