"""Fig. 6/8 at paper scale: the livestream pipeline (Partitioner -> Decoder
-> Merger -> Overlay -> Encoder -> RTPServer) on n=200 simulated workers,
QoS constraints ON vs OFF.

The paper's headline result: with the 300 ms / 15 s constraint armed, the
QoS manager's adaptive output-buffer sizing cuts workflow latency by more
than an order of magnitude (>=13x here) while sustaining the same
throughput — against the identical job with static 32 KB buffers (the
constraints-off / Fig. 7 configuration).

Recorded grids (non-smoke; BENCH_scale.json via the shared bench-writer in
benchmarks/run.py):

* n=200 / m=200 / 800 streams (20k items/s offered), exact AND batched
  event cores — the pair gives the exact-vs-batched events/sec trajectory
  at identical physics,
* n=200 / m=800 / 3200 streams (~80k items/s offered) — the paper's FULL
  Fig. 8 grid in BOTH event cores: the calendar-queue scheduler +
  struct-of-arrays dispatch (core/eventq.py) makes the exact core's
  per-completion event stream recordable at this scale for the first
  time (the pre-overhaul heap core managed ~40k events/s; see
  docs/perf.md).

Latencies are averaged after a 60% settle point so the constraints-on arm
is measured converged.  Routing uses 1024 virtual key ranges where m
exceeds the default 128-range table (core/routing.py; `key_ranges_for`
fails fast when a grid exceeds the widest table instead of silently
mis-routing).  Smoke mode shrinks the cluster to n=20 for seconds-level CI
and runs BOTH event modes, asserting cross-mode equivalence (the strict
decision-level contract lives in tests/test_sim_modes.py).
"""
from __future__ import annotations

import sys
import time
from pathlib import Path

sys.path.insert(0, "src")
# standalone execution (`python benchmarks/scale.py`): make the repo root
# importable so the shared bench-writer (benchmarks.run) resolves
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from repro.configs.nephele_media import (  # noqa: E402
    H264_PACKET_BYTES,
    MediaJobParams,
    build_media_job,
)
from repro.core import (  # noqa: E402
    SimSourceSpec,
    StreamSimulator,
)
from repro.core.routing import (  # noqa: E402,F401  (re-exported policy)
    WIDE_KEY_RANGES,
    key_ranges_for,
)

#: constraints-on mean latency must beat constraints-off by at least this
#: factor at matched throughput (the paper's Fig. 7 vs Fig. 8 gap).
LATENCY_FACTOR_FLOOR = 13.0
#: "matched throughput": the constrained arm must deliver at least this
#: share of the unconstrained arm's rate.
THROUGHPUT_MATCH = 0.95
#: cross-mode smoke equivalence: batched mean latency within this relative
#: tolerance of exact (the golden-scenario contract in tests/test_sim_modes
#: is 1%; the smoke arm allows the same).
MODE_LATENCY_RTOL = 0.01


def _run_arm(constraints_on: bool, n: int, m: int, streams: int,
             duration_ms: float, seed: int = 42,
             event_mode: str = "exact",
             scheduler: str = "calendar") -> dict:
    p = MediaJobParams(parallelism=m, num_workers=n, streams=streams,
                      fps=25.0, latency_limit_ms=300.0)
    jg, jcs = build_media_job(p)
    gpp = (p.streams // p.group_size) // p.parallelism
    if gpp < 1:
        raise ValueError(
            f"grid m={m}/streams={streams}: fewer stream groups "
            f"({streams // p.group_size}) than Partitioner subtasks ({m}); "
            f"each subtask needs >= 1 owned group (raise streams)")
    sim = StreamSimulator(
        jg, jcs, p.num_workers,
        sources={"Partitioner": SimSourceSpec(
            rate_items_per_s=p.fps * p.streams / p.parallelism,
            item_bytes=H264_PACKET_BYTES, keys_per_task=gpp)},
        initial_buffer_bytes=32 * 1024,
        measurement_interval_ms=1_000.0,
        enable_qos=constraints_on, enable_chaining=constraints_on,
        seed=seed,
        num_key_ranges=key_ranges_for(m),
        event_mode=event_mode,
        scheduler=scheduler,
    )
    t0 = time.perf_counter()
    res = sim.run(duration_ms)
    wall_s = time.perf_counter() - t0
    settle = duration_ms * 0.6
    return {
        "constraints": "on" if constraints_on else "off",
        "event_mode": event_mode,
        "scheduler": scheduler,
        "wall_s": round(wall_s, 3),
        "events": res.events,
        "events_per_sec": round(res.events / wall_s, 1),
        "mean_latency_ms": round(res.mean_latency_ms(settle), 3),
        "max_latency_ms": round(res.max_latency_ms(settle), 3),
        "throughput_items_per_s": round(res.throughput_items_per_s, 1),
        "items_at_sinks": len(res.sink_latencies_ms),
        "total_buffers": res.total_buffers,
        "total_mb": round(res.total_bytes / 1e6, 1),
        "chains": len(res.chained_groups),
        "give_ups": len(res.give_ups),
    }


def run_scale(n: int, m: int, streams: int, duration_ms: float,
              record_floor: bool,
              event_mode: str = "exact",
              scheduler: str = "calendar") -> tuple[list, dict]:
    """One constraints-off/on grid in one event mode and scheduler.
    Returns the printable rows and the grid record (for BENCH_scale.json)."""
    off = _run_arm(False, n, m, streams, duration_ms, event_mode=event_mode,
                   scheduler=scheduler)
    on = _run_arm(True, n, m, streams, duration_ms, event_mode=event_mode,
                  scheduler=scheduler)
    factor = off["mean_latency_ms"] / max(on["mean_latency_ms"], 1e-9)
    matched = (on["throughput_items_per_s"]
               >= THROUGHPUT_MATCH * off["throughput_items_per_s"])
    floor = LATENCY_FACTOR_FLOOR if record_floor else 5.0
    assert factor >= floor, (
        f"scale n={n} m={m} [{event_mode}]: constraints-on mean latency "
        f"{on['mean_latency_ms']}ms vs off {off['mean_latency_ms']}ms — "
        f"factor {factor:.1f}x below the {floor}x floor")
    assert matched, (
        f"scale n={n} m={m} [{event_mode}]: throughput not matched "
        f"({on['throughput_items_per_s']}/s on vs "
        f"{off['throughput_items_per_s']}/s off)")
    grid = {
        "scenario": "fig8_livestream",
        "workers": n, "parallelism": m, "streams": streams,
        "event_mode": event_mode,
        "scheduler": scheduler,
        "fps": 25.0, "duration_ms": duration_ms,
        "offered_items_per_s": 25.0 * streams,
        "latency_limit_ms": 300.0, "window_ms": 15_000.0,
        "latency_factor": round(factor, 1),
        "throughput_matched": matched,
        "arms": [off, on],
    }
    suffix = "" if event_mode == "exact" else f"_{event_mode}"
    if scheduler != "calendar":
        suffix += f"_{scheduler}"
    rows = []
    for arm in (off, on):
        derived = (
            f"mean_ms={arm['mean_latency_ms']};max_ms={arm['max_latency_ms']};"
            f"thr={arm['throughput_items_per_s']};events={arm['events']};"
            f"events_per_sec={arm['events_per_sec']}")
        if arm["constraints"] == "on":
            derived += f";factor={factor:.1f}x"
        rows.append((f"scale_n{n}_m{m}_{arm['constraints']}{suffix}",
                     arm["wall_s"] * 1e6, derived))
    return rows, grid


def _assert_mode_equivalence(exact_grid: dict, batched_grid: dict) -> None:
    """Smoke-level cross-mode equivalence: identical item conservation and
    QoS outcome shape, latency within MODE_LATENCY_RTOL per arm."""
    for ge, gb in zip(exact_grid["arms"], batched_grid["arms"]):
        assert ge["items_at_sinks"] == gb["items_at_sinks"], (
            f"mode equivalence: sink items diverged "
            f"({ge['items_at_sinks']} exact vs {gb['items_at_sinks']} "
            f"batched, constraints {ge['constraints']})")
        assert ge["chains"] == gb["chains"] and \
            ge["give_ups"] == gb["give_ups"], (
            f"mode equivalence: QoS outcomes diverged (constraints "
            f"{ge['constraints']}: chains {ge['chains']}/{gb['chains']}, "
            f"give_ups {ge['give_ups']}/{gb['give_ups']})")
        me, mb = ge["mean_latency_ms"], gb["mean_latency_ms"]
        assert abs(mb - me) <= MODE_LATENCY_RTOL * max(me, 1e-9), (
            f"mode equivalence: mean latency diverged {me} vs {mb} "
            f"(constraints {ge['constraints']})")


def run_full_grid(duration_ms: float = 60_000.0,
                  record: bool = True) -> list[tuple[str, float, str]]:
    """The recorded paper-scale run: m=200 in both event modes (the
    exact-vs-batched perf trajectory) + the FULL Fig. 8 m=800 grid in
    both cores — the exact-mode m=800 leg exists because of the
    calendar-queue event core.  Writes BENCH_scale.json when ``record``."""
    rows: list = []
    grids: list[dict] = []
    for m, streams, mode in ((200, 800, "exact"), (200, 800, "batched"),
                             (800, 3200, "exact"), (800, 3200, "batched")):
        r, g = run_scale(n=200, m=m, streams=streams,
                         duration_ms=duration_ms, record_floor=True,
                         event_mode=mode)
        rows.extend(r)
        grids.append(g)
        if len(grids) == 2:
            # check the m=200 exact-vs-batched pair BEFORE spending the
            # long m=800 leg: a mode divergence should fail in minutes,
            # not after the costliest grid has run
            _assert_mode_equivalence(grids[0], grids[1])
    if record:
        from benchmarks.run import write_bench
        write_bench("scale", {"grids": grids})
    return rows


def _assert_scheduler_equivalence(cal_grid: dict, heap_grid: dict) -> None:
    """The two schedulers are the SAME physics down to the bit (they share
    one total order), so their arms must agree exactly — not within a
    tolerance like the cross-mode check."""
    for gc, gh in zip(cal_grid["arms"], heap_grid["arms"]):
        for key in ("events", "items_at_sinks", "mean_latency_ms",
                    "max_latency_ms", "throughput_items_per_s",
                    "total_buffers", "total_mb", "chains", "give_ups"):
            assert gc[key] == gh[key], (
                f"scheduler equivalence: {key} diverged "
                f"({gc[key]} calendar vs {gh[key]} heap, "
                f"constraints {gc['constraints']})")


def run(quick: bool = True, smoke: bool = False):
    if smoke:
        # seconds-level CI canary: same physics, n=20 cluster, BOTH event
        # modes AND both schedulers; cross-mode equivalence (1% latency
        # tolerance) and bit-exact cross-scheduler equivalence asserted
        rows, exact_grid = run_scale(n=20, m=20, streams=80,
                                     duration_ms=30_000.0,
                                     record_floor=False)
        hrows, heap_grid = run_scale(n=20, m=20, streams=80,
                                     duration_ms=30_000.0,
                                     record_floor=False,
                                     scheduler="heap")
        _assert_scheduler_equivalence(exact_grid, heap_grid)
        brows, batched_grid = run_scale(n=20, m=20, streams=80,
                                        duration_ms=30_000.0,
                                        record_floor=False,
                                        event_mode="batched")
        _assert_mode_equivalence(exact_grid, batched_grid)
        return rows + hrows + brows
    # the recorded n=200 grids (BENCH_scale.json), m=800 included
    return run_full_grid()


if __name__ == "__main__":
    for name, us, derived in run(quick="--full" not in sys.argv,
                                 smoke="--smoke" in sys.argv):
        print(f"{name},{us:.0f},{derived}")
