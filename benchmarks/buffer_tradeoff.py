"""Paper Fig. 2: output-buffer size x data-creation rate -> latency and
throughput, on the discrete-event simulator (sender -> receiver over one
TCP-like link, 128-byte items)."""
from __future__ import annotations

import sys

sys.path.insert(0, "src")

from repro.core import (  # noqa: E402
    ALL_TO_ALL,
    JobConstraint,
    JobGraph,
    JobSequence,
    JobVertex,
    SimSourceSpec,
    StreamSimulator,
)


def run_one(buffer_bytes: int, rate: float, duration_ms: float = 60_000.0):
    jg = JobGraph("fig2")
    jg.add_vertex(JobVertex("Sender", 1, is_source=True, sim_cpu_ms=0.001,
                            sim_item_bytes=128))
    jg.add_vertex(JobVertex("Receiver", 1, is_sink=True, sim_cpu_ms=0.001))
    jg.add_edge("Sender", "Receiver", ALL_TO_ALL)
    seq = JobSequence.of(("Sender", "Receiver"))
    jc = JobConstraint(seq, 1e9, 10_000.0, name="fig2")  # monitoring only
    sim = StreamSimulator(
        jg, [jc], num_workers=2,
        sources={"Sender": SimSourceSpec(rate_items_per_s=rate,
                                         item_bytes=128)},
        initial_buffer_bytes=buffer_bytes,
        enable_qos=False,
        # the pure Fig. 2 sweep: buffer-fill time must be the only latency
        # knob, so the max-buffer-lifetime flush is explicitly disabled
        max_buffer_lifetime_ms=None,
    )
    res = sim.run(duration_ms, max_events=3_000_000)
    return res.mean_latency_ms(duration_ms * 0.2), res.throughput_items_per_s


def run(quick: bool = True):
    rows = []
    buffers = [1024, 8192, 65536] if quick else [512, 1024, 4096, 8192,
                                                 32768, 65536]
    rates = [10, 1000, 20000] if quick else [1, 10, 100, 1000, 10000, 20000]
    for b in buffers:
        for r in rates:
            lat, thru = run_one(b, r)
            rows.append((f"fig2_buf{b}_rate{r}", lat * 1e3,
                         f"lat_ms={lat:.1f};thru={thru:.0f}/s"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run(quick=False):
        print(f"{name},{us:.1f},{derived}")
