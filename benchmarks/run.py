"""Benchmark aggregator — one module per paper figure/table + the framework
benches.  Prints ``name,us_per_call,derived`` CSV lines.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME] [--smoke]
                                            [--bench-out] [--profile]

``--smoke``: CI mode — tiny shapes, seconds not minutes, to catch executor
regressions.  Only modules whose ``run`` accepts a ``smoke`` keyword take
part (the rest are skipped); failures still exit non-zero.

``--profile``: wrap each module's ``run`` in cProfile and write the raw
stats to ``BENCH_<module>.prof`` next to the JSON artifact (inspect with
``python -m pstats BENCH_<module>.prof``) — so perf PRs start from a
recorded profile instead of guesswork.  Profiling inflates wall times;
numbers from a profiled run are for attribution, not for the perf
trajectory.

``--bench-out``: record the run — every module's rows land in
``BENCH_<module>.json`` at the repo root via :func:`write_bench`, the
repo's perf trajectory (one JSON per module per recorded run; commit them
to track events/sec across PRs).  Modules may also call ``write_bench``
directly with richer payloads (benchmarks/scale.py writes
``BENCH_scale.json`` with wall-time / events-per-sec / latency /
throughput per grid: the n=200/m=200 pair in both event cores plus the
full Fig. 8 n=200/m=800 grid on the batched core).
"""
from __future__ import annotations

import argparse
import inspect
import json
import sys
import time
import traceback
from pathlib import Path

sys.path.insert(0, "src")
# standalone execution (`python benchmarks/run.py`): make the repo root
# importable so the canonical-module delegation below resolves
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

#: repo root — BENCH_<name>.json files land here.
BENCH_DIR = Path(__file__).resolve().parent.parent

MODULES = [
    ("buffer_tradeoff", "Fig. 2: buffer size x rate -> latency/throughput"),
    ("media_pipeline", "Figs. 7-10: media job scenario suite"),
    ("qos_scaling", "§3.4: QoS setup algorithms at n=200, m=800"),
    ("scale", "Fig. 8 at n=200 up to m=800: constraints on/off, exact + "
              "batched event cores, >=13x latency factor"),
    ("serving_qos", "serving-plane QoS: adaptive batching + chaining"),
    ("faults", "crash-under-load: fault injection + checkpoint recovery, "
               "time-to-detect/recover/SLO-recovery on both backends"),
    ("kernels", "Pallas kernel validation vs oracles"),
    ("roofline", "dry-run roofline terms per (arch x shape)"),
]


#: bench names written during this process — the generic ``--bench-out``
#: row dump never clobbers an artifact a module wrote itself, and a smoke
#: run never overwrites a module's recorded full-scale artifact.
_written: set[str] = set()


def write_bench(name: str, payload: dict) -> Path:
    """Shared bench-writer: record ``payload`` as ``BENCH_<name>.json`` at
    the repo root.  The envelope carries the bench name and a wall-clock
    stamp; everything else is the caller's measurement dict."""
    out = BENCH_DIR / f"BENCH_{name}.json"
    doc = {"bench": name, "recorded_unix_s": round(time.time(), 1)}
    doc.update(payload)
    out.write_text(json.dumps(doc, indent=1, sort_keys=True) + "\n")
    _written.add(name)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke mode: tiny shapes, seconds not minutes")
    ap.add_argument("--bench-out", action="store_true",
                    help="write BENCH_<module>.json rows next to the repo "
                         "root (perf trajectory)")
    ap.add_argument("--profile", action="store_true",
                    help="cProfile each module and write BENCH_<module>.prof "
                         "next to the JSON artifact")
    args = ap.parse_args()

    # preflight WARNs (graph_check/feasibility, e.g. NS-F002 "goal only
    # reachable near max scale-out") are advisory and never raise — surface
    # them per CSV row so a benchmark topology drifting toward its
    # feasibility edge is visible in the perf trajectory, not swallowed.
    from repro.analysis import graph_check

    failures = []
    print("name,us_per_call,derived,preflight_warns")
    for mod_name, desc in MODULES:
        if args.only and args.only != mod_name:
            continue
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            kwargs = {"quick": not args.full}
            if args.smoke:
                if "smoke" not in inspect.signature(mod.run).parameters:
                    continue  # module has no smoke-sized variant yet
                kwargs["smoke"] = True
            rows = []
            warn_mark = graph_check.preflight_warn_count
            if args.profile:
                # profile the module's whole run (modules may return lists
                # or generators — consume under the profiler either way)
                import cProfile

                prof = cProfile.Profile()
                prof.enable()
                try:
                    results = list(mod.run(**kwargs))
                finally:
                    prof.disable()
                    prof.dump_stats(BENCH_DIR / f"BENCH_{mod_name}.prof")
            else:
                results = mod.run(**kwargs)
            for name, us, derived in results:
                warns = graph_check.preflight_warn_count - warn_mark
                warn_mark = graph_check.preflight_warn_count
                rows.append({"name": name, "us_per_call": round(us, 1),
                             "derived": derived, "preflight_warns": warns})
                print(f"{name},{us:.1f},{derived},{warns}", flush=True)
            if args.bench_out and rows and mod_name not in _written:
                if args.smoke and (BENCH_DIR / f"BENCH_{mod_name}.json"
                                   ).exists():
                    # never replace a recorded full-scale artifact with a
                    # smoke-sized row dump
                    continue
                write_bench(mod_name, {"smoke": args.smoke, "rows": rows})
        except Exception as e:  # noqa: BLE001
            failures.append((mod_name, repr(e)))
            traceback.print_exc()
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    # run via the canonical module instance: under ``python -m``, this file
    # executes as ``__main__`` while modules that self-record call
    # ``benchmarks.run.write_bench`` — two module instances would split the
    # ``_written`` registry and the generic row dump would clobber a
    # module's own artifact (e.g. BENCH_scale.json's grid payload)
    from benchmarks.run import main as _canonical_main

    _canonical_main()
