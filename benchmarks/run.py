"""Benchmark aggregator — one module per paper figure/table + the framework
benches.  Prints ``name,us_per_call,derived`` CSV lines.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME] [--smoke]

``--smoke``: CI mode — tiny shapes, seconds not minutes, to catch executor
regressions.  Only modules whose ``run`` accepts a ``smoke`` keyword take
part (the rest are skipped); failures still exit non-zero.
"""
from __future__ import annotations

import argparse
import inspect
import sys
import traceback

sys.path.insert(0, "src")

MODULES = [
    ("buffer_tradeoff", "Fig. 2: buffer size x rate -> latency/throughput"),
    ("media_pipeline", "Figs. 7-10: media job scenario suite"),
    ("qos_scaling", "§3.4: QoS setup algorithms at n=200, m=800"),
    ("serving_qos", "serving-plane QoS: adaptive batching + chaining"),
    ("kernels", "Pallas kernel validation vs oracles"),
    ("roofline", "dry-run roofline terms per (arch x shape)"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke mode: tiny shapes, seconds not minutes")
    args = ap.parse_args()

    failures = []
    print("name,us_per_call,derived")
    for mod_name, desc in MODULES:
        if args.only and args.only != mod_name:
            continue
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            kwargs = {"quick": not args.full}
            if args.smoke:
                if "smoke" not in inspect.signature(mod.run).parameters:
                    continue  # module has no smoke-sized variant yet
                kwargs["smoke"] = True
            for name, us, derived in mod.run(**kwargs):
                print(f"{name},{us:.1f},{derived}", flush=True)
        except Exception as e:  # noqa: BLE001
            failures.append((mod_name, repr(e)))
            traceback.print_exc()
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
