"""Paper Figs. 7/8/9 (+10): the video-aggregation job under four scenarios:

  none     — constraints monitored, no optimizations (Fig. 7)
  buffers  — adaptive output-buffer sizing only (Fig. 8)
  full     — buffers + dynamic task chaining (Fig. 9)
  hop      — Hadoop-Online-style baseline: fixed 32 KB buffers, static
             chain-mapper for Merger/Overlay/Encoder (Fig. 10)

Scale note (recorded in EXPERIMENTS.md): the Python event simulator runs a
proportionally reduced cluster (n=10 workers, m=40, 320 streams at the
paper's 8-streams-per-pipeline load) — the QoS control plane is the real
code; the paper's 200x800 setup is exercised structurally by
qos_scaling.py.
"""
from __future__ import annotations

import sys

sys.path.insert(0, "src")

from repro.configs.nephele_media import (  # noqa: E402
    H264_PACKET_BYTES,
    MediaJobParams,
    build_media_job,
)
from repro.core import SimSourceSpec, StreamSimulator  # noqa: E402


def run_scenario(scenario: str, p: MediaJobParams, duration_ms: float,
                 limit_ms: float | None = None):
    jg, jcs = build_media_job(p)
    if limit_ms is not None:
        from repro.core import JobConstraint
        jcs = [JobConstraint(jcs[0].sequence, limit_ms, jcs[0].window_ms,
                             name=jcs[0].name)]
    groups_per_partitioner = (p.streams // p.group_size) // p.parallelism
    sim = StreamSimulator(
        jg, jcs, p.num_workers,
        sources={"Partitioner": SimSourceSpec(
            rate_items_per_s=p.fps * p.streams / p.parallelism,
            item_bytes=H264_PACKET_BYTES,
            keys_per_task=groups_per_partitioner,
        )},
        initial_buffer_bytes=200 if scenario == "hop_small" else 32 * 1024,
        measurement_interval_ms=1_000.0,
        enable_qos=scenario in ("buffers", "full"),
        enable_chaining=scenario == "full",
    )
    if scenario == "hop":
        # static chain-mapper analogue: Merger/Overlay/Encoder fused from the
        # start (compile-time chaining, §2.2.2)
        from repro.core.chaining import ChainRequest
        for i in range(p.parallelism):
            req = ChainRequest(
                tuple(sim.rg.tasks_of(n)[i]
                      for n in ("Merger", "Overlay", "Encoder")),
                worker=i % p.num_workers,
            )
            sim._apply_chain(req)
    res = sim.run(duration_ms)
    settle = duration_ms * 0.6
    return res, res.mean_latency_ms(settle), res.max_latency_ms(settle)


def run(quick: bool = True):
    p = MediaJobParams(
        parallelism=8 if quick else 40,
        num_workers=2 if quick else 10,
        streams=64 if quick else 320,
        fps=25.0,
        latency_limit_ms=50.0,  # scaled SLO (see module docstring)
    )
    dur = 120_000.0 if quick else 300_000.0
    rows = []
    base = None
    for scenario, lim in (("none", None), ("buffers", None), ("full", None),
                          ("hop", None),
                          # scaled-down SLO where buffers alone are not
                          # enough, so dynamic chaining engages (Fig. 9's
                          # mechanism at this cluster scale)
                          ("buffers_tight", 22.0), ("full_tight", 22.0)):
        base_scenario = scenario.replace("_tight", "")
        # chaining engages only after the buffer phase settles (paper §4.3.2:
        # a ~9-minute convergence at full scale) -> tight runs get more time
        d = dur * 3 if lim is not None else dur
        res, mean, worst = run_scenario(base_scenario, p, d, limit_ms=lim)
        if scenario == "none":
            base = mean
        speedup = base / mean if base else float("nan")
        rows.append((
            f"media_{scenario}",
            mean * 1e3,
            f"mean_ms={mean:.1f};max_ms={worst:.1f};chains={len(res.chained_groups)};"
            f"giveups={len(res.give_ups)};speedup_vs_none={speedup:.1f}x",
        ))
    return rows


if __name__ == "__main__":
    for name, us, derived in run(quick="--full" not in sys.argv):
        print(f"{name},{us:.1f},{derived}")
