"""Quickstart: the paper's QoS scheme end-to-end in ~40 lines.

Builds a small streaming job with a latency constraint, runs it on the
discrete-event simulator without and with QoS management, and prints the
latency improvement from adaptive output-buffer sizing + dynamic chaining.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.configs.nephele_media import MediaJobParams, build_media_job
from repro.core import SimSourceSpec, StreamSimulator

params = MediaJobParams(parallelism=8, num_workers=2, streams=64, fps=25.0,
                        latency_limit_ms=50.0)
jg, constraints = build_media_job(params)
print(f"job: {list(jg.vertices)}  constraint: "
      f"{constraints[0].latency_limit_ms} ms over "
      f"{constraints[0].window_ms/1e3:.0f}s windows")

results = {}
for qos in (False, True):
    sim = StreamSimulator(
        jg, constraints, params.num_workers,
        sources={"Partitioner": SimSourceSpec(
            rate_items_per_s=params.fps * params.streams / params.parallelism,
            item_bytes=350, keys_per_task=2)},
        initial_buffer_bytes=32 * 1024,
        enable_qos=qos,
    )
    res = sim.run(120_000.0)
    results[qos] = res
    label = "QoS managed" if qos else "unoptimized"
    print(f"{label:12s}: mean latency {res.mean_latency_ms(60_000):8.1f} ms   "
          f"throughput {res.throughput_items_per_s:6.1f} items/s   "
          f"chains={len(res.chained_groups)}")

speedup = (results[False].mean_latency_ms(60_000)
           / results[True].mean_latency_ms(60_000))
print(f"latency improvement: {speedup:.1f}x (paper: >= 13x at 200 nodes)")
