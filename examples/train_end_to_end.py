"""End-to-end training driver: a ~100M-parameter qwen3-family model on the
streaming synthetic corpus for a few hundred steps, with checkpointing and
an injected failure + restart mid-run (the §3.6 rollback-recovery path).

    PYTHONPATH=src python examples/train_end_to_end.py [--steps 300]
"""
import argparse
import sys
import tempfile

sys.path.insert(0, "src")

from repro.launch.train import train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    # ~100M params: d_model 512, 8 layers, byte-level vocab
    overrides = dict(
        num_layers=8, d_model=512, num_heads=8, num_kv_heads=4,
        d_ff=2048, vocab_size=384, tie_embeddings=False,
        attn_chunk=128, remat=False,
    )
    with tempfile.TemporaryDirectory() as ckpt:
        out = train(
            arch="qwen3-1.7b", smoke=True, steps=args.steps,
            batch=args.batch, seq=args.seq,
            cfg_overrides=overrides,
            ckpt_dir=ckpt, save_every=max(args.steps // 4, 10),
            log_every=max(args.steps // 10, 1),
            fail_at={args.steps // 2: "injected node failure"},
        )
    first, last = out["losses"][0], out["losses"][-1]
    print(f"loss {first:.3f} -> {last:.3f} over {len(out['losses'])} steps "
          f"({out['steps_per_s']:.2f} steps/s, incl. one restart)")
    assert last < first, "loss must decrease"


if __name__ == "__main__":
    main()
