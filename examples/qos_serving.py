"""Serve a small model with batched requests under a latency SLO —
the paper's buffer/chaining trade-off on the serving plane (DESIGN.md §2.2).

    PYTHONPATH=src python examples/qos_serving.py [--duration 20]
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax

from repro.configs import get_config
from repro.models import build_model
from repro.serving import QoSServer, RequestSpec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=20.0)
    ap.add_argument("--rate", type=float, default=30.0)
    ap.add_argument("--slo-ms", type=float, default=400.0)
    args = ap.parse_args()

    cfg = get_config("qwen3-1.7b", smoke=True)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    spec = RequestSpec(rate_per_s=args.rate, prompt_len=16, gen_len=4,
                       vocab=cfg.vocab_size)

    for qos in (False, True):
        srv = QoSServer(model, params, spec, latency_limit_ms=args.slo_ms,
                        enable_qos=qos, initial_buffer_bytes=8192,
                        measurement_interval_ms=500.0)
        res = srv.run(args.duration * 1e3)
        label = "QoS adaptive" if qos else "fixed batch "
        print(f"{label}: {res.completed} done, mean {res.mean_latency_ms:.0f} ms, "
              f"p90 {res.p(0.9):.0f} ms, {res.throughput_rps:.1f} req/s, "
              f"mean batch {res.mean_batch:.1f}")


if __name__ == "__main__":
    main()
