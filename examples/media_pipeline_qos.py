"""The paper's evaluation job (Fig. 5-9) on the threaded engine with REAL
user code: JAX image ops stand in for the video pipeline stages
(decode -> merge/tile -> overlay -> encode), QoS constraints attached.

    PYTHONPATH=src python examples/media_pipeline_qos.py [--duration 30]
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ALL_TO_ALL, POINTWISE, JobConstraint, JobGraph,
                        JobSequence, JobVertex, SourceSpec, StreamEngine)

H = W = 32  # tiny frames so the CPU keeps up


@jax.jit
def _decode(packet):
    # "decode": expand a compressed packet into a frame (deterministic)
    x = jnp.arange(H * W, dtype=jnp.float32) + packet
    return jnp.reshape(x, (H, W)) / (H * W)


@jax.jit
def _merge(frames):
    a, b = jnp.split(frames, 2, axis=0)
    return jnp.concatenate([a, b], axis=1)


@jax.jit
def _overlay(frame):
    ticker = jnp.linspace(0, 1, frame.shape[1])
    return frame * 0.9 + ticker[None, :] * 0.1


@jax.jit
def _encode(frame):
    return jnp.mean(frame), jnp.std(frame)


def decode_fn(payload, emit, ctx):
    frame = _decode(jnp.float32(payload))
    emit(np.asarray(frame), size_bytes=frame.size * 4)


def merge_fn(payload, emit, ctx):
    buf = getattr(ctx, "_group", None)
    if buf is None:
        buf = ctx._group = []
    buf.append(payload)
    if len(buf) == 2:
        merged = _merge(jnp.concatenate([jnp.asarray(b) for b in buf], 0))
        buf.clear()
        emit(np.asarray(merged), size_bytes=merged.size * 4)


def overlay_fn(payload, emit, ctx):
    out = _overlay(jnp.asarray(payload))
    emit(np.asarray(out), size_bytes=out.size * 4)


def encode_fn(payload, emit, ctx):
    m, s = _encode(jnp.asarray(payload))
    emit((float(m), float(s)), size_bytes=64)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=20.0)
    ap.add_argument("--no-qos", action="store_true")
    args = ap.parse_args()

    jg = JobGraph("media")
    jg.add_vertex(JobVertex("Partitioner", 2, is_source=True))
    jg.add_vertex(JobVertex("Decoder", 2, fn=decode_fn))
    jg.add_vertex(JobVertex("Merger", 2, fn=merge_fn))
    jg.add_vertex(JobVertex("Overlay", 2, fn=overlay_fn))
    jg.add_vertex(JobVertex("Encoder", 2, fn=encode_fn))
    jg.add_vertex(JobVertex("RTPServer", 2, is_sink=True))
    jg.add_edge("Partitioner", "Decoder", ALL_TO_ALL)
    jg.add_edge("Decoder", "Merger", POINTWISE)
    jg.add_edge("Merger", "Overlay", POINTWISE)
    jg.add_edge("Overlay", "Encoder", POINTWISE)
    jg.add_edge("Encoder", "RTPServer", ALL_TO_ALL)

    seq = JobSequence.of(("Partitioner", "Decoder"), "Decoder",
                         ("Decoder", "Merger"), "Merger",
                         ("Merger", "Overlay"), "Overlay",
                         ("Overlay", "Encoder"), "Encoder",
                         ("Encoder", "RTPServer"))
    jc = JobConstraint(seq, latency_limit_ms=200.0, window_ms=4_000.0,
                       name="e2e")

    eng = StreamEngine(
        jg, [jc], num_workers=2,
        sources={"Partitioner": SourceSpec(
            rate_items_per_s=60.0,
            make_payload=lambda s: (s % 97, 256))},
        initial_buffer_bytes=16 * 1024,
        measurement_interval_ms=1_000.0,
        enable_qos=not args.no_qos,
    )
    res = eng.run(args.duration * 1e3)
    print(f"frames delivered: {res.items_at_sinks}")
    print(f"mean end-to-end latency: {res.mean_latency_ms:.1f} ms  "
          f"(p90 {res.latency_percentile(0.9):.1f} ms)")
    print(f"chained groups: {res.chained_groups}")
    sizes = sorted(set(res.final_buffer_sizes.values()))
    print(f"final buffer sizes: {sizes[:6]}{'...' if len(sizes) > 6 else ''}")


if __name__ == "__main__":
    main()
