"""QoS manager: the max-plus DP must agree with brute-force sequence
enumeration (§3.4.1 'efficiently enumerate violated runtime constraints')."""
import itertools

from repro.configs.nephele_media import MediaJobParams, build_media_job
from repro.core import (
    QoSManager,
    RuntimeGraph,
    SimClock,
    enumerate_runtime_sequences,
)
from repro.core.measurement import ChannelStats, QoSReport, TaskStats
from repro.core.setup import compute_qos_setup


def build(m=3, workers=1, limit=100.0):
    p = MediaJobParams(parallelism=m, num_workers=workers,
                       latency_limit_ms=limit)
    jg, jcs = build_media_job(p)
    rg = RuntimeGraph(jg, workers)
    allocs = compute_qos_setup(jg, jcs, rg)
    clock = SimClock()
    mgr = QoSManager(allocs[0], rg, clock)
    return jg, jcs, rg, mgr, clock


def feed(mgr, rg, clock, chan_lat, task_lat):
    rep = QoSReport(worker=0, sent_at_ms=clock.now())
    for c in rg.channels:
        rep.channel_stats.append(ChannelStats(
            channel_id=c.id, mean_latency_ms=chan_lat(c),
            mean_oblt_ms=80.0, buffer_size_bytes=1024,
        ))
    for v in rg.vertices:
        rep.task_stats.append(TaskStats(vertex_id=v.id,
                                        mean_latency_ms=task_lat(v)))
    mgr.receive_report(rep)


def brute_force_worst(jc, rg, scope, chan_lat, task_lat):
    measured = set(jc.sequence.vertices())
    best = -1.0
    owned = set(scope.anchor_tasks)
    for s in enumerate_runtime_sequences(jc, rg):
        if not owned & set(s.vertices()):
            continue
        tot = sum(chan_lat(c) for c in s.channels())
        tot += sum(task_lat(v) for v in s.vertices()
                   if v.job_vertex in measured)
        best = max(best, tot)
    return best


def test_dp_matches_bruteforce():
    jg, jcs, rg, mgr, clock = build(m=3)
    # deterministic but irregular latencies
    chan_lat = lambda c: 1.0 + (hash(c.id) % 97) / 10.0
    task_lat = lambda v: 0.5 + (hash(v.id) % 13) / 10.0
    clock.advance_to(1_000.0)
    feed(mgr, rg, clock, chan_lat, task_lat)
    scope = mgr.allocation.scopes[0]
    res = mgr.analyze(scope)
    expected = brute_force_worst(jcs[0], rg, scope, chan_lat, task_lat)
    assert abs(res.worst_estimate_ms - expected) < 1e-6


def test_violated_channels_found():
    jg, jcs, rg, mgr, clock = build(m=3, limit=50.0)
    # one Partitioner->Decoder channel is pathological
    bad = rg.channels_of("Partitioner", "Decoder")[0].id
    chan_lat = lambda c: 200.0 if c.id == bad else 1.0
    clock.advance_to(1_000.0)
    feed(mgr, rg, clock, chan_lat, lambda v: 1.0)
    res = mgr.analyze(mgr.allocation.scopes[0])
    assert res.worst_estimate_ms > 200.0
    assert bad in {c.id for c in res.violated_channels}
    # healthy parallel channels on non-violated paths are not targeted
    assert len(res.violated_channels) < len(rg.channels)


def test_no_data_means_no_action():
    jg, jcs, rg, mgr, clock = build()
    clock.advance_to(1_000.0)
    assert mgr.analyze(mgr.allocation.scopes[0]) is None
    assert mgr.check() == []


def test_check_emits_buffer_updates_then_cooldown():
    jg, jcs, rg, mgr, clock = build(m=3, limit=10.0)
    clock.advance_to(1_000.0)
    feed(mgr, rg, clock, lambda c: 50.0, lambda v: 1.0)
    actions = mgr.check()
    assert actions, "violation must trigger countermeasures"
    from repro.core import BufferSizeUpdate
    assert all(isinstance(a, BufferSizeUpdate) for a in actions)
    # §3.5: after a run it waits for the measurement window to flush
    assert mgr.check() == []
