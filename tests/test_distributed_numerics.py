"""Distributed execution must be numerically equivalent to single-device —
run in a subprocess with 8 host devices, compare losses for a dense and a
MoE smoke model (this is the test class that catches wrong-math shardings,
e.g. psum over different token sets)."""
import json

import pytest
import subprocess
import sys
import textwrap

CODE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys, json
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.launch.partition import batch_shardings, make_rules, param_shardings
    from repro.models import build_model
    from repro.sharding import use_sharding_rules

    out = {}
    for arch, tweaks in (
        ("qwen3-1.7b", dict(num_heads=4, num_kv_heads=4, d_model=64,
                            d_ff=128)),
        ("mixtral-8x7b", dict(num_heads=4, num_kv_heads=4, d_model=64,
                              d_ff=128, num_experts=4, experts_per_token=2,
                              sliding_window=None)),
        ("mamba2-130m", dict(d_model=64, ssm_state=16, ssm_head_dim=16,
                             ssm_chunk=16)),
    ):
        cfg = get_config(arch, smoke=True).with_(remat=False, **tweaks)
        model = build_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        B, S = 8, 64
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                  cfg.vocab_size)
        batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
        # single device
        l_single = float(jax.jit(model.loss)(params, batch))
        # 2x4 mesh with the production rules
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rules = make_rules(cfg, mesh, seq_len=S, global_batch=B)
        with mesh, use_sharding_rules(rules, mesh):
            psh = param_shardings(model.logical_axes(), mesh, rules)
            bsh = batch_shardings(batch, mesh, rules)
            p_d = jax.device_put(params, psh)
            b_d = jax.device_put(batch, bsh)
            l_dist = float(jax.jit(model.loss)(p_d, b_d))
        out[arch] = {"single": l_single, "dist": l_dist,
                     "rules": {k: str(v) for k, v in rules.items()}}
    print(json.dumps(out))
""")


@pytest.mark.slow
def test_distributed_loss_matches_single_device():
    r = subprocess.run([sys.executable, "-c", CODE], capture_output=True,
                       text=True, timeout=900, cwd=".")
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    for arch, v in out.items():
        rel = abs(v["single"] - v["dist"]) / max(abs(v["single"]), 1e-9)
        assert rel < 5e-3, (
            f"{arch}: single={v['single']:.5f} dist={v['dist']:.5f} "
            f"(rules {v['rules']})"
        )
