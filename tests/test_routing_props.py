"""Property-style tests for key-range routing + keyed state over random key
streams and random rescale sequences (hypothesis, optional test extra)."""
import pytest

pytest.importorskip("hypothesis")  # optional test extra
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import NUM_KEY_RANGES, KeyRouter, StateStore, range_of_key


@settings(deadline=None, max_examples=50)
@given(
    sizes=st.lists(st.integers(min_value=1, max_value=16), min_size=1,
                   max_size=6),
    keys=st.lists(st.integers(min_value=0, max_value=10_000), min_size=1,
                  max_size=200),
)
def test_router_invariants_over_random_rescale_sequences(sizes, keys):
    router = KeyRouter(sizes[0])
    for new_size in sizes[1:]:
        before = {k: router.owner(k) for k in keys}
        plan = router.plan(new_size)
        moved = set(plan.moves)
        router.commit(plan)
        # every range owned by a live subtask
        assert all(0 <= router.owner_of_range(r) < new_size
                   for r in range(NUM_KEY_RANGES))
        # balance within 1
        counts = [0] * new_size
        for r in range(NUM_KEY_RANGES):
            counts[router.owner_of_range(r)] += 1
        assert max(counts) - min(counts) <= 1
        # determinism: unmoved ranges -> unmoved keys
        for k in keys:
            if range_of_key(k) not in moved:
                assert router.owner(k) == before[k]
            else:
                assert router.owner(k) == plan.moves[range_of_key(k)][1]


@settings(deadline=None, max_examples=50)
@given(
    sizes=st.lists(st.integers(min_value=1, max_value=16), min_size=1,
                   max_size=6),
    keys=st.lists(st.integers(min_value=-10_000, max_value=10_000),
                  min_size=1, max_size=200),
)
def test_dense_lookup_table_matches_range_semantics(sizes, keys):
    """The O(1) emit-path contract (core/routing.py): across any random
    rescale sequence, the dense ``table``/``mask`` lookup both backends
    inline is equivalent to the range arithmetic of ``owner()``, the table
    is exactly NUM_KEY_RANGES wide, and ``commit`` swaps it to precisely
    the planned owner tuple (atomically: the table object is immutable)."""
    router = KeyRouter(sizes[0])
    assert router.mask == NUM_KEY_RANGES - 1  # power-of-two default
    for new_size in sizes[1:] + [sizes[0]]:
        plan = router.plan(new_size)
        router.commit(plan)
        table, mask = router.table, router.mask
        assert isinstance(table, tuple) and len(table) == NUM_KEY_RANGES
        assert table == plan.new_owners
        for k in keys:
            # masked index == modulo range arithmetic, negative keys included
            assert table[k & mask] == router.owner(k)
            assert k & mask == range_of_key(k)


@settings(deadline=None, max_examples=30)
@given(
    n_from=st.integers(min_value=1, max_value=12),
    n_to=st.integers(min_value=1, max_value=12),
    keys=st.lists(st.integers(min_value=0, max_value=10_000), min_size=1,
                  max_size=100),
)
def test_plan_does_not_mutate_live_table(n_from, n_to, keys):
    """``plan()`` is pure: until ``commit``, emit-path readers keep seeing
    the old table (the swap is a single tuple rebind)."""
    router = KeyRouter(n_from)
    table_before = router.table
    owners_before = {k: router.owner(k) for k in keys}
    plan = router.plan(n_to)
    assert router.table is table_before
    assert {k: router.owner(k) for k in keys} == owners_before
    router.commit(plan)
    assert router.table is plan.new_owners


@settings(deadline=None, max_examples=50)
@given(
    keys=st.lists(st.integers(min_value=-1_000, max_value=10_000),
                  min_size=1, max_size=300),
    n_from=st.integers(min_value=1, max_value=8),
    n_to=st.integers(min_value=1, max_value=8),
)
def test_migration_partitions_state_exactly(keys, n_from, n_to):
    """Simulated migration over a random key stream: per-key totals are
    conserved, and afterwards every key lives on exactly one store — the
    one the router owns it with."""
    router = KeyRouter(n_from)
    stores = {i: StateStore() for i in range(max(n_from, n_to))}
    totals = {}
    for k in keys:
        stores[router.owner(k)].bump(k)
        totals[k] = totals.get(k, 0) + 1
    plan = router.plan(n_to)
    # snapshot moved ranges from each source, install on targets (the
    # RuntimeRewirer protocol without the execution backends)
    for src in plan.sources:
        entries = stores[src].snapshot(plan.ranges_from(src), evict=True)
        for k, v in entries.items():
            stores[plan.moves[range_of_key(k)][1]].restore({k: v})
    router.commit(plan)
    merged = {}
    holders = {}
    for i, s in stores.items():
        for k, v in s.items():
            merged[k] = merged.get(k, 0) + v
            holders.setdefault(k, []).append(i)
    assert merged == totals  # nothing lost, nothing duplicated
    for k, hs in holders.items():
        assert hs == [router.owner(k)]  # exactly one owner, the routed one


@settings(deadline=None, max_examples=30)
@given(keys=st.lists(
    st.one_of(st.integers(min_value=-100, max_value=100), st.text(max_size=8)),
    min_size=1, max_size=100))
def test_state_store_snapshot_restore_roundtrip_any_hashable(keys):
    s = StateStore()
    for k in keys:
        s.bump(k)
    all_ranges = range(NUM_KEY_RANGES)
    snap = s.snapshot(all_ranges, evict=True)
    assert len(s) == 0
    s.restore(snap)
    for k in set(keys):
        assert s.get(k) == keys.count(k)
