"""Streaming data pipeline: tokenizer roundtrip (hypothesis), packing,
replay determinism (the rollback-recovery contract)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional test extra
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.data import ByteTokenizer, PackedBatchIterator, SyntheticCorpus


@settings(max_examples=100, deadline=None)
@given(st.text(max_size=200))
def test_tokenizer_roundtrip(text):
    tok = ByteTokenizer()
    ids = tok.encode(text)
    assert ids[0] == tok.BOS and ids[-1] == tok.EOS
    assert all(0 <= i < tok.vocab_size for i in ids)
    assert tok.decode(ids) == text


def test_packing_shapes_and_shift():
    it = PackedBatchIterator(SyntheticCorpus(num_documents=50),
                             ByteTokenizer(), batch=4, seq_len=64)
    b = next(it)
    assert b["tokens"].shape == (4, 64)
    assert b["labels"].shape == (4, 64)
    # labels are the next-token shift within each packed row
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_replay_determinism():
    """Restoring the recorded offset must replay the same stream."""
    c = SyntheticCorpus(num_documents=100)
    a = PackedBatchIterator(c, ByteTokenizer(), batch=2, seq_len=32)
    for _ in range(5):
        next(a)
    state = a.state()
    want = [next(a) for _ in range(3)]

    b = PackedBatchIterator(c, ByteTokenizer(), batch=2, seq_len=32)
    b.restore(state)
    got = [next(b) for _ in range(3)]
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w["tokens"], g["tokens"])


def test_corpus_deterministic():
    c = SyntheticCorpus(seed=3)
    assert c.document(7) == SyntheticCorpus(seed=3).document(7)
    assert c.document(7) != c.document(8)
