"""QoS serving plane: batch-mode tasks + adaptive batch sizing."""
import jax
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serving import QoSServer, RequestSpec


@pytest.fixture(scope="module")
def model_and_params():
    cfg = get_config("qwen3-1.7b", smoke=True)
    m = build_model(cfg)
    return m, m.init_params(jax.random.PRNGKey(0)), cfg


@pytest.mark.slow
def test_requests_complete(model_and_params):
    m, params, cfg = model_and_params
    spec = RequestSpec(rate_per_s=20.0, prompt_len=8, gen_len=2,
                       vocab=cfg.vocab_size)
    srv = QoSServer(m, params, spec, latency_limit_ms=500.0,
                    enable_qos=False, initial_buffer_bytes=2048)
    res = srv.run(15_000.0)  # generous: first batches pay jit compiles
    assert res.completed > 10
    assert all(lat > 0 for lat in res.latencies_ms)


@pytest.mark.slow
def test_adaptive_batching_changes_batch_size(model_and_params):
    m, params, cfg = model_and_params
    spec = RequestSpec(rate_per_s=20.0, prompt_len=8, gen_len=2,
                       vocab=cfg.vocab_size)
    srv = QoSServer(m, params, spec, latency_limit_ms=30.0,
                    enable_qos=True, initial_buffer_bytes=4096,
                    measurement_interval_ms=400.0, window_ms=2_000.0)
    res = srv.run(25_000.0)
    assert res.completed > 0
    # contract: either the SLO is met, or the manager moved the batch knob
    # (visible either in the buffer size or in shrinking batch sizes)
    ingress = [v for k, v in res.final_buffer_sizes.items()
               if k.startswith("Ingress")]
    moved = any(v != 4096 for v in ingress) or (
        len(res.batch_sizes) >= 2
        and res.batch_sizes[-1] < res.batch_sizes[0])
    assert res.p(0.9) < 30.0 or moved


@pytest.mark.slow
def test_replica_token_and_kv_cache_gauges(model_and_params):
    """Per-Decode-replica token-throughput and KV-cache-occupancy gauges
    (metrics only — groundwork for token-level autoscaling)."""
    m, params, cfg = model_and_params
    spec = RequestSpec(rate_per_s=20.0, prompt_len=8, gen_len=2,
                       vocab=cfg.vocab_size)
    srv = QoSServer(m, params, spec, latency_limit_ms=500.0,
                    enable_qos=False, initial_buffer_bytes=2048)
    res = srv.run(12_000.0)
    assert res.completed > 0
    replicas = {v.id for v in srv.engine.rg.tasks_of("Decode")}
    assert set(res.replica_metrics) == replicas
    total_tokens = sum(g["tokens_generated"]
                       for g in res.replica_metrics.values())
    # every completed request generated gen_len tokens on some replica
    assert total_tokens >= res.completed * spec.gen_len
    for g in res.replica_metrics.values():
        assert g["token_throughput_per_s"] >= 0.0
        # session records ARE the KV occupancy: each live session pins at
        # least one KV slot (its kv_pos is past the prompt)
        assert g["kv_cache_tokens"] >= g["kv_cache_sessions"]
    assert res.total_token_throughput_per_s > 0.0
    assert sum(g["kv_cache_sessions"]
               for g in res.replica_metrics.values()) > 0


def test_autoscaler_arg_validated(model_and_params):
    m, params, cfg = model_and_params
    spec = RequestSpec(rate_per_s=10.0, prompt_len=8, gen_len=2,
                       vocab=cfg.vocab_size)
    with pytest.raises(ValueError, match="autoscaler"):
        QoSServer(m, params, spec, autoscaler="bogus")


def test_token_autoscaler_wiring_and_sample(model_and_params):
    """``autoscaler="tokens"`` swaps the elastic telemetry for the
    token/KV sample and prices the controller's constraint in tokens."""
    m, params, cfg = model_and_params
    spec = RequestSpec(rate_per_s=10.0, prompt_len=8, gen_len=4,
                       vocab=cfg.vocab_size)
    srv = QoSServer(m, params, spec, elastic=True, autoscaler="tokens",
                    max_decode_replicas=3,
                    kv_token_budget_per_replica=1_000)
    st = srv.engine._elastic[0]
    assert st["sample"] is not None
    # the controller watches decoded tokens/s: request floor x gen_len
    assert st["ctl"].c.min_items_per_s == pytest.approx(
        spec.rate_per_s * spec.gen_len)
    # the engine's own constraint set stays request-denominated (the
    # manager's ScaleRequest countermeasure prices in requests)
    assert all(c.min_items_per_s != st["ctl"].c.min_items_per_s
               for c in srv.constraints if hasattr(c, "min_items_per_s"))
    # sample math: token deltas over wall time, owning its own baseline
    now = srv.engine.clock.now()
    srv._token_sample(now)  # re-baseline
    with srv._lock:
        srv._replica_tokens["fake"] = srv._replica_tokens.get("fake", 0) + 500
    rate, util = srv._token_sample(now + 1_000.0)
    assert rate == pytest.approx(500.0, rel=0.01)
    assert 0.0 <= util <= 1.0


@pytest.mark.slow
def test_mid_run_spawned_replica_true_throughput(model_and_params):
    """Regression: replica_metrics used to divide every replica's tokens
    by the whole-run duration, under-reporting any replica spawned
    mid-run.  A Decode replica scaled out mid-run must report
    ``token_throughput_per_s`` within 5% of its true live-duration rate."""
    m, params, cfg = model_and_params
    spec = RequestSpec(rate_per_s=30.0, prompt_len=8, gen_len=2,
                       vocab=cfg.vocab_size)
    srv = QoSServer(m, params, spec, latency_limit_ms=500.0,
                    enable_qos=False, initial_buffer_bytes=2048,
                    elastic=True, max_decode_replicas=2)
    eng = srv.engine
    # detach the autoscaler: this test drives the rescale by hand, and the
    # idle controller would otherwise scale the spawned replica back in
    eng._elastic.clear()
    eng.start()
    try:
        import time
        time.sleep(4.0)  # warm-up: jit compiles + steady traffic
        before = {v.id for v in eng.rg.tasks_of("Decode")}
        t_lo = eng.clock.now()
        assert eng.scale_out("Decode", 2, reason="test")
        t_hi = eng.clock.now()
        time.sleep(5.0)
    finally:
        res = eng.stop()
    new_rids = {v.id for v in eng.rg.tasks_of("Decode")} - before
    assert len(new_rids) == 1
    rid = new_rids.pop()
    g = srv.replica_metrics(res.duration_ms)[rid]
    end = eng._t0 + res.duration_ms
    # the live window is bracketed by the clock reads around scale_out
    assert end - t_hi - 50.0 <= g["live_duration_ms"] <= end - t_lo + 50.0
    assert g["live_duration_ms"] < 0.8 * res.duration_ms
    # throughput is denominated by the live window, not the full run
    true_rate = g["tokens_generated"] / (g["live_duration_ms"] / 1e3)
    assert g["token_throughput_per_s"] == pytest.approx(true_rate, rel=0.05)
    whole_run_rate = g["tokens_generated"] / (res.duration_ms / 1e3)
    if g["tokens_generated"]:
        assert g["token_throughput_per_s"] > whole_run_rate * 1.2
