"""QoS serving plane: batch-mode tasks + adaptive batch sizing."""
import jax
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serving import QoSServer, RequestSpec


@pytest.fixture(scope="module")
def model_and_params():
    cfg = get_config("qwen3-1.7b", smoke=True)
    m = build_model(cfg)
    return m, m.init_params(jax.random.PRNGKey(0)), cfg


@pytest.mark.slow
def test_requests_complete(model_and_params):
    m, params, cfg = model_and_params
    spec = RequestSpec(rate_per_s=20.0, prompt_len=8, gen_len=2,
                       vocab=cfg.vocab_size)
    srv = QoSServer(m, params, spec, latency_limit_ms=500.0,
                    enable_qos=False, initial_buffer_bytes=2048)
    res = srv.run(15_000.0)  # generous: first batches pay jit compiles
    assert res.completed > 10
    assert all(lat > 0 for lat in res.latencies_ms)


@pytest.mark.slow
def test_adaptive_batching_changes_batch_size(model_and_params):
    m, params, cfg = model_and_params
    spec = RequestSpec(rate_per_s=20.0, prompt_len=8, gen_len=2,
                       vocab=cfg.vocab_size)
    srv = QoSServer(m, params, spec, latency_limit_ms=30.0,
                    enable_qos=True, initial_buffer_bytes=4096,
                    measurement_interval_ms=400.0, window_ms=2_000.0)
    res = srv.run(25_000.0)
    assert res.completed > 0
    # contract: either the SLO is met, or the manager moved the batch knob
    # (visible either in the buffer size or in shrinking batch sizes)
    ingress = [v for k, v in res.final_buffer_sizes.items()
               if k.startswith("Ingress")]
    moved = any(v != 4096 for v in ingress) or (
        len(res.batch_sizes) >= 2
        and res.batch_sizes[-1] < res.batch_sizes[0])
    assert res.p(0.9) < 30.0 or moved


@pytest.mark.slow
def test_replica_token_and_kv_cache_gauges(model_and_params):
    """Per-Decode-replica token-throughput and KV-cache-occupancy gauges
    (metrics only — groundwork for token-level autoscaling)."""
    m, params, cfg = model_and_params
    spec = RequestSpec(rate_per_s=20.0, prompt_len=8, gen_len=2,
                       vocab=cfg.vocab_size)
    srv = QoSServer(m, params, spec, latency_limit_ms=500.0,
                    enable_qos=False, initial_buffer_bytes=2048)
    res = srv.run(12_000.0)
    assert res.completed > 0
    replicas = {v.id for v in srv.engine.rg.tasks_of("Decode")}
    assert set(res.replica_metrics) == replicas
    total_tokens = sum(g["tokens_generated"]
                       for g in res.replica_metrics.values())
    # every completed request generated gen_len tokens on some replica
    assert total_tokens >= res.completed * spec.gen_len
    for g in res.replica_metrics.values():
        assert g["token_throughput_per_s"] >= 0.0
        # session records ARE the KV occupancy: each live session pins at
        # least one KV slot (its kv_pos is past the prompt)
        assert g["kv_cache_tokens"] >= g["kv_cache_sessions"]
    assert res.total_token_throughput_per_s > 0.0
    assert sum(g["kv_cache_sessions"]
               for g in res.replica_metrics.values()) > 0
