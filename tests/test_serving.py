"""QoS serving plane: batch-mode tasks + adaptive batch sizing."""
import jax
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serving import QoSServer, RequestSpec


@pytest.fixture(scope="module")
def model_and_params():
    cfg = get_config("qwen3-1.7b", smoke=True)
    m = build_model(cfg)
    return m, m.init_params(jax.random.PRNGKey(0)), cfg


@pytest.mark.slow
def test_requests_complete(model_and_params):
    m, params, cfg = model_and_params
    spec = RequestSpec(rate_per_s=20.0, prompt_len=8, gen_len=2,
                       vocab=cfg.vocab_size)
    srv = QoSServer(m, params, spec, latency_limit_ms=500.0,
                    enable_qos=False, initial_buffer_bytes=2048)
    res = srv.run(15_000.0)  # generous: first batches pay jit compiles
    assert res.completed > 10
    assert all(lat > 0 for lat in res.latencies_ms)


@pytest.mark.slow
def test_adaptive_batching_changes_batch_size(model_and_params):
    m, params, cfg = model_and_params
    spec = RequestSpec(rate_per_s=20.0, prompt_len=8, gen_len=2,
                       vocab=cfg.vocab_size)
    srv = QoSServer(m, params, spec, latency_limit_ms=30.0,
                    enable_qos=True, initial_buffer_bytes=4096,
                    measurement_interval_ms=400.0, window_ms=2_000.0)
    res = srv.run(25_000.0)
    assert res.completed > 0
    # contract: either the SLO is met, or the manager moved the batch knob
    # (visible either in the buffer size or in shrinking batch sizes)
    ingress = [v for k, v in res.final_buffer_sizes.items()
               if k.startswith("Ingress")]
    moved = any(v != 4096 for v in ingress) or (
        len(res.batch_sizes) >= 2
        and res.batch_sizes[-1] < res.batch_sizes[0])
    assert res.p(0.9) < 30.0 or moved
