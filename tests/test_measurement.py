"""Tagged-item measurement + reporters (paper §3.3)."""
from repro.core import QoSReporter, RunningAverage, SimClock


def test_one_tag_per_interval():
    clock = SimClock()
    rep = QoSReporter(0, clock, interval_ms=1000.0)
    rep.assign_manager(1, channels=["c1"], tasks=[])
    assert rep.should_tag("c1")
    clock.advance_to(500.0)
    assert not rep.should_tag("c1")      # same interval
    clock.advance_to(1_001.0)
    assert rep.should_tag("c1")          # next interval


def test_reports_as_needed_only():
    """§3.4.1: no empty reports."""
    clock = SimClock()
    rep = QoSReporter(0, clock, interval_ms=100.0)
    rep.assign_manager(1, channels=["c1"], tasks=["t1"])
    clock.advance_to(500.0)
    assert rep.maybe_flush() == []       # nothing measured -> nothing sent
    rep.record_channel_latency("c1", 12.0)
    clock.advance_to(700.0)
    out = rep.maybe_flush()
    assert len(out) == 1
    mgr, report = out[0]
    assert mgr == 1
    assert report.channel_stats[0].mean_latency_ms == 12.0
    # aggregation buffer cleared after flush
    clock.advance_to(900.0)
    assert rep.maybe_flush() == []


def test_report_routing_respects_interest():
    clock = SimClock()
    rep = QoSReporter(0, clock, interval_ms=100.0)
    rep.assign_manager(1, channels=["c1"], tasks=[])
    rep.assign_manager(2, channels=["c2"], tasks=[])
    rep.record_channel_latency("c1", 5.0)
    rep.record_channel_latency("c2", 7.0)
    clock.advance_to(500.0)
    out = dict(rep.maybe_flush())
    assert out[1].channel_stats[0].channel_id == "c1"
    assert out[2].channel_stats[0].channel_id == "c2"


def test_running_average_window_eviction():
    ra = RunningAverage(window_ms=1000.0)
    ra.add(0.0, 10.0)
    ra.add(500.0, 20.0)
    assert ra.value(now_ms=600.0) == 15.0
    # first sample falls out of the window
    assert ra.value(now_ms=1_200.0) == 20.0
    assert ra.value(now_ms=3_000.0) is None


def test_running_average_evicts_on_add():
    """Stale samples leave on add(), not only on value(): a window that is
    written between manager reads stays bounded at the window span instead
    of accumulating every sample until the next read."""
    ra = RunningAverage(window_ms=1000.0)
    for i in range(10_000):
        ra.add(float(i), 1.0)
    # never read — yet only the samples inside the window survive
    assert len(ra._items) <= 1001
    assert ra.value(now_ms=9_999.0) == 1.0
    # results identical to read-time eviction: fresh value wins the window
    ra.add(20_000.0, 5.0)
    assert len(ra._items) == 1
    assert ra.value(now_ms=20_000.0) == 5.0


def test_mean_aggregation_per_interval():
    clock = SimClock()
    rep = QoSReporter(0, clock, interval_ms=100.0)
    rep.assign_manager(1, channels=["c"], tasks=[])
    for v in (10.0, 20.0, 30.0):
        rep.record_channel_latency("c", v)
    clock.advance_to(200.0)
    (_, report), = rep.maybe_flush()
    assert report.channel_stats[0].mean_latency_ms == 20.0
    assert report.channel_stats[0].n_samples == 3
