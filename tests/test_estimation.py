"""Predictive QoS: estimator units, pre-flight config rules, the
decision-neutrality invariant, and the proactive path end-to-end.

The load-bearing test here is shadow-mode golden invariance: with
``ProactiveConfig(enabled=False)`` the estimators run on every control
tick but the three pinned decision traces (tests/golden/) must come out
bit-identical — estimator bookkeeping changes NO decisions unless the
proactive path is armed.
"""
from __future__ import annotations

import json

import pytest

from repro.analysis.graph_check import GraphValidationError, run_preflight
from repro.core import (
    ALL_TO_ALL,
    EwmaEstimator,
    HoltEstimator,
    JobConstraint,
    JobGraph,
    JobSequence,
    JobVertex,
    ProactiveConfig,
    SimSourceSpec,
    SlidingWindowTrendEstimator,
    StreamSimulator,
    ThroughputConstraint,
    make_estimator,
)
from repro.core.measurement import RateMeter

from test_sim_determinism import (
    DURATIONS_MS,
    GOLDEN,
    GOLDEN_BATCHED,
    SIMS,
    _assert_trace_equal,
    _trace,
)


# ---------------------------------------------------------------------------
# estimator units
# ---------------------------------------------------------------------------


def test_ewma_converges_and_forecasts_flat():
    est = EwmaEstimator(alpha=0.3)
    assert est.rate_now() == 0.0
    assert est.forecast(1_000.0) == 0.0
    for i in range(200):
        est.update(i * 250.0, 100.0)
    assert est.rate_now() == pytest.approx(100.0)
    # flat forecast: no trend term, any horizon returns the level
    assert est.forecast(10.0) == est.forecast(100_000.0) == est.rate_now()


def test_ewma_validates_alpha():
    with pytest.raises(ValueError):
        EwmaEstimator(alpha=0.0)
    with pytest.raises(ValueError):
        EwmaEstimator(alpha=1.5)


def test_trend_exact_on_linear_ramp():
    """The least-squares fit reproduces a linear ramp exactly: forecast(h)
    is the true rate at now + h."""
    est = SlidingWindowTrendEstimator(window_ms=5_000.0)
    slope, intercept = 0.04, 100.0  # rate(t) = 100 + 0.04 * t
    for i in range(12):
        t = i * 250.0
        est.update(t, intercept + slope * t)
    t_last = 11 * 250.0
    assert est.rate_now() == pytest.approx(intercept + slope * t_last)
    for h in (250.0, 1_000.0, 3_000.0):
        want = intercept + slope * (t_last + h)
        assert est.forecast(h) == pytest.approx(want)


def test_trend_window_evicts_old_samples():
    est = SlidingWindowTrendEstimator(window_ms=1_000.0)
    est.update(0.0, 500.0)  # will age out
    for t in (2_000.0, 2_250.0, 2_500.0, 2_750.0, 3_000.0):
        est.update(t, 100.0)
    assert est.rate_now() == pytest.approx(100.0)
    assert est.forecast(2_000.0) == pytest.approx(100.0)
    with pytest.raises(ValueError):
        SlidingWindowTrendEstimator(window_ms=0.0)


def test_trend_clamps_forecast_at_zero():
    est = SlidingWindowTrendEstimator(window_ms=5_000.0)
    for i in range(8):
        est.update(i * 250.0, max(200.0 - i * 50.0, 0.0))
    assert est.forecast(60_000.0) == 0.0


def test_holt_tracks_ramp():
    est = HoltEstimator(alpha=0.5, beta=0.3)
    slope = 0.05  # per ms
    for i in range(80):
        t = i * 250.0
        est.update(t, 100.0 + slope * t)
    t_last = 79 * 250.0
    now = est.rate_now()
    # smoothed level lags the true value slightly but is close
    assert now == pytest.approx(100.0 + slope * t_last, rel=0.05)
    # the trend term has learned the slope: a 2 s forecast is ahead of
    # now by about slope * horizon
    ahead = est.forecast(2_000.0) - now
    assert ahead == pytest.approx(slope * 2_000.0, rel=0.15)


def test_holt_duplicate_timestamp_folds_into_level():
    est = HoltEstimator()
    est.update(0.0, 100.0)
    est.update(250.0, 110.0)
    trend_before = est._trend
    est.update(250.0, 300.0)  # same timestamp: no trend update
    assert est._trend == trend_before
    assert est.rate_now() > 110.0
    with pytest.raises(ValueError):
        HoltEstimator(alpha=0.0)
    with pytest.raises(ValueError):
        HoltEstimator(beta=2.0)


def test_make_estimator_registry():
    assert isinstance(make_estimator("ewma"), EwmaEstimator)
    assert isinstance(make_estimator("trend", window_ms=2_000.0),
                      SlidingWindowTrendEstimator)
    assert isinstance(make_estimator("holt", alpha=0.4), HoltEstimator)
    with pytest.raises(ValueError, match="unknown estimator kind"):
        make_estimator("quadratic")


def test_rate_meter_converts_counts_to_rates():
    m = RateMeter()
    assert m.sample(1_000.0, 50.0) is None  # first call: baseline only
    assert m.sample(2_000.0, 150.0) == pytest.approx(100.0)  # 100 items/s
    assert m.sample(2_000.0, 200.0) is None  # non-advancing clock
    # counter reset (task retired): clamp at zero, never negative
    assert m.sample(3_000.0, 10.0) == 0.0


# ---------------------------------------------------------------------------
# NS-E pre-flight rules
# ---------------------------------------------------------------------------


def _tiny_jg() -> JobGraph:
    jg = JobGraph("tiny")
    jg.add_vertex(JobVertex("S", 1, is_source=True))
    jg.add_vertex(JobVertex("K", 1, is_sink=True))
    jg.add_edge("S", "K", ALL_TO_ALL)
    return jg


def _preflight_rules(**kw) -> set[str]:
    try:
        run_preflight(_tiny_jg(), [], measurement_interval_ms=1_000.0, **kw)
    except GraphValidationError as e:
        return {d.rule for d in e.diagnostics}
    return set()


def test_preflight_rejects_nonpositive_horizon():
    rules = _preflight_rules(proactive=ProactiveConfig(horizon_ms=0.0))
    assert "NS-E001" in rules
    rules = _preflight_rules(proactive=ProactiveConfig(horizon_ms=-5.0))
    assert "NS-E001" in rules


def test_preflight_rejects_nonpositive_update_period():
    rules = _preflight_rules(
        proactive=ProactiveConfig(update_period_ms=0.0))
    assert "NS-E002" in rules


def test_preflight_rejects_horizon_below_control_tick():
    # control tick is measurement_interval_ms / 4 = 250 ms
    rules = _preflight_rules(proactive=ProactiveConfig(horizon_ms=100.0))
    assert "NS-E003" in rules
    assert _preflight_rules(
        proactive=ProactiveConfig(horizon_ms=250.0)) == set()


def test_preflight_rejects_unknown_estimator_kind():
    rules = _preflight_rules(
        proactive=ProactiveConfig(estimator="quadratic"))
    assert "NS-E004" in rules


def test_preflight_accepts_valid_config_and_none():
    assert _preflight_rules(proactive=None) == set()
    assert _preflight_rules(proactive=ProactiveConfig()) == set()


def test_simulator_ctor_runs_estimator_preflight():
    with pytest.raises(GraphValidationError):
        StreamSimulator(
            _tiny_jg(), [], num_workers=1,
            sources={"S": SimSourceSpec(10.0)},
            proactive=ProactiveConfig(estimator="nope"))


# ---------------------------------------------------------------------------
# decision neutrality: shadow mode reproduces the golden traces bit-exactly
# ---------------------------------------------------------------------------


def test_shadow_mode_reproduces_golden_traces():
    """Estimators armed, proactive actions off: all three pinned decision
    traces must come out bit-identical to the golden file."""
    golden = json.loads(GOLDEN.read_text())
    shadow = ProactiveConfig(enabled=False)
    for name, builder in SIMS.items():
        got = _trace(builder(proactive=shadow).run(DURATIONS_MS[name]))
        _assert_trace_equal(f"{name}[shadow]", got, golden[name])


def test_shadow_mode_golden_heap_and_batched():
    """Same invariant on the other scheduler and the batched event core
    (one scenario each keeps the suite fast; ci.sh covers the matrix)."""
    shadow = ProactiveConfig(enabled=False)
    golden = json.loads(GOLDEN.read_text())
    got = _trace(SIMS["scale"](scheduler="heap", proactive=shadow)
                 .run(DURATIONS_MS["scale"]))
    _assert_trace_equal("scale[heap,shadow]", got, golden["scale"])
    golden_b = json.loads(GOLDEN_BATCHED.read_text())
    got = _trace(SIMS["scale"](event_mode="batched", proactive=shadow)
                 .run(DURATIONS_MS["scale"]))
    _assert_trace_equal("scale[batched,shadow]", got, golden_b["scale"])


# ---------------------------------------------------------------------------
# proactive path end-to-end (simulator)
# ---------------------------------------------------------------------------


def _burst_rate(elapsed_ms: float) -> float:
    """150/s steady, linear ramp to 450/s over 10 s, hold, drop to 100/s."""
    if elapsed_ms < 10_000.0:
        return 150.0
    if elapsed_ms < 20_000.0:
        return 150.0 + (elapsed_ms - 10_000.0) * 0.03
    if elapsed_ms < 30_000.0:
        return 450.0
    return 100.0


def _proactive_sim(proactive: ProactiveConfig | None) -> StreamSimulator:
    jg = JobGraph("proactive-e2e")
    jg.add_vertex(JobVertex("Src", 2, is_source=True, sim_cpu_ms=0.01))
    jg.add_vertex(JobVertex("Work", 2, sim_cpu_ms=4.0, sim_item_bytes=256))
    jg.add_vertex(JobVertex("Sink", 1, is_sink=True, sim_cpu_ms=0.01))
    jg.add_edge("Src", "Work", ALL_TO_ALL)
    jg.add_edge("Work", "Sink", ALL_TO_ALL)
    seq = JobSequence.of(("Src", "Work"), "Work", ("Work", "Sink"))
    jcs = [JobConstraint(seq, 300.0, 3_000.0, name="lat"),
           ThroughputConstraint("Work", 300.0, window_ms=3_000.0,
                                max_parallelism=6)]
    return StreamSimulator(
        jg, jcs, num_workers=2,
        sources={"Src": SimSourceSpec(150.0, item_bytes=256, keys=64,
                                      rate_fn=_burst_rate)},
        initial_buffer_bytes=1024, enable_qos=True, enable_chaining=False,
        seed=5, proactive=proactive)


def test_proactive_scales_out_before_violation_and_gives_back():
    sim = _proactive_sim(ProactiveConfig(horizon_ms=3_000.0,
                                         estimator="trend"))
    res = sim.run(60_000.0)
    reasons = [repr(a) for h in res.manager_history for a in h.actions]
    assert any("proactive: forecast util" in r for r in reasons), reasons
    assert any("sustained low forecast" in r for r in reasons), reasons
    # after the give-back the stage is at its job-declared base again
    assert len(sim.rg.tasks_of("Work")) == 2
    # the proactive scale-out actually went live (scale_log, not just a
    # requested action)
    assert any(d.to_parallelism > d.from_parallelism for d in res.scale_log)
    assert any(d.to_parallelism < d.from_parallelism for d in res.scale_log)


def test_proactive_path_is_deterministic():
    cfg = ProactiveConfig(horizon_ms=3_000.0, estimator="trend")
    a = _trace(_proactive_sim(cfg).run(45_000.0))
    b = _trace(_proactive_sim(cfg).run(45_000.0))
    assert a == b
