"""Job graph / runtime graph formalism (paper §3.1)."""
import pytest

from repro.core import (
    ALL_TO_ALL,
    POINTWISE,
    JobGraph,
    JobVertex,
    RuntimeGraph,
)


def make_jg(m=4):
    jg = JobGraph("t")
    jg.add_vertex(JobVertex("A", m, is_source=True))
    jg.add_vertex(JobVertex("B", m))
    jg.add_vertex(JobVertex("C", m, is_sink=True))
    jg.add_edge("A", "B", ALL_TO_ALL)
    jg.add_edge("B", "C", POINTWISE)
    return jg


def test_expansion_counts():
    rg = RuntimeGraph(make_jg(4), num_workers=2)
    assert len(rg.vertices) == 12
    # A->B all-to-all: 16 channels; B->C pointwise: 4
    assert len(rg.channels) == 20
    assert rg.num_runtime_edges("A", "B") == 16
    assert rg.num_runtime_edges("B", "C") == 4


def test_worker_allocation_spread():
    rg = RuntimeGraph(make_jg(4), num_workers=2)
    for jv in ("A", "B", "C"):
        workers = [rg.worker(v) for v in rg.tasks_of(jv)]
        assert sorted(set(workers)) == [0, 1]


def test_pointwise_requires_equal_parallelism():
    jg = JobGraph("t")
    jg.add_vertex(JobVertex("A", 2))
    jg.add_vertex(JobVertex("B", 3))
    with pytest.raises(ValueError):
        jg.add_edge("A", "B", POINTWISE)


def test_cycle_rejected():
    jg = make_jg(2)
    with pytest.raises(ValueError):
        jg.add_edge("C", "A")


def test_in_out_channels_consistent():
    rg = RuntimeGraph(make_jg(3), num_workers=3)
    for v in rg.tasks_of("B"):
        assert len(rg.in_channels(v)) == 3   # from every A
        assert len(rg.out_channels(v)) == 1  # pointwise to C
