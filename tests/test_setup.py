"""Algorithms 1-3 (paper §3.4.2) incl. hypothesis property tests on the
side conditions."""
import pytest

pytest.importorskip("hypothesis")  # optional test extra
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.nephele_media import MediaJobParams, build_media_job
from repro.core import RuntimeGraph, check_side_conditions
from repro.core.setup import (
    compute_qos_setup,
    compute_reporter_setup,
    get_anchor_vertex,
)


def test_anchor_is_decoder_for_media_job():
    """All vertices tie on worker count; Decoder wins the min-runtime-edge
    tiebreak (Algorithm 3)."""
    p = MediaJobParams(parallelism=8, num_workers=4)
    jg, jcs = build_media_job(p)
    rg = RuntimeGraph(jg, 4)
    path = jcs[0].sequence.covered_path()
    assert get_anchor_vertex(path, rg) == "Decoder"


def test_one_manager_per_worker_hosting_anchors():
    p = MediaJobParams(parallelism=8, num_workers=4)
    jg, jcs = build_media_job(p)
    rg = RuntimeGraph(jg, 4)
    allocs = compute_qos_setup(jg, jcs, rg)
    assert len(allocs) == 4  # anchors spread over all 4 workers
    check_side_conditions(allocs, jcs, rg)


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(min_value=2, max_value=12),
    workers=st.integers(min_value=1, max_value=6),
)
def test_side_conditions_hold_for_any_scale(m, workers):
    """Property (§3.4.2): every constraint owned exactly once; subgraphs
    minimal — for any parallelism/worker combination."""
    p = MediaJobParams(parallelism=m, num_workers=workers)
    jg, jcs = build_media_job(p)
    rg = RuntimeGraph(jg, workers)
    allocs = compute_qos_setup(jg, jcs, rg)
    check_side_conditions(allocs, jcs, rg)
    assert len(allocs) == min(workers, m)


def test_reporter_routes_cover_all_subgraph_elements():
    p = MediaJobParams(parallelism=4, num_workers=2)
    jg, jcs = build_media_job(p)
    rg = RuntimeGraph(jg, 2)
    allocs = compute_qos_setup(jg, jcs, rg)
    ra = compute_reporter_setup(allocs, rg)
    for mgr_worker, alloc in allocs.items():
        for c in alloc.subgraph.channels:
            # receiver-side latency route exists
            assert c.id in ra.channel_routes[rg.worker(c.dst)][mgr_worker]
            # sender-side oblt route exists
            assert c.id in ra.channel_routes[rg.worker(c.src)][mgr_worker]
        for v in alloc.subgraph.vertices:
            assert v.id in ra.task_routes[rg.worker(v)][mgr_worker]
