"""End-to-end behaviour tests for the paper's system: the Fig. 7->8->9
narrative — unoptimized, +adaptive buffers (order-of-magnitude), +chaining
(further reduction under a tight SLO) — on the simulated cluster, plus the
training-plane integration (train a model for real and meet FT semantics)."""
import pytest

from repro.configs.nephele_media import (
    H264_PACKET_BYTES,
    MediaJobParams,
    build_media_job,
)
from repro.core import SimSourceSpec, StreamSimulator


def _run(limit, qos, chaining, duration=240_000.0):
    p = MediaJobParams(parallelism=8, num_workers=2, streams=64, fps=25.0,
                       latency_limit_ms=limit)
    jg, jcs = build_media_job(p)
    sim = StreamSimulator(
        jg, jcs, p.num_workers,
        sources={"Partitioner": SimSourceSpec(
            rate_items_per_s=p.fps * p.streams / p.parallelism,
            item_bytes=H264_PACKET_BYTES, keys_per_task=2)},
        initial_buffer_bytes=32 * 1024,
        enable_qos=qos, enable_chaining=chaining,
    )
    return sim.run(duration)


@pytest.mark.slow
def test_paper_narrative_fig7_fig8_fig9():
    unopt = _run(300.0, qos=False, chaining=False, duration=120_000.0)
    buffers = _run(300.0, qos=True, chaining=False, duration=120_000.0)
    # Fig. 8: order-of-magnitude from buffers alone; constraint met
    lat_u = unopt.mean_latency_ms(60_000)
    lat_b = buffers.mean_latency_ms(60_000)
    assert lat_u / lat_b > 10.0
    assert lat_b < 300.0
    # Fig. 9 mechanism: under a tighter SLO buffers alone are not enough and
    # chaining engages, improving further
    tight_nochain = _run(22.0, qos=True, chaining=False)
    tight_chain = _run(22.0, qos=True, chaining=True)
    assert len(tight_chain.chained_groups) >= 1
    assert (tight_chain.mean_latency_ms(180_000)
            < tight_nochain.mean_latency_ms(180_000))
    # throughput preserved throughout (the paper's standing requirement)
    assert (tight_chain.throughput_items_per_s
            > 0.95 * unopt.throughput_items_per_s)


@pytest.mark.slow
def test_training_plane_end_to_end(tmp_path):
    """Train a small model for 60 steps with an injected failure; loss must
    decrease across the restart (checkpoint + data replay intact)."""
    from repro.launch.train import train

    out = train(
        arch="qwen3-1.7b", smoke=True, steps=60, batch=4, seq=128,
        ckpt_dir=str(tmp_path), save_every=20, log_every=0,
        fail_at={30: "injected"},
    )
    assert out["losses"][-1] < out["losses"][0]
    assert not out["dead_workers"]
