"""Elastic throughput scaling (paper §6 future work, core/elastic.py):
an undersized stage saturates, the controller scales it out live, and the
delivered throughput recovers to the constraint."""
from repro.core import (
    ALL_TO_ALL,
    ElasticController,
    JobConstraint,
    JobGraph,
    JobSequence,
    JobVertex,
    SimSourceSpec,
    StreamSimulator,
    ThroughputConstraint,
)


def build(workers=4):
    jg = JobGraph("elastic")
    jg.add_vertex(JobVertex("Src", 4, is_source=True, sim_cpu_ms=0.01,
                            sim_item_bytes=256))
    # 2 workers x 4ms per item: capacity ~500/s < offered 800/s
    jg.add_vertex(JobVertex("Work", 2, sim_cpu_ms=4.0, sim_item_bytes=256))
    jg.add_vertex(JobVertex("Sink", 4, is_sink=True, sim_cpu_ms=0.01))
    jg.add_edge("Src", "Work", ALL_TO_ALL)
    jg.add_edge("Work", "Sink", ALL_TO_ALL)
    seq = JobSequence.of(("Src", "Work"), "Work", ("Work", "Sink"))
    jc = JobConstraint(seq, 1e9, 5_000.0, name="lat")  # monitoring only
    return jg, [jc]


def run(elastic: bool, duration=60_000.0):
    jg, jcs = build()
    sim = StreamSimulator(
        jg, jcs, num_workers=4,
        sources={"Src": SimSourceSpec(rate_items_per_s=200.0,
                                      item_bytes=256, keys=64)},
        initial_buffer_bytes=2048, enable_qos=False,
    )
    ctl = None
    if elastic:
        ctl = ElasticController(
            ThroughputConstraint("Work", min_items_per_s=750.0,
                                 window_ms=5_000.0),
            max_parallelism=16, step=2, cooldown_ms=5_000.0,
        )
        sim.attach_elastic(ctl)
    res = sim.run(duration)
    return sim, ctl, res


def test_saturated_stage_scales_out_and_recovers():
    sim_e, ctl, res_e = run(elastic=True)
    _, _, res_f = run(elastic=False)
    # scale-out happened
    assert ctl.decisions, "controller never acted"
    assert len(sim_e.rg.tasks_of("Work")) > 2
    # throughput recovered vs the fixed run
    assert res_e.throughput_items_per_s > 1.3 * res_f.throughput_items_per_s
    # and approaches the offered 800/s
    late = res_e.throughput_items_per_s
    assert late > 600.0


def test_grow_vertex_rejects_pointwise():
    import pytest

    from repro.core import POINTWISE, RuntimeGraph

    jg = JobGraph("pw")
    jg.add_vertex(JobVertex("A", 2, is_source=True))
    jg.add_vertex(JobVertex("B", 2))
    jg.add_edge("A", "B", POINTWISE)
    rg = RuntimeGraph(jg, 2)
    with pytest.raises(ValueError):
        rg.grow_vertex("B", 4)


def test_grow_vertex_wiring():
    from repro.core import RuntimeGraph

    jg, _ = build()
    rg = RuntimeGraph(jg, 4)
    before = len(rg.channels)
    new_vs, new_cs = rg.grow_vertex("Work", 4)
    assert len(new_vs) == 2
    # each new task: 4 in (from Src) + 4 out (to Sink)
    assert len(new_cs) == 2 * 8
    assert len(rg.channels) == before + 16
    for v in new_vs:
        assert len(rg.in_channels(v)) == 4
        assert len(rg.out_channels(v)) == 4
