"""Discrete-event simulator scenarios: the paper's qualitative results."""
import pytest

from repro.configs.nephele_media import (
    H264_PACKET_BYTES,
    MediaJobParams,
    build_media_job,
)
from repro.core import SimSourceSpec, StreamSimulator


def run_media(qos, chaining=False, limit=300.0, duration=120_000.0, m=8,
              window=15_000.0):
    p = MediaJobParams(parallelism=m, num_workers=2, streams=8 * m,
                       fps=25.0, latency_limit_ms=limit, window_ms=window)
    jg, jcs = build_media_job(p)
    sim = StreamSimulator(
        jg, jcs, p.num_workers,
        sources={"Partitioner": SimSourceSpec(
            rate_items_per_s=p.fps * p.streams / p.parallelism,
            item_bytes=H264_PACKET_BYTES,
            keys_per_task=(p.streams // p.group_size) // p.parallelism)},
        initial_buffer_bytes=32 * 1024,
        enable_qos=qos, enable_chaining=chaining,
    )
    return sim.run(duration)


@pytest.fixture(scope="module")
def unopt():
    return run_media(qos=False)


@pytest.fixture(scope="module")
def adaptive():
    return run_media(qos=True)


@pytest.mark.slow
def test_buffer_sizing_improves_latency_order_of_magnitude(unopt, adaptive):
    """Fig. 7 vs Fig. 8: adaptive buffers must improve mean latency by >10x
    (the paper got ~10x from buffers alone)."""
    lat_un = unopt.mean_latency_ms(after_ms=60_000)
    lat_ad = adaptive.mean_latency_ms(after_ms=60_000)
    assert lat_un > 10 * lat_ad


def test_throughput_preserved(unopt, adaptive):
    """§1: latency optimization must preserve high data throughput."""
    assert adaptive.throughput_items_per_s > 0.95 * unopt.throughput_items_per_s


def test_constraint_met_stops_actions(adaptive):
    """Once the 300ms constraint holds, managers stop acting (§3.5)."""
    assert adaptive.mean_latency_ms(after_ms=60_000) < 300.0
    late = [r for r in adaptive.manager_history if r.at_ms > 90_000]
    assert len(late) == 0


@pytest.mark.slow
def test_chaining_triggers_under_tight_constraint():
    """When buffers alone cannot meet the SLO, the managers chain the
    Decoder..Encoder series (Fig. 9's mechanism)."""
    res = run_media(qos=True, chaining=True, limit=22.0,
                    duration=300_000.0)
    assert len(res.chained_groups) >= 1
    for group in res.chained_groups:
        assert [g.split("[")[0] for g in group] == [
            "Decoder", "Merger", "Overlay", "Encoder"]


@pytest.mark.slow
def test_give_up_reports_on_infeasible_constraint():
    """§3.5: when countermeasures are exhausted the master is notified.
    Construct the exhausted state deterministically: buffers already at
    omega with obl ~ 0 (no Eq. 2/3 move possible) and a single-task
    sequence (nothing to chain)."""
    from repro.core import (ALL_TO_ALL, JobConstraint, JobGraph, JobSequence,
                            JobVertex, SimSourceSpec, StreamSimulator)
    from repro.core.buffers import BufferSizingPolicy

    jg = JobGraph("giveup")
    jg.add_vertex(JobVertex("Src", 2, is_source=True, sim_cpu_ms=0.01,
                            sim_item_bytes=128))
    jg.add_vertex(JobVertex("Work", 2, sim_cpu_ms=0.05, sim_item_bytes=128))
    jg.add_vertex(JobVertex("Sink", 2, is_sink=True, sim_cpu_ms=0.01))
    jg.add_edge("Src", "Work", ALL_TO_ALL)
    jg.add_edge("Work", "Sink", ALL_TO_ALL)
    seq = JobSequence.of(("Src", "Work"), "Work", ("Work", "Sink"))
    jc = JobConstraint(seq, latency_limit_ms=1e-4, window_ms=2_000.0,
                       name="infeasible")
    omega = 64 * 1024
    sim = StreamSimulator(
        jg, [jc], num_workers=2,
        sources={"Src": SimSourceSpec(rate_items_per_s=2_000.0,
                                      item_bytes=128, keys=8)},
        initial_buffer_bytes=omega,
        policy=BufferSizingPolicy(omega_bytes=omega),
        enable_qos=True, enable_chaining=True,
        # the static feasibility pass (NS-F001) correctly rejects this
        # deliberately-impossible bound at construction; bypass it — the
        # point here is the *runtime* give-up path
        preflight=False,
    )
    res = sim.run(60_000.0)
    assert len(res.give_ups) >= 1
